//! Online multi-job demo: three ways to fill a shared cluster.
//!
//! 1. Two zip tenants sharing 50% of their input — watch the shared
//!    blocks' cross-job effective reference counts keep them cached
//!    under LERC while LRU wastes them.
//! 2. Poisson arrivals: four tenants trickling in at exponential gaps.
//! 3. A priority mix: short interactive probes cutting ahead of long
//!    batch jobs.
//!
//! Everything runs on the deterministic simulator, so the numbers are
//! identical on every machine. Run with:
//! `cargo run --release --example multijob_demo`

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind};
use lerc_engine::metrics::report::fleet_table;
use lerc_engine::sim::Simulator;
use lerc_engine::workload;

fn cfg(policy: PolicyKind, cache_blocks: u64) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(4)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .build()
        .expect("valid config")
}

fn main() {
    // --- 1. shared input, LERC vs LRU --------------------------------
    let queue = workload::multijob_zip_shared(2, 12, 4096, true, 6);
    println!("== {} ==", queue.name);
    for policy in [PolicyKind::Lru, PolicyKind::Lerc] {
        let fleet = Engine::run(&Simulator::from_engine_config(cfg(policy, 3)), &queue).unwrap();
        println!("\n{}:", policy.name());
        print!("{}", fleet_table(&fleet));
    }

    // --- 2. Poisson arrivals ------------------------------------------
    let queue = workload::multijob_poisson(4, 8, 4096, 6.0, 42);
    println!("\n== {} ==", queue.name);
    let sim = Simulator::from_engine_config(cfg(PolicyKind::Lerc, 4));
    let fleet = Engine::run(&sim, &queue).unwrap();
    print!("{}", fleet_table(&fleet));

    // --- 3. priority mix ----------------------------------------------
    let queue = workload::multijob_priority_mix(4, 8, 4096, 4);
    println!("\n== {} ==", queue.name);
    let sim = Simulator::from_engine_config(cfg(PolicyKind::Lerc, 4));
    let fleet = Engine::run(&sim, &queue).unwrap();
    print!("{}", fleet_table(&fleet));

    println!("\nmultijob_demo done");
}
