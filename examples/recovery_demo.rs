//! Failure injection walk-through: kill a worker halfway through the
//! multi-tenant zip experiment and watch lineage recovery re-home and
//! recompute the lost blocks, per policy.
//!
//!     cargo run --release --example recovery_demo
//!
//! Runs on the deterministic simulator (seconds). The kill fires once
//! 50% of tasks have been dispatched; the driver quiesces, wipes worker
//! 1 (memory + its executor-local transform blocks — ingest data
//! survives in replicated external storage), synthesizes the minimal
//! recompute closure from lineage, and re-homes the orphans over the
//! surviving workers (DESIGN.md §3).

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind};
use lerc_engine::recovery::TopologyPlan;
use lerc_engine::sim::Simulator;
use lerc_engine::workload;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let (tenants, blocks, block_len, workers) = (6u32, 20u32, 65536usize, 4u32);
    let w = workload::multi_tenant_zip(tenants, blocks, block_len);
    let total = w.task_count() as u64;
    let cache_blocks = (tenants * blocks * 2) as u64 / 3 / workers as u64;

    println!(
        "recovery demo — {tenants} tenants x 2 x {blocks} blocks, {workers} workers, \
         kill W1 at {}/{total} dispatches\n",
        total / 2
    );
    println!(
        "| policy | clean (s) | with kill (s) | recovery (s) | lost | recomputed | eff ratio |"
    );
    println!("|---|---|---|---|---|---|---|");
    for policy in [PolicyKind::Lru, PolicyKind::Lrc, PolicyKind::Lerc] {
        let cfg = |topology: TopologyPlan| {
            EngineConfig::builder()
                .num_workers(workers)
                .block_len(block_len)
                .cache_blocks(cache_blocks)
                .policy(policy)
                .topology(topology)
                .build()
                .expect("valid config")
        };
        let clean = Simulator::from_engine_config(cfg(TopologyPlan::none())).run_workload(&w)?;
        let kill_sim = Simulator::from_engine_config(cfg(TopologyPlan::kill_at(1, total / 2)));
        let killed = kill_sim.run_workload(&w)?;
        println!(
            "| {} | {:.3} | {:.3} | {:.3} | {} | {} | {:.3} |",
            policy.name(),
            clean.compute_makespan.as_secs_f64(),
            killed.compute_makespan.as_secs_f64(),
            killed.recovery.recovery_time().as_secs_f64(),
            killed.recovery.blocks_lost_cached + killed.recovery.blocks_lost_durable,
            killed.recovery.recompute_tasks,
            killed.effective_hit_ratio(),
        );
    }
    println!(
        "\nEvery policy pays the same recompute bill (lineage is policy-agnostic);\n\
         the difference is how much of the surviving cache still buys effective\n\
         hits — LERC keeps whole peer-groups, LRU keeps orphaned halves."
    );
    Ok(())
}
