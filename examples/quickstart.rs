//! Quickstart: the end-to-end driver on a real small workload.
//!
//! Runs the paper's multi-tenant zip experiment on the **threaded engine**
//! with **real AOT-compiled XLA compute** (PJRT CPU, artifacts built by
//! `make artifacts`), real on-disk blocks, and the HDD throttle model —
//! comparing LRU, LRC and LERC end to end and reporting the paper's
//! metrics. Falls back to the synthetic compute engine when artifacts are
//! missing so the example always runs.
//!
//!     cargo run --release --example quickstart

use lerc_engine::Engine;
use lerc_engine::common::config::{ComputeMode, DiskConfig, EngineConfig, PolicyKind};
use lerc_engine::driver::ClusterEngine;
use lerc_engine::workload;
use std::path::PathBuf;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Scaled-down §IV geometry: 4 tenants × 2 files × 12 blocks of 256 KiB.
    let tenants = 4;
    let blocks = 12;
    let block_len = 65536;
    let workers = 4;
    let w = workload::multi_tenant_zip(tenants, blocks, block_len);
    let input_bytes = w.input_bytes();
    let cache_fraction = 0.66;

    let artifacts = PathBuf::from("artifacts");
    let compute = if artifacts.join("manifest.tsv").exists() {
        println!("compute: PJRT (AOT artifacts from {:?})", artifacts);
        ComputeMode::Pjrt {
            artifacts_dir: artifacts,
        }
    } else {
        println!("compute: synthetic (run `make artifacts` for the XLA path)");
        ComputeMode::Synthetic
    };

    println!(
        "workload: {} | input {} MiB | cache fraction {:.2}\n",
        w.name,
        input_bytes / (1024 * 1024),
        cache_fraction
    );
    println!("| policy | job phase (s) | hit ratio | effective hit ratio | peer msgs |");
    println!("|---|---|---|---|---|");

    let mut lru_time = None;
    for policy in PolicyKind::PAPER {
        let cfg = EngineConfig::builder()
            .num_workers(workers)
            .cache_capacity_per_worker(
                ((input_bytes as f64 * cache_fraction) / workers as f64) as u64,
            )
            .block_len(block_len)
            .policy(policy)
            .compute(compute.clone())
            // Keep the HDD geometry but compress wall time 2×.
            .disk(DiskConfig::default())
            .time_scale(0.5)
            .build()?;
        let report = ClusterEngine::new(cfg).run_workload(&w)?;
        println!(
            "| {} | {:.3} | {:.3} | {:.3} | {} |",
            report.policy,
            report.compute_makespan.as_secs_f64(),
            report.hit_ratio(),
            report.effective_hit_ratio(),
            report.messages.peer_protocol_total()
        );
        match policy {
            PolicyKind::Lru => lru_time = Some(report.compute_makespan),
            PolicyKind::Lerc => {
                if let Some(lru) = lru_time {
                    let gain = 100.0
                        * (1.0 - report.compute_makespan.as_secs_f64() / lru.as_secs_f64());
                    println!("\nLERC speedup over LRU: {gain:.1}% (paper: up to 37%)");
                }
            }
            _ => {}
        }
    }
    Ok(())
}
