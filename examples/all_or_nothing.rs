//! Fig 3 reproduction: the all-or-nothing measurement study.
//!
//! A zip job over two 10-block RDDs; blocks are cached one at a time in
//! the order A1, B1, A2, B2, … . The cache hit ratio climbs linearly, but
//! the total task runtime steps down ONLY when both blocks of a pair are
//! in memory — caching half a pair buys nothing.
//!
//!     cargo run --example all_or_nothing

use lerc_engine::harness::experiments::fig3_all_or_nothing;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let blocks = 10;
    let rows = fig3_all_or_nothing(blocks, 65536)?;

    println!("Fig 3 — zip job, 2 × {blocks} blocks of 256 KiB, cached in order A1,B1,A2,B2,…\n");
    println!("{:>14} | {:>10} | {:>12} | staircase", "cached blocks", "hit ratio", "runtime (s)");
    println!("{}", "-".repeat(60));
    let max_rt = rows
        .iter()
        .map(|r| r.total_runtime.as_secs_f64())
        .fold(0.0f64, f64::max);
    for r in &rows {
        let bar = "#".repeat((40.0 * r.total_runtime.as_secs_f64() / max_rt) as usize);
        println!(
            "{:>14} | {:>10.2} | {:>12.3} | {}",
            r.cached_blocks,
            r.hit_ratio,
            r.total_runtime.as_secs_f64(),
            bar
        );
    }

    // The paper's observation, checked: adding the FIRST block of a pair
    // leaves the runtime flat; adding the second drops it.
    let mut flat = 0;
    let mut drops = 0;
    for k in 1..rows.len() {
        let delta = rows[k - 1].total_runtime.as_secs_f64() - rows[k].total_runtime.as_secs_f64();
        let rel = delta / rows[0].total_runtime.as_secs_f64();
        if k % 2 == 1 {
            assert!(rel.abs() < 0.02, "half-pair at k={k} moved runtime by {rel}");
            flat += 1;
        } else {
            assert!(rel > 0.005, "completed pair at k={k} did not speed up");
            drops += 1;
        }
    }
    println!("\nOK: {flat} half-pair steps flat, {drops} completed-pair steps dropped.");
    println!("Hit ratio grew linearly while runtime moved in pair-sized steps —");
    println!("the cache hit ratio is the wrong metric for data-parallel tasks.");
    Ok(())
}
