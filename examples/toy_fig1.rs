//! Fig 1 toy example: the eviction decision that motivates LERC.
//!
//! Cache holds {a, b, c}; block d is on disk; block e arrives and one
//! block must go. Task 1 coalesces (a, b); Task 2 coalesces (c, d).
//! Evicting c is the only choice that costs nothing — c's cache hit was
//! never *effective* because its peer d is not in memory.
//!
//!     cargo run --example toy_fig1

use lerc_engine::common::config::PolicyKind;
use lerc_engine::harness::experiments::{print_toy_table, toy_fig1_table};

fn main() {
    println!("Paper Fig 1: blocks a,b,c cached (3-entry cache), d on disk, e arriving.\n");
    let rows = toy_fig1_table(&PolicyKind::ALL);
    print_toy_table(&rows);
    println!();
    println!("LERC evicts c — the optimal decision (paper §III-B).");
    println!("Recency/frequency policies and LRC break the (a, b) pair instead,");
    println!("driving the effective cache hit ratio to zero.");

    // Assert the paper's claim as a hard check.
    let lerc = rows.iter().find(|r| r.policy == "LERC").expect("LERC row");
    assert_eq!(lerc.evicted, "c", "LERC must evict c");
    assert!((lerc.effective_hit_ratio - 0.5).abs() < 1e-9);
    println!("\nOK: LERC evicted c; effective cache hit ratio 50%.");
}
