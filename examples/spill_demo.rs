//! Spill-tier walk-through (DESIGN.md §5): run the double-map-zip
//! pipeline under real memory pressure and compare what happens to a
//! policy victim's bytes — dropped outright (recompute), spilled
//! per-block (naive), or demoted group-by-group with pre-dispatch
//! restore (LERC-coordinated).
//!
//!     cargo run --release --example spill_demo
//!
//! Runs on the deterministic simulator (seconds). Watch the recompute
//! column: the coordinated discipline refuses to spend spill budget on
//! dead bytes and never displaces a block a pending task still needs, so
//! under the same budget it re-runs far fewer lineage recomputes — and
//! its restored groups still count as (separately reported) restored
//! hits.

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind, SpillConfig};
use lerc_engine::sim::Simulator;
use lerc_engine::workload;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let (blocks, block_len, workers) = (24u32, 16384usize, 2u32);
    let w = workload::double_map_zip_agg(blocks, block_len);
    let total = w.task_count() as u64;
    let cache_blocks = 3u64;
    let budget = blocks as u64 * (block_len as u64) * 4;

    println!(
        "spill demo — map(A)/map(B) -> zip -> agg over {blocks} blocks, {workers} workers, \
         {cache_blocks} cache blocks/worker, spill budget {} MiB/worker\n",
        budget / (1024 * 1024)
    );
    println!(
        "| spill config | recomputes | spilled | restored | restored hits | spill reads | \
         makespan (s) | eff ratio |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    for (name, spill) in [
        ("none (drop + reread)", None),
        ("budget 0 (recompute)", Some(SpillConfig::coordinated(0))),
        ("per-block (naive)", Some(SpillConfig::per_block(budget))),
        ("coordinated (LERC)", Some(SpillConfig::coordinated(budget))),
    ] {
        let mut builder = EngineConfig::builder()
            .num_workers(workers)
            .block_len(block_len)
            .cache_blocks(cache_blocks)
            .policy(PolicyKind::Lerc);
        if let Some(spill) = spill {
            builder = builder.spill(spill);
        }
        let cfg = builder.build()?;
        let r = Simulator::from_engine_config(cfg).run_workload(&w)?;
        assert_eq!(r.tasks_run, total + r.tier.spill_recompute_tasks);
        println!(
            "| {} | {} | {} | {} | {} | {} | {:.3} | {:.3} |",
            name,
            r.tier.spill_recompute_tasks,
            r.tier.spilled_blocks,
            r.tier.restored_blocks,
            r.tier.restored_hits,
            r.tier.spill_reads,
            r.compute_makespan.as_secs_f64(),
            r.effective_hit_ratio()
        );
    }
    println!(
        "\nwith spill unset the engines behave exactly as before the tier existed \
         (all tier counters zero); see DESIGN.md §5 for the state machine."
    );
    Ok(())
}
