//! The paper's §IV evaluation (Fig 5/6/7) end to end: 10 tenants submit
//! zip jobs in parallel; sweep cache size × {LRU, LRC, LERC}.
//!
//! Default runs on the deterministic simulator (seconds). Pass `--real`
//! to run the threaded engine with real disk files + PJRT compute
//! (minutes; requires `make artifacts`).
//!
//!     cargo run --release --example multi_tenant_zip [--real]

use lerc_engine::common::config::ComputeMode;
use lerc_engine::harness::experiments::{fig5_6_7_sweep, fig5_6_7_sweep_real, ExpOptions};
use lerc_engine::metrics::report::markdown_table;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let real = std::env::args().any(|a| a == "--real");
    let opts = if real {
        ExpOptions {
            tenants: 4,
            blocks_per_file: 12,
            workers: 4,
            fractions: vec![0.42, 0.66],
            ..Default::default()
        }
    } else {
        ExpOptions::default() // paper geometry: 10 tenants × 2 × 50 blocks
    };

    println!(
        "Fig 5/6/7 — {} engine, {} tenants × 2 files × {} blocks ({} MiB input)\n",
        if real { "threaded (real I/O + XLA)" } else { "simulated" },
        opts.tenants,
        opts.blocks_per_file,
        (opts.tenants as u64 * 2 * opts.blocks_per_file as u64 * opts.block_len as u64 * 4)
            / (1024 * 1024),
    );

    let rows = if real {
        let compute = if std::path::Path::new("artifacts/manifest.tsv").exists() {
            ComputeMode::Pjrt {
                artifacts_dir: "artifacts".into(),
            }
        } else {
            ComputeMode::Synthetic
        };
        fig5_6_7_sweep_real(&opts, compute, 0.05)?
    } else {
        fig5_6_7_sweep(&opts)?
    };
    println!("{}", markdown_table(&rows));

    // Paper headline: at the 2/3-cache point LERC cuts runtime vs LRU by
    // ~37% and vs LRC by ~19%.
    let at = |frac: f64, p: &str| {
        rows.iter()
            .find(|r| (r.cache_fraction - frac).abs() < 0.02 && r.policy == p)
            .map(|r| r.makespan_s)
    };
    if let (Some(lru), Some(lrc), Some(lerc)) = (at(0.66, "LRU"), at(0.66, "LRC"), at(0.66, "LERC"))
    {
        println!(
            "at 2/3 cache: LERC vs LRU: -{:.1}% (paper -37.0%) | LERC vs LRC: -{:.1}% (paper -18.6%)",
            100.0 * (1.0 - lerc / lru),
            100.0 * (1.0 - lerc / lrc)
        );
    }
    Ok(())
}
