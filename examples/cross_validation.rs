//! The §II-B motivating scenario for DAG-aware caching: k-fold
//! cross-validation re-reads the training dataset k times, so its blocks
//! carry reference count k while scratch data carries 1. Recency-based
//! policies can't see this; LRC/LERC can.
//!
//!     cargo run --example cross_validation

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind};
use lerc_engine::sim::Simulator;
use lerc_engine::workload;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let folds = 5;
    let blocks = 32;
    let block_len = 65536;
    let w = workload::cross_validation(folds, blocks, block_len);
    let input_bytes = w.input_bytes();

    println!(
        "{folds}-fold cross-validation over {blocks} training blocks (+{blocks} scratch), cache = 50% of input\n"
    );
    println!("| policy | job phase (s) | hit ratio | effective hit ratio |");
    println!("|---|---|---|---|");
    let mut results = Vec::new();
    for policy in [
        PolicyKind::Lru,
        PolicyKind::Lfu,
        PolicyKind::Lrc,
        PolicyKind::Lerc,
    ] {
        let cfg = EngineConfig::builder()
            .num_workers(4)
            .cache_capacity_per_worker(input_bytes / 2 / 4)
            .block_len(block_len)
            .policy(policy)
            .build()?;
        let r = Simulator::from_engine_config(cfg).run_workload(&w)?;
        println!(
            "| {} | {:.3} | {:.3} | {:.3} |",
            r.policy,
            r.compute_makespan.as_secs_f64(),
            r.hit_ratio(),
            r.effective_hit_ratio()
        );
        results.push(r);
    }

    let lru = &results[0];
    let lrc = &results[2];
    let lerc = &results[3];
    assert!(
        lrc.hit_ratio() >= lru.hit_ratio(),
        "LRC must exploit the high reference count of the training set"
    );
    assert!(lerc.compute_makespan <= lru.compute_makespan);
    println!(
        "\nDAG-aware policies keep the k-referenced training set resident: \
         LRC/LERC beat recency-based eviction on re-read-heavy workloads."
    );
    Ok(())
}
