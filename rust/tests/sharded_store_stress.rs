//! Threaded stress tests for the sharded block store's two cross-shard
//! invariants:
//!
//! 1. **All-or-nothing group pinning** — a group registered in the intent
//!    table has every member cached and pinned at every observable
//!    instant; a failed `pin_group` leaves no pins behind (LERC's sticky
//!    sets never exist half-pinned).
//! 2. **Capacity accounting** — per-shard byte accounting re-sums exactly
//!    under concurrent insert/evict/remove churn, never goes negative
//!    (u64 underflow would explode the re-sum check), and stays bounded
//!    by capacity plus the transient-overshoot slack.
//!
//! Plus the Optimistic read path's contracts (DESIGN.md §7): snapshots
//! are never torn (payload and tier observed at the same instant), pin
//! counts stay exact under off-lock readers, and deferred policy touches
//! never change what gets evicted relative to the Locked path.

use lerc_engine::cache::sharded::{ShardedStore, DEFAULT_TOUCH_BUFFER};
use lerc_engine::cache::store::{BlockData, BlockTier};
use lerc_engine::common::config::{PolicyKind, SpillConfig, StoreReadPath};
use lerc_engine::common::ids::{BlockId, DatasetId, GroupId, TaskId};
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::dag::analysis::PeerGroup;
use lerc_engine::peer::WorkerPeerTracker;
use lerc_engine::spill::{demote_evicted, SpillManager};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

const PAYLOAD_WORDS: usize = 32;
const BLOCK_BYTES: u64 = (PAYLOAD_WORDS * 4) as u64;

fn payload() -> BlockData {
    Arc::from(vec![0.5f32; PAYLOAD_WORDS])
}

/// Writers churn datasets 0..4; pinners own dataset 9 exclusively, so a
/// pinned-group member can only disappear through eviction (which must
/// respect pins), never through a foreign `remove`. Shared body for both
/// read paths — pin exactness and capacity accounting are path-blind.
fn churn_store(store: Arc<ShardedStore>) {
    let capacity = store.capacity();
    let stop = Arc::new(AtomicBool::new(false));

    let mut joins = Vec::new();

    // 4 writer threads: insert / get / remove churn over a keyspace ~4x
    // the capacity, forcing constant eviction.
    for t in 0..4u64 {
        let store = store.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0xC0DE ^ t);
            let data = payload();
            for _ in 0..30_000 {
                let b = BlockId::new(
                    DatasetId(rng.next_below(4) as u32),
                    rng.next_below(2048) as u32,
                );
                match rng.next_below(10) {
                    0..=5 => {
                        store.insert(b, data.clone());
                    }
                    6..=8 => {
                        let _ = store.get(b);
                    }
                    _ => {
                        let _ = store.remove(b);
                    }
                }
            }
        }));
    }

    // 2 pinner threads: materialize a group, pin it atomically, verify
    // the sticky-set guarantee while held, release.
    for t in 0..2u64 {
        let store = store.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0x9142 ^ t);
            let data = payload();
            for round in 0..5_000u64 {
                let members: Vec<BlockId> = (0..3)
                    .map(|_| BlockId::new(DatasetId(9), rng.next_below(256) as u32))
                    .collect();
                for &m in &members {
                    store.insert(m, data.clone());
                }
                let gid = GroupId((t << 32) | round);
                if store.pin_group(gid, &members) {
                    // While pinned, every member must stay resident: pins
                    // are exempt from eviction on every shard.
                    for &m in &members {
                        assert!(m.dataset == DatasetId(9));
                        assert!(
                            store.contains(m),
                            "pinned member {m} of group {gid} evicted"
                        );
                    }
                    store.check_group_invariants().expect("group invariant");
                    store.unpin_group(gid);
                }
                // Failed pins must leave nothing behind; verified in
                // aggregate by the zero-pin check after the join below.
            }
        }));
    }

    // Monitor thread: cross-shard invariants under fire.
    {
        let store = store.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let mut checks = 0u64;
            while !stop.load(Ordering::Relaxed) {
                store.check_invariants().expect("store invariants");
                // Capacity accounting: per-shard transient overshoot is
                // at most one block (insert-then-evict happens inside the
                // shard lock); pinned blocks can hold extra bytes.
                let slack = (8 + store.pinned_count() as u64) * BLOCK_BYTES;
                let used = store.used();
                assert!(
                    used <= capacity + slack,
                    "used {used} exceeds capacity {capacity} + slack {slack}"
                );
                checks += 1;
                std::thread::yield_now();
            }
            assert!(checks > 0);
        }));
    }

    // Join workers (all but the monitor, which is last in `joins`).
    let monitor = joins.pop().expect("monitor thread");
    for j in joins {
        j.join().expect("worker thread panicked");
    }
    stop.store(true, Ordering::Relaxed);
    monitor.join().expect("monitor thread panicked");

    // Quiescent state: no pins leaked (every successful pin_group was
    // matched by unpin_group; every failed one rolled back), accounting
    // exact, membership consistent.
    store.flush_touches();
    assert_eq!(store.pinned_count(), 0, "leaked pins after stress");
    assert_eq!(store.pinned_group_count(), 0, "leaked group intents");
    store.check_invariants().expect("final invariants");
    assert!(store.used() <= capacity + 8 * BLOCK_BYTES);
    assert_eq!(store.cached_blocks().len(), store.len());
}

#[test]
fn concurrent_churn_preserves_group_and_capacity_invariants() {
    let store = ShardedStore::new(512 * BLOCK_BYTES, PolicyKind::Lerc, 8);
    churn_store(Arc::new(store));
}

/// Same churn, Optimistic read path: gets are served off-lock from the
/// seqlock index while writers and pinners mutate, and every pin count
/// must still be exact at quiescence.
#[test]
fn concurrent_churn_preserves_invariants_on_optimistic_reads() {
    let store = ShardedStore::with_read_path(
        512 * BLOCK_BYTES,
        PolicyKind::Lerc,
        8,
        StoreReadPath::Optimistic,
        DEFAULT_TOUCH_BUFFER,
    );
    churn_store(Arc::new(store));
}

/// The §5/§7 snapshot-coherence contract: an optimistic reader must
/// never observe a payload paired with a demoted tier record, nor a
/// `Memory` tier with no payload — payload and tier are read at the same
/// instant or not at all. The owner thread drives every block through
/// the full lifecycle (insert → restored-Memory → demoted → reinserted →
/// dropped) while readers snapshot continuously.
#[test]
fn optimistic_reads_never_observe_torn_payload_or_tier() {
    // Capacity for the whole keyspace: no evictions, so the owner's
    // tier transitions are the only residency changes.
    let store = Arc::new(ShardedStore::with_read_path(
        1024 * BLOCK_BYTES,
        PolicyKind::Lru,
        8,
        StoreReadPath::Optimistic,
        DEFAULT_TOUCH_BUFFER,
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for t in 0..3u64 {
        let store = store.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0x7042 ^ t);
            let mut hits = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let b = BlockId::new(DatasetId(5), rng.next_below(256) as u32);
                let (data, tier) = store.get_with_tier(b);
                if data.is_some() {
                    assert!(
                        matches!(tier, None | Some(BlockTier::Memory)),
                        "torn snapshot: {b} served a payload with tier {tier:?}"
                    );
                    hits += 1;
                } else {
                    assert_ne!(
                        tier,
                        Some(BlockTier::Memory),
                        "torn snapshot: {b} marked restored-Memory with no payload"
                    );
                }
            }
            hits
        }));
    }

    // Owner: per-block tier lifecycle, each step leaving the
    // authoritative state coherent (so any torn observation is the read
    // path's fault, not the history's).
    let mut rng = SplitMix64::new(0xD157);
    let data = payload();
    let mut phase = [0u8; 256];
    for round in 0..60_000u64 {
        let i = rng.next_below(256) as usize;
        let b = BlockId::new(DatasetId(5), i as u32);
        match phase[i] {
            0 => {
                store.insert(b, data.clone());
            }
            1 => {
                store.set_tier(b, BlockTier::Memory);
            }
            2 => {
                store.clear_tier(b);
                let _ = store.remove(b);
                store.set_tier(b, BlockTier::SpilledLocal);
            }
            3 => {
                // Re-materialize: insert clears the stale demotion mark.
                store.insert(b, data.clone());
            }
            _ => {
                let _ = store.remove(b);
                store.set_tier(b, BlockTier::Dropped);
            }
        }
        phase[i] = (phase[i] + 1) % 5;
        if round % 4096 == 0 {
            store.check_invariants().expect("invariants under tier churn");
        }
    }

    stop.store(true, Ordering::Relaxed);
    let mut hits = 0u64;
    for j in joins {
        hits += j.join().expect("reader thread panicked");
    }
    assert!(hits > 0, "readers never exercised the optimistic hit path");
    store.flush_touches();
    store.check_invariants().expect("final invariants");
}

/// Locked ≡ Optimistic under concurrent reads: one owner thread applies
/// an identical seeded history to a Locked and an Optimistic store while
/// reader threads hammer the Optimistic store's *pinned* sentinels.
/// Touching a pinned block can never change which unpinned block LRU
/// evicts next, so every insert must evict the same victims in the same
/// order on both stores, and the final contents must be identical —
/// concurrency perturbs timing, never decisions.
#[test]
fn optimistic_matches_locked_contents_under_concurrent_reads() {
    let capacity = 128 * BLOCK_BYTES;
    let locked = Arc::new(ShardedStore::new(capacity, PolicyKind::Lru, 4));
    let optimistic = Arc::new(ShardedStore::with_read_path(
        capacity,
        PolicyKind::Lru,
        4,
        StoreReadPath::Optimistic,
        DEFAULT_TOUCH_BUFFER,
    ));
    let data = payload();

    let sentinels: Vec<BlockId> = (0..8).map(|i| BlockId::new(DatasetId(9), i)).collect();
    for &m in &sentinels {
        locked.insert(m, data.clone());
        optimistic.insert(m, data.clone());
        locked.pin(m);
        optimistic.pin(m);
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let optimistic = optimistic.clone();
        let stop = stop.clone();
        let sentinels = sentinels.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0xF00D ^ t);
            let mut hits = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let m = sentinels[rng.next_below(8) as usize];
                if optimistic.get(m).is_some() {
                    hits += 1;
                }
            }
            hits
        }));
    }

    let mut rng = SplitMix64::new(0xAB1E);
    for step in 0..40_000u64 {
        let b = BlockId::new(DatasetId(0), rng.next_below(512) as u32);
        match rng.next_below(8) {
            0..=4 => {
                let l = locked.insert(b, data.clone());
                let o = optimistic.insert(b, data.clone());
                assert_eq!(l, o, "insert outcome diverged at step {step} ({b})");
            }
            5 => {
                assert_eq!(
                    locked.remove(b).is_some(),
                    optimistic.remove(b).is_some(),
                    "remove diverged at step {step} ({b})"
                );
            }
            _ => {
                assert_eq!(
                    locked.get(b).is_some(),
                    optimistic.get(b).is_some(),
                    "get diverged at step {step} ({b})"
                );
            }
        }
    }

    stop.store(true, Ordering::Relaxed);
    let mut hits = 0u64;
    for j in joins {
        hits += j.join().expect("reader thread panicked");
    }
    assert!(hits > 0, "readers never exercised the optimistic path");

    optimistic.flush_touches();
    let mut l = locked.cached_blocks();
    let mut o = optimistic.cached_blocks();
    l.sort_unstable();
    o.sort_unstable();
    assert_eq!(l, o, "final cached contents diverged");
    assert_eq!(locked.used(), optimistic.used(), "byte accounting diverged");
    locked.check_invariants().expect("locked invariants");
    optimistic.check_invariants().expect("optimistic invariants");
}

/// In-tree property test (the offline build has no proptest crate;
/// randomness is deterministic SplitMix64 with the failing seed in the
/// panic message): at shards=1 a random single-threaded history applied
/// through the Optimistic read path evicts exactly the blocks the Locked
/// path evicts, in the same order, for every policy — deferred touches
/// change *when* policy bookkeeping runs, never what it decides. A tiny
/// touch ring forces the full-ring inline-drain fallback to take part.
#[test]
fn prop_deferred_touches_never_change_evictions_at_one_shard() {
    const CASES: u64 = 25;
    for (ki, &kind) in PolicyKind::ALL.iter().enumerate() {
        for case in 0..CASES {
            let seed = 0x5EED_0000 ^ ((ki as u64) << 16) ^ case;
            equivalent_history(kind, seed);
        }
    }
}

fn equivalent_history(kind: PolicyKind, seed: u64) {
    let capacity = 24 * BLOCK_BYTES;
    let locked = ShardedStore::new(capacity, kind, 1);
    // A tiny ring also exercises the full-ring inline-drain fallback.
    let ring = 8;
    let optimistic =
        ShardedStore::with_read_path(capacity, kind, 1, StoreReadPath::Optimistic, ring);
    let mut rng = SplitMix64::new(seed);
    let data = payload();
    let mut pins: Vec<BlockId> = Vec::new();
    for step in 0..300 {
        let b = BlockId::new(DatasetId(0), rng.next_below(64) as u32);
        match rng.next_below(10) {
            0..=3 => {
                let l = locked.insert(b, data.clone());
                let o = optimistic.insert(b, data.clone());
                assert_eq!(l, o, "[{kind:?} seed={seed}] insert diverged at step {step}");
            }
            4..=6 => {
                assert_eq!(
                    locked.get(b).is_some(),
                    optimistic.get(b).is_some(),
                    "[{kind:?} seed={seed}] get diverged at step {step}"
                );
            }
            7 => {
                assert_eq!(
                    locked.remove(b).is_some(),
                    optimistic.remove(b).is_some(),
                    "[{kind:?} seed={seed}] remove diverged at step {step}"
                );
            }
            8 => {
                if pins.len() < 4 && locked.contains(b) {
                    locked.pin(b);
                    optimistic.pin(b);
                    pins.push(b);
                }
            }
            _ => {
                if let Some(p) = pins.pop() {
                    locked.unpin(p);
                    optimistic.unpin(p);
                }
            }
        }
    }
    optimistic.flush_touches();

    // Single-threaded, so even the stats must agree exactly: the
    // optimistic hit/miss atomics merge into the same totals the locked
    // shard counters produce.
    let ls = locked.stats();
    let os = optimistic.stats();
    assert_eq!(
        (ls.inserts, ls.evictions, ls.rejected, ls.mem_hits, ls.misses),
        (os.inserts, os.evictions, os.rejected, os.mem_hits, os.misses),
        "[{kind:?} seed={seed}] stats diverged"
    );

    let mut l = locked.cached_blocks();
    let mut o = optimistic.cached_blocks();
    l.sort_unstable();
    o.sort_unstable();
    assert_eq!(l, o, "[{kind:?} seed={seed}] final contents diverged");
    assert_eq!(locked.used(), optimistic.used(), "[{kind:?} seed={seed}]");
    locked.check_invariants().expect("locked invariants");
    optimistic.check_invariants().expect("optimistic invariants");
}

/// Deterministic single-thread check of the all-or-nothing contract and
/// rollback path (no concurrency, exact assertions).
#[test]
fn pin_group_rolls_back_cleanly_on_missing_member() {
    let store = ShardedStore::new(64 * BLOCK_BYTES, PolicyKind::Lru, 4);
    let a = BlockId::new(DatasetId(0), 1);
    let b = BlockId::new(DatasetId(0), 2);
    let missing = BlockId::new(DatasetId(0), 3);
    store.insert(a, payload());
    store.insert(b, payload());

    assert!(!store.pin_group(GroupId(1), &[a, b, missing]));
    assert_eq!(store.pinned_count(), 0, "partial pins after failed group pin");
    assert_eq!(store.pinned_group_count(), 0);

    store.insert(missing, payload());
    assert!(store.pin_group(GroupId(1), &[a, b, missing]));
    assert_eq!(store.pinned_count(), 3);
    store.check_group_invariants().unwrap();
    store.unpin_group(GroupId(1));
    assert_eq!(store.pinned_count(), 0);
}

/// Spill-tier churn (DESIGN.md §5): the real demotion pipeline —
/// `insert_retaining` victims fed through `spill::demote_evicted` into a
/// shared `SpillManager` — hammered from several threads, with a restore
/// thread promoting residents back. Invariants under fire and at
/// quiescence:
///
/// 1. **Group-atomic tier transitions** — an offered set is admitted
///    whole or not at all, so a demoted block is never left half-in:
///    every `SpilledLocal` tier record has spill-resident accounting and
///    every spill resident left the memory store.
/// 2. **Byte-exact accounting across both tiers** — the memory store
///    re-sums exactly (existing check) and the spill manager's used
///    bytes re-sum exactly and never exceed the budget.
#[test]
fn concurrent_spill_churn_is_group_atomic_and_byte_exact() {
    let capacity = 24 * BLOCK_BYTES;
    let budget = 32 * BLOCK_BYTES;
    let store = Arc::new(ShardedStore::new(capacity, PolicyKind::Lerc, 4));
    let mgr = Arc::new(Mutex::new(SpillManager::new(SpillConfig::coordinated(budget))));
    // Groups of three over dataset 1; a third retired up front so the
    // dead-filter and dead-reclamation paths both run.
    let peers = {
        let mut t = WorkerPeerTracker::default();
        let groups: Vec<PeerGroup> = (0..128u64)
            .map(|g| PeerGroup {
                id: GroupId(g),
                task: TaskId(g),
                members: (0..3)
                    .map(|k| BlockId::new(DatasetId(1), g as u32 * 3 + k))
                    .collect(),
                output: BlockId::new(DatasetId(2), g as u32),
            })
            .collect();
        t.register(&groups, &[]);
        for g in 0..128u64 {
            if g % 3 == 0 {
                t.retire_task(TaskId(g));
            }
        }
        Arc::new(t)
    };
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();

    // Readers: concurrent gets + tier probes against the store while the
    // owner demotes (the engine's remote-read envelope — only the home
    // thread ever inserts, demotes or restores).
    for t in 0..2u64 {
        let store = store.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = SplitMix64::new(0x4EAD ^ t);
            while !stop.load(Ordering::Relaxed) {
                let b = BlockId::new(DatasetId(1), rng.next_below(384) as u32);
                let _ = store.get(b);
                let _ = store.tier_of(b);
                let _ = store.peek_bytes(b);
            }
        }));
    }

    // Monitor: spill accounting stays byte-exact and under budget at
    // every observable instant (its own lock serializes with offers, so
    // it can never see a half-admitted set — that is the atomicity).
    {
        let mgr = mgr.clone();
        let stop = stop.clone();
        joins.push(std::thread::spawn(move || {
            let mut checks = 0u64;
            while !stop.load(Ordering::Relaxed) {
                {
                    let m = mgr.lock().unwrap();
                    m.check_invariants().expect("spill invariants");
                    assert!(m.used() <= budget, "spill over budget");
                }
                checks += 1;
                std::thread::yield_now();
            }
            assert!(checks > 0);
        }));
    }

    // The owner thread (this one): insert/demote/restore churn through
    // the real engine pipeline.
    let mut rng = SplitMix64::new(0x5B1D);
    let data = payload();
    for round in 0..20_000u64 {
        let b = BlockId::new(DatasetId(1), rng.next_below(384) as u32);
        if round % 5 == 4 {
            // Restore path: release a spill resident and promote it.
            let released = mgr.lock().unwrap().release(b).is_some();
            if released {
                store.pin(b);
                let (outcome, payloads) = store.insert_retaining(b, data.clone());
                if !outcome.evicted.is_empty() {
                    let evicted: Vec<(BlockId, BlockData)> =
                        outcome.evicted.iter().copied().zip(payloads).collect();
                    let mut m = mgr.lock().unwrap();
                    let plan = demote_evicted(&store, &peers, &mut m, |_| true, evicted);
                    for (bb, _) in &plan.spilled {
                        store.set_tier(*bb, BlockTier::SpilledLocal);
                    }
                }
                store.set_tier(b, BlockTier::Memory);
                store.unpin(b);
            }
            continue;
        }
        // Demote path: skip blocks currently spilled (their producer
        // would have to restore or recompute first, as in the engines).
        if mgr.lock().unwrap().contains(b) {
            continue;
        }
        let (outcome, payloads) = store.insert_retaining(b, data.clone());
        if outcome.evicted.is_empty() {
            continue;
        }
        let evicted: Vec<(BlockId, BlockData)> =
            outcome.evicted.iter().copied().zip(payloads).collect();
        let mut m = mgr.lock().unwrap();
        let plan = demote_evicted(&store, &peers, &mut m, |_| true, evicted);
        // Group-atomic admission: every spilled block of the offered set
        // is resident in the manager and out of the memory store. The
        // caller publishes the SpilledLocal marks after persisting, as
        // the engines do.
        for (bb, _) in &plan.spilled {
            assert!(m.contains(*bb), "spilled {bb} missing from manager");
            assert!(!store.contains(*bb), "spilled {bb} still in memory");
            store.set_tier(*bb, BlockTier::SpilledLocal);
        }
        m.check_invariants().expect("spill accounting under churn");
        drop(m);
        if round % 512 == 0 {
            store.check_invariants().expect("store invariants under churn");
        }
    }

    stop.store(true, Ordering::Relaxed);
    for j in joins {
        j.join().expect("spill churn thread panicked");
    }

    // Quiescent cross-checks: the two tiers partition the blocks they
    // track, and both re-sum byte-exactly.
    store.check_invariants().expect("store invariants");
    let m = mgr.lock().unwrap();
    m.check_invariants().expect("final spill invariants");
    for b in m.resident_blocks() {
        assert!(!store.contains(b), "{b} resident in both tiers");
        assert_eq!(store.tier_of(b), Some(BlockTier::SpilledLocal), "{b} tier record");
    }
    for b in store.cached_blocks() {
        assert!(!m.contains(b), "{b} cached yet spill-resident");
    }
}

/// Capacity accounting survives remove-heavy single-thread churn with
/// replacement inserts of differing sizes (the classic double-count /
/// underflow traps).
#[test]
fn byte_accounting_stays_exact_under_replacement_churn() {
    let store = ShardedStore::new(128 * BLOCK_BYTES, PolicyKind::Lru, 4);
    let mut rng = SplitMix64::new(42);
    for _ in 0..20_000 {
        let b = BlockId::new(DatasetId(0), rng.next_below(512) as u32);
        match rng.next_below(4) {
            0 => {
                // Replacement with a different size must not double-count.
                let words = 8 + 8 * rng.next_below(8) as usize;
                store.insert(b, Arc::from(vec![1.0f32; words]));
            }
            1 => {
                let _ = store.remove(b);
            }
            _ => {
                let _ = store.get(b);
            }
        }
    }
    store.check_invariants().unwrap();
    let recounted: u64 = store
        .cached_blocks()
        .iter()
        .map(|&b| (store.get(b).expect("listed").len() * 4) as u64)
        .sum();
    assert_eq!(recounted, store.used());
}
