//! Property tests for the recovery closure over `workload::generators::
//! random_dag` seeds: the ancestor closure used by lineage recovery must
//! be **acyclic** (topologically executable given what survives) and
//! **minimal** (every synthesized task is individually necessary), and
//! must re-materialize every needed lost block.

use lerc_engine::common::fxhash::FxHashSet;
use lerc_engine::common::ids::BlockId;
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::dag::analysis::RefCounts;
use lerc_engine::dag::task::{enumerate_tasks, Task};
use lerc_engine::recovery::{recovery_closure, synthesize_recompute_tasks, LineageIndex};
use lerc_engine::scheduler::TaskTracker;
use lerc_engine::workload;

/// One randomized scenario: run a prefix of the job, lose a random subset
/// of the materialized transform blocks, derive the closure.
struct Scenario {
    tasks: Vec<Task>,
    lineage: LineageIndex,
    tracker: TaskTracker,
    lost: Vec<BlockId>,
    roots: Vec<BlockId>,
    closure: Vec<usize>,
}

fn build(seed: u64) -> Scenario {
    let mut rng = SplitMix64::new(seed ^ 0x5EED);
    let w = workload::random_dag(seed, 12, 1024);
    let dag = &w.dags[0];
    let mut next_id = 0u64;
    let tasks = enumerate_tasks(dag, &mut next_id);
    let lineage = LineageIndex::new(&tasks);
    let inputs: Vec<BlockId> =
        dag.inputs().flat_map(|d| d.blocks().collect::<Vec<_>>()).collect();
    let mut tracker = TaskTracker::new(tasks.clone(), inputs);
    let mut refcounts = RefCounts::from_tasks(&tasks);

    // Complete a random prefix of the ready order.
    let completions = rng.next_below(tasks.len() as u64 + 1) as usize;
    let mut done = 0;
    while done < completions {
        let Some(tid) = tracker.pop_ready() else {
            break;
        };
        let task = tracker.task(tid).unwrap().clone();
        refcounts.on_task_complete(&task);
        tracker.on_task_complete(tid).unwrap();
        done += 1;
    }

    // Lose a random subset of materialized transform blocks.
    let materialized: Vec<BlockId> = {
        let mut m: Vec<BlockId> = tracker
            .materialized_blocks()
            .filter(|&b| lineage.is_transform(b))
            .collect();
        m.sort();
        m
    };
    let lost: Vec<BlockId> =
        materialized.into_iter().filter(|_| rng.next_below(2) == 0).collect();
    for &b in &lost {
        tracker.on_block_lost(b);
    }
    let roots: Vec<BlockId> = lost
        .iter()
        .copied()
        .filter(|&b| {
            (lineage.is_sink(b) || refcounts.get(b) > 0) && !tracker.has_pending_producer(b)
        })
        .collect();
    let closure = recovery_closure(&lineage, &tasks, &roots, |b| {
        tracker.is_materialized(b) || tracker.has_pending_producer(b)
    });
    Scenario {
        tasks,
        lineage,
        tracker,
        lost,
        roots,
        closure,
    }
}

#[test]
fn closure_is_acyclic_and_topologically_executable() {
    for seed in 0..200u64 {
        let s = build(seed);
        // Walk the closure in order: every task's inputs must be either
        // currently available, an ingest block, or produced by an
        // *earlier* closure task — i.e. the closure is executable as
        // returned, hence acyclic.
        let mut will_have: FxHashSet<BlockId> = FxHashSet::default();
        for (pos, &ti) in s.closure.iter().enumerate() {
            for &input in &s.tasks[ti].inputs {
                let ok = !s.lineage.is_transform(input)
                    || s.tracker.is_materialized(input)
                    || s.tracker.has_pending_producer(input)
                    || will_have.contains(&input);
                assert!(
                    ok,
                    "seed {seed}: closure[{pos}] (task {ti}) needs {input} \
                     which nothing earlier provides"
                );
            }
            will_have.insert(s.tasks[ti].output);
        }
        // No duplicates (a cycle would force one).
        let unique: FxHashSet<usize> = s.closure.iter().copied().collect();
        assert_eq!(unique.len(), s.closure.len(), "seed {seed}");
    }
}

#[test]
fn closure_is_minimal_and_complete() {
    for seed in 0..200u64 {
        let s = build(seed);
        let root_set: FxHashSet<BlockId> = s.roots.iter().copied().collect();
        let outputs: FxHashSet<BlockId> = s.closure.iter().map(|&i| s.tasks[i].output).collect();
        // Complete: every needed root is re-produced.
        for &r in &s.roots {
            assert!(outputs.contains(&r), "seed {seed}: root {r} not recomputed");
        }
        // Minimal: every closure task's output is a root or feeds another
        // closure task — dropping any one task would break feasibility.
        for &ti in &s.closure {
            let out = s.tasks[ti].output;
            let needed_by_closure = s
                .closure
                .iter()
                .any(|&tj| tj != ti && s.tasks[tj].inputs.contains(&out));
            assert!(
                root_set.contains(&out) || needed_by_closure,
                "seed {seed}: task {ti} (output {out}) is not individually necessary"
            );
        }
        // Lost-but-unneeded blocks stay out: anything recomputed is
        // reachable from the roots by construction, so the closure never
        // exceeds the lost set's ancestor cone.
        for &ti in &s.closure {
            assert!(
                s.lost.contains(&s.tasks[ti].output),
                "seed {seed}: recomputing {} which was never lost",
                s.tasks[ti].output
            );
        }
    }
}

#[test]
fn synthesized_ids_are_fresh_and_shapes_preserved() {
    for seed in 0..50u64 {
        let s = build(seed);
        let mut next = s.tasks.len() as u64;
        let re = synthesize_recompute_tasks(&s.tasks, &s.closure, &mut next);
        assert_eq!(re.len(), s.closure.len());
        let mut seen = FxHashSet::default();
        for (r, &orig) in re.iter().zip(&s.closure) {
            assert!(r.id.0 >= s.tasks.len() as u64, "fresh id");
            assert!(seen.insert(r.id), "duplicate id");
            assert_eq!(r.output, s.tasks[orig].output);
            assert_eq!(r.inputs, s.tasks[orig].inputs);
            assert_eq!(r.kind, s.tasks[orig].kind);
        }
    }
}
