//! Control-plane equivalence and accounting tests: the home-routed,
//! batched mode must change message *counts*, never cache *decisions*.

use lerc_engine::Engine;
use lerc_engine::common::config::{CtrlPlane, DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::common::fxhash::FxHashMap;
use lerc_engine::common::ids::{BlockId, DatasetId};
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::driver::ctrl::DeltaCoalescer;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::scheduler::home_worker;
use lerc_engine::workload;
use std::time::Duration;

fn cfg(policy: PolicyKind, cache_blocks: u64, workers: u32, mode: CtrlPlane) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            bandwidth_bytes_per_sec: 500 * 1024 * 1024,
            seek_latency: Duration::from_micros(200),
            unthrottled: false,
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .ctrl_plane(mode)
        .build()
        .expect("valid config")
}

/// The tentpole's correctness bar: on the paper's zip geometry, Broadcast
/// and HomeRouted replay the *same* cache decisions — identical hits,
/// effective hits, disk reads, and eviction counts — for both DAG-aware
/// policies. Only the message accounting may differ.
#[test]
fn modes_replay_identical_decisions() {
    for (tenants, blocks, cache, workers) in [(3u32, 6u32, 4u64, 2u32), (4, 8, 6, 4)] {
        let w = workload::multi_tenant_zip(tenants, blocks, 4096);
        for policy in [PolicyKind::Lrc, PolicyKind::Lerc] {
            let b = ClusterEngine::new(cfg(policy, cache, workers, CtrlPlane::Broadcast))
                .run_workload(&w)
                .unwrap();
            let h = ClusterEngine::new(cfg(policy, cache, workers, CtrlPlane::HomeRouted))
                .run_workload(&w)
                .unwrap();
            let tag = format!("{} t={tenants} w={workers}", policy.name());
            assert_eq!(b.tasks_run, h.tasks_run, "{tag}");
            assert_eq!(b.access.accesses, h.access.accesses, "{tag}");
            assert_eq!(b.access.mem_hits, h.access.mem_hits, "{tag}");
            assert_eq!(b.access.effective_hits, h.access.effective_hits, "{tag}");
            assert_eq!(b.access.disk_reads, h.access.disk_reads, "{tag}");
            assert_eq!(b.evictions, h.evictions, "{tag}");
            // Same invalidation *events* too — routing changes deliveries,
            // not which groups break.
            assert_eq!(
                b.messages.invalidation_broadcasts, h.messages.invalidation_broadcasts,
                "{tag}"
            );
            assert_eq!(b.messages.eviction_reports, h.messages.eviction_reports, "{tag}");
        }
    }
}

/// Broadcast-mode accounting invariants (documented in `metrics`): every
/// invalidation is delivered to every worker — including the evicting
/// worker, whose replica transitions only on the master's authoritative
/// broadcast — and every completion fans one ref-count message to each
/// worker, plus the initial profile push.
#[test]
fn broadcast_accounting_counts_full_fanout() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    for workers in [2u32, 4] {
        let r = ClusterEngine::new(cfg(PolicyKind::Lerc, 3, workers, CtrlPlane::Broadcast))
            .run_workload(&w)
            .unwrap();
        let m = &r.messages;
        assert_eq!(
            m.broadcast_deliveries,
            m.invalidation_broadcasts * workers as u64,
            "w={workers}"
        );
        assert_eq!(
            m.refcount_updates,
            (r.tasks_run + 1) * workers as u64,
            "w={workers}: initial seed + one per completion, each × workers"
        );
    }
}

/// Home-routed accounting: deliveries per invalidation span 1..=workers
/// (only interested workers), and batched ref-count traffic is strictly
/// below the broadcast plane's `workers × (tasks + 1)`.
#[test]
fn home_routed_accounting_is_sublinear() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    for workers in [2u32, 4] {
        let r = ClusterEngine::new(cfg(PolicyKind::Lerc, 3, workers, CtrlPlane::HomeRouted))
            .run_workload(&w)
            .unwrap();
        let m = &r.messages;
        assert!(
            m.broadcast_deliveries <= m.invalidation_broadcasts * workers as u64,
            "w={workers}"
        );
        if m.invalidation_broadcasts > 0 {
            assert!(m.broadcast_deliveries >= m.invalidation_broadcasts, "w={workers}");
        }
        assert!(
            m.refcount_updates < (r.tasks_run + 1) * workers as u64,
            "w={workers}: {} routed msgs should undercut the broadcast fan-out {}",
            m.refcount_updates,
            (r.tasks_run + 1) * workers as u64
        );
        // Zip groups are worker-local (aligned placement), so deliveries
        // must not scale with the cluster: at most one per invalidation
        // here, regardless of worker count.
        assert_eq!(m.broadcast_deliveries, m.invalidation_broadcasts, "w={workers}");
    }
}

/// Stress the coalescer the way the driver uses it: interleave bursts of
/// absolute-count updates with flushes, replaying every flushed batch
/// into per-worker "policy" maps. After each flush (the driver's drain
/// boundary, always ahead of task dispatch), every block's policy-visible
/// count at its home worker must equal the newest staged count — batching
/// may drop intermediate values, never the final one.
#[test]
fn coalesced_deltas_are_never_stale_at_flush() {
    const WORKERS: u32 = 4;
    const BLOCKS: u32 = 200;
    let b = |i: u32| BlockId::new(DatasetId(0), i);
    let mut rng = SplitMix64::new(0xC0A1);
    let mut coalescer = DeltaCoalescer::new(WORKERS);
    let mut truth: FxHashMap<BlockId, u32> = FxHashMap::default();
    let mut policy_view: Vec<FxHashMap<BlockId, u32>> =
        (0..WORKERS).map(|_| FxHashMap::default()).collect();

    for _round in 0..2_000 {
        // A burst of 1–8 updates (a drain cycle's completions).
        let burst = 1 + (rng.next_u64() % 8) as usize;
        for _ in 0..burst {
            let block = b((rng.next_u64() % BLOCKS as u64) as u32);
            let count = (rng.next_u64() % 16) as u32;
            coalescer.stage(&[(block, count)]);
            truth.insert(block, count);
        }
        // Flush roughly every other cycle so staging spans cycles too.
        if rng.next_u64() % 2 == 0 {
            coalescer.flush(|w, batch| {
                for &(blk, count) in batch.iter() {
                    assert_eq!(home_worker(blk, WORKERS).0 as usize, w, "routed to wrong home");
                    policy_view[w].insert(blk, count);
                }
            });
            assert!(coalescer.is_empty(), "flush must drain everything staged");
            for (&blk, &count) in &truth {
                let w = home_worker(blk, WORKERS).0 as usize;
                assert_eq!(
                    policy_view[w].get(&blk),
                    Some(&count),
                    "stale count visible for {blk} after flush"
                );
            }
        }
    }
}

/// End-to-end pressure run on the routed plane: a bigger cluster, deep
/// eviction churn, and coalescing across many drain cycles must keep the
/// access accounting conserved and the run complete.
#[test]
fn home_routed_survives_pressure_with_conserved_accounting() {
    let w = workload::multi_tenant_zip(6, 8, 4096);
    for policy in [PolicyKind::Lrc, PolicyKind::Lerc] {
        let engine = ClusterEngine::new(cfg(policy, 3, 4, CtrlPlane::HomeRouted));
        let r = engine.run_workload(&w).unwrap();
        assert_eq!(r.tasks_run, 48, "{}", policy.name());
        let a = &r.access;
        assert_eq!(a.accesses, a.mem_hits + a.disk_reads, "{}", policy.name());
        assert!(r.evictions > 0, "{}", policy.name());
    }
}
