//! Spill-tier acceptance suite (DESIGN.md §5): opt-in behavior (spill
//! unset = byte-identical pre-spill reports), group-coordinated demotion
//! and pre-dispatch restore on the deterministic simulator, sim ≡
//! threaded agreement on the spilled/restored sets, sink bytes identical
//! with spill on/off in both engines and both control planes, and a
//! mid-job kill whose SpilledLocal losses are re-planned by recovery.

use lerc_engine::Engine;
use lerc_engine::common::config::{
    CtrlPlane, DiskConfig, EngineConfig, NetConfig, PolicyKind, SpillConfig,
};
use lerc_engine::common::ids::{BlockId, DatasetId};
use lerc_engine::common::tempdir::TempDir;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::metrics::{RunReport, TierStats};
use lerc_engine::recovery::FailurePlan;
use lerc_engine::sim::Simulator;
use lerc_engine::storage::DiskStore;
use lerc_engine::workload::{self, Workload};
use std::collections::HashSet;
use std::path::Path;
use std::time::Duration;

const BLOCK_LEN: usize = 4096;
const BLOCK_BYTES: u64 = (BLOCK_LEN as u64) * 4;

fn sim_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(BLOCK_LEN)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .build()
        .expect("valid config")
}

fn fast_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(BLOCK_LEN)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .build()
        .expect("valid config")
}

/// The sim ≡ threaded comparison config: modeled costs dominate real
/// scheduling noise (same recipe as `tests/sim_vs_engine.rs`).
fn compare_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(BLOCK_LEN)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            bandwidth_bytes_per_sec: 500 * 1024 * 1024,
            seek_latency: Duration::from_micros(200),
            unthrottled: false,
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .ctrl_plane(CtrlPlane::Broadcast)
        .build()
        .expect("valid config")
}

/// Conservation with the spill tier on: every access is served by exactly
/// one tier (restored hits are a *subset* of memory hits, reported
/// additionally), and restored effectiveness never breaks the
/// `mem_hits >= effective_hits` identity the waste metric relies on.
fn assert_conserved(r: &RunReport) {
    assert_eq!(
        r.access.accesses,
        r.access.mem_hits + r.tier.spill_reads + r.access.disk_reads,
        "tiered access accounting must cover every read"
    );
    assert!(
        r.tier.restored_hits <= r.access.mem_hits,
        "restored hits are a subset of memory hits"
    );
    assert!(
        r.access.effective_hits <= r.access.mem_hits,
        "Def. 1: effective hits are memory hits"
    );
}

fn sink_blocks(w: &Workload) -> Vec<BlockId> {
    let mut out = Vec::new();
    for dag in &w.dags {
        let parents: HashSet<DatasetId> =
            dag.datasets.iter().flat_map(|d| d.parents.iter().copied()).collect();
        for ds in dag.transforms() {
            if !parents.contains(&ds.id) {
                out.extend(ds.blocks());
            }
        }
    }
    out
}

fn read_store(dir: &Path) -> DiskStore {
    DiskStore::new(
        dir,
        DiskConfig {
            unthrottled: true,
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn spill_unset_reports_zero_tier_stats_in_both_engines() {
    let w = workload::double_map_zip_agg(8, BLOCK_LEN);
    let sim_engine = Simulator::from_engine_config(sim_cfg(PolicyKind::Lerc, 3, 2));
    let sim = sim_engine.run_workload(&w).unwrap();
    assert_eq!(sim.tier, TierStats::default(), "sim: spill off must be inert");
    let real = ClusterEngine::new(fast_cfg(PolicyKind::Lerc, 3, 2)).run_workload(&w).unwrap();
    assert_eq!(real.tier, TierStats::default(), "engine: spill off must be inert");
    // And with spill off the old conservation holds unchanged.
    assert_eq!(sim.access.accesses, sim.access.mem_hits + sim.access.disk_reads);
}

#[test]
fn coordinated_spill_demotes_and_restores_groups_on_the_sim() {
    let w = workload::double_map_zip_agg(12, BLOCK_LEN);
    let total = w.task_count() as u64;
    let mut cfg = sim_cfg(PolicyKind::Lerc, 3, 2);
    cfg.spill = Some(SpillConfig::coordinated(64 * BLOCK_BYTES));
    let r = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert_eq!(r.tasks_run, total + r.tier.spill_recompute_tasks);
    assert!(r.tier.spilled_blocks > 0, "tight memory must demote");
    assert!(
        r.tier.restored_blocks > 0,
        "pre-dispatch restores must fire: {:?}",
        r.tier
    );
    assert!(r.tier.groups_restored > 0);
    assert_eq!(r.tier.spilled_log.len() as u64, r.tier.spilled_blocks);
    assert_eq!(r.tier.restored_log.len() as u64, r.tier.restored_blocks);
    assert!(r.tier.spilled_bytes >= r.tier.spilled_blocks * BLOCK_BYTES / 2);
    assert_conserved(&r);
    // A generous budget admits every live-group victim: no recomputes.
    assert_eq!(r.tier.spill_recompute_tasks, 0, "budget was generous");
}

#[test]
fn zero_budget_recomputes_needed_drops_and_still_completes() {
    let w = workload::double_map_zip_agg(10, BLOCK_LEN);
    let total = w.task_count() as u64;
    let mut cfg = sim_cfg(PolicyKind::Lerc, 3, 2);
    cfg.spill = Some(SpillConfig::coordinated(0));
    let r = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert!(
        r.tier.spill_recompute_tasks > 0,
        "a zero budget is the pure-recompute baseline: {:?}",
        r.tier
    );
    assert_eq!(r.tier.spilled_blocks, 0);
    assert_eq!(r.tasks_run, total + r.tier.spill_recompute_tasks);
    assert_conserved(&r);
}

#[test]
fn sim_spill_decisions_are_deterministic() {
    let w = workload::double_map_zip_agg(10, BLOCK_LEN);
    let run = || {
        let mut cfg = sim_cfg(PolicyKind::Lerc, 3, 2);
        cfg.spill = Some(SpillConfig::coordinated(8 * BLOCK_BYTES));
        Simulator::from_engine_config(cfg).run_workload(&w).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.tier, b.tier);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.access.mem_hits, b.access.mem_hits);
}

#[test]
fn sim_and_engine_agree_on_spilled_and_restored_sets() {
    // All data placement in this DAG is co-located (index-aligned maps,
    // zip of aligned transforms) and LRU consumes no control-plane
    // state, so every eviction, demotion and restore is a deterministic
    // function of each worker's local op order — the threaded engine
    // replays the simulator's decisions exactly, including which blocks
    // demote and restore. (DAG-aware policies agree at the same
    // asynchronous-delivery band as the rest of the engine; see
    // tests/sim_vs_engine.rs and DESIGN.md §5.)
    let w = workload::double_map_zip_agg(10, BLOCK_LEN);
    for (policy, spill) in [
        (PolicyKind::Lru, SpillConfig::coordinated(32 * BLOCK_BYTES)),
        (PolicyKind::Lru, SpillConfig::per_block(32 * BLOCK_BYTES)),
    ] {
        let mut scfg = compare_cfg(policy, 3, 2);
        scfg.spill = Some(spill);
        let sim = Simulator::from_engine_config(scfg.clone()).run_workload(&w).unwrap();
        let real = ClusterEngine::new(scfg).run_workload(&w).unwrap();
        assert_eq!(sim.tasks_run, real.tasks_run, "{}", policy.name());
        assert_eq!(
            sim.tier.spilled_log,
            real.tier.spilled_log,
            "{}: spilled sets diverged",
            policy.name()
        );
        assert_eq!(
            sim.tier.restored_log,
            real.tier.restored_log,
            "{}: restored sets diverged",
            policy.name()
        );
        assert_eq!(sim.tier.spill_recompute_tasks, real.tier.spill_recompute_tasks);
        assert!(sim.tier.spilled_blocks > 0, "{}: scenario must spill", policy.name());
        assert_conserved(&sim);
        assert_conserved(&real);
    }
}

#[test]
fn sink_bytes_identical_with_spill_on_and_off_across_planes() {
    let w = workload::double_map_zip_agg(8, BLOCK_LEN);
    let baseline_dir = TempDir::new("spill-base").unwrap();
    let mut base_cfg = fast_cfg(PolicyKind::Lerc, 3, 2);
    base_cfg.disk_dir = Some(baseline_dir.path().to_path_buf());
    let base = ClusterEngine::new(base_cfg).run_workload(&w).unwrap();
    assert_eq!(base.tier, TierStats::default());
    let base_store = read_store(baseline_dir.path());

    for plane in [CtrlPlane::Broadcast, CtrlPlane::HomeRouted] {
        for spill in [
            SpillConfig::coordinated(6 * BLOCK_BYTES),
            SpillConfig::per_block(6 * BLOCK_BYTES),
            SpillConfig::coordinated(0),
        ] {
            let dir = TempDir::new("spill-on").unwrap();
            let mut cfg = fast_cfg(PolicyKind::Lerc, 3, 2);
            cfg.ctrl_plane = plane;
            cfg.disk_dir = Some(dir.path().to_path_buf());
            cfg.spill = Some(spill);
            let r = ClusterEngine::new(cfg).run_workload(&w).unwrap();
            assert_eq!(
                r.tasks_run,
                w.task_count() as u64 + r.tier.spill_recompute_tasks,
                "{}/{:?}",
                plane.name(),
                spill.mode
            );
            assert_conserved(&r);
            let store = read_store(dir.path());
            for b in sink_blocks(&w) {
                let (want, _) = base_store.read(b).unwrap();
                let (got, _) = store.read(b).unwrap();
                assert_eq!(want, got, "sink {b} differs ({}/{:?})", plane.name(), spill.mode);
            }
        }
    }
}

#[test]
fn mid_job_kill_replans_a_dead_workers_spilled_blocks() {
    // Kill worker 1 once the map stage is done: its spill area — full of
    // M/N blocks the pending zips still need — dies with it, and
    // recovery must re-plan them through lineage.
    let w = workload::double_map_zip_agg(12, BLOCK_LEN);
    let total = w.task_count() as u64;
    let mut cfg = sim_cfg(PolicyKind::Lerc, 3, 2);
    cfg.spill = Some(SpillConfig::coordinated(64 * BLOCK_BYTES));
    cfg.failures = FailurePlan::kill_at(1, total / 2);
    let r = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert_eq!(r.recovery.workers_killed, 1);
    assert!(
        r.recovery.blocks_lost_spilled > 0,
        "the dead worker held spilled blocks: {:?}",
        r.recovery
    );
    assert!(r.recovery.recompute_tasks > 0, "lost spilled blocks re-planned");
    assert_eq!(
        r.tasks_run,
        total + r.recovery.recompute_tasks + r.tier.spill_recompute_tasks
    );

    // Threaded engine: same plan, and the final sink bytes still match a
    // clean spill-off run.
    let clean_dir = TempDir::new("spill-kill-base").unwrap();
    let mut clean_cfg = fast_cfg(PolicyKind::Lerc, 3, 2);
    clean_cfg.disk_dir = Some(clean_dir.path().to_path_buf());
    ClusterEngine::new(clean_cfg).run_workload(&w).unwrap();

    let kill_dir = TempDir::new("spill-kill").unwrap();
    let mut kcfg = fast_cfg(PolicyKind::Lerc, 3, 2);
    kcfg.disk_dir = Some(kill_dir.path().to_path_buf());
    kcfg.spill = Some(SpillConfig::coordinated(64 * BLOCK_BYTES));
    kcfg.failures = FailurePlan::kill_at(1, total / 2);
    let kr = ClusterEngine::new(kcfg).run_workload(&w).unwrap();
    assert_eq!(kr.recovery.workers_killed, 1);
    assert!(kr.recovery.recompute_tasks > 0);
    let clean_store = read_store(clean_dir.path());
    let kill_store = read_store(kill_dir.path());
    for b in sink_blocks(&w) {
        let (want, _) = clean_store.read(b).unwrap();
        let (got, _) = kill_store.read(b).unwrap();
        assert_eq!(want, got, "sink {b} differs after kill with spill on");
    }
}

#[test]
fn read_through_serves_spilled_blocks_without_promotion() {
    use lerc_engine::common::config::{RestorePolicy, SpillMode};
    let w = workload::double_map_zip_agg(12, BLOCK_LEN);
    let mut cfg = sim_cfg(PolicyKind::Lerc, 3, 2);
    cfg.spill = Some(SpillConfig {
        budget_per_worker: 64 * BLOCK_BYTES,
        mode: SpillMode::Coordinated,
        restore: RestorePolicy::ReadThrough,
    });
    let r = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert_eq!(r.tier.restored_blocks, 0, "read-through never promotes");
    assert_eq!(r.tier.groups_restored, 0);
    assert!(r.tier.spill_reads > 0, "spilled inputs served in place: {:?}", r.tier);
    assert_conserved(&r);
    assert_eq!(r.tasks_run, w.task_count() as u64 + r.tier.spill_recompute_tasks);
}

#[test]
fn per_job_and_aggregate_accounting_hold_with_spill_under_multijob() {
    use lerc_engine::workload::JobQueue;
    let mut q = JobQueue::default();
    q.name = "spill_multijob".into();
    q.submit(workload::double_map_zip_agg(8, BLOCK_LEN), 0, 0);
    let mut w2 = workload::random_dag_for_job(7, 1, 100, 8, BLOCK_LEN);
    w2.name = "second".into();
    q.submit(w2, 6, 1);
    let mut cfg = sim_cfg(PolicyKind::Lerc, 3, 2);
    cfg.spill = Some(SpillConfig::coordinated(8 * BLOCK_BYTES));
    let fleet = Engine::run(&Simulator::from_engine_config(cfg), &q).unwrap();
    assert_eq!(fleet.jobs.len(), 2);
    assert_conserved(&fleet.aggregate);
    // Every access is attributed to a job, whatever tier served it
    // (tier classification only moves reads between the hit buckets).
    let per_job: u64 = fleet.jobs.iter().map(|j| j.access.accesses).sum();
    assert_eq!(per_job, fleet.aggregate.access.accesses);
}
