//! Flight-recorder integration tests (DESIGN.md §8): sim ≡ threaded
//! logical event equivalence, Off-mode zero-cost (byte-identical
//! reports), ring-overflow accounting, and exact ineffective-hit
//! attribution reconciliation.

use lerc_engine::common::config::{
    DiskConfig, EngineConfig, MemConfig, NetConfig, PolicyKind, TimelineConfig,
};
use lerc_engine::driver::ClusterEngine;
use lerc_engine::metrics::RunReport;
use lerc_engine::recovery::TopologyPlan;
use lerc_engine::sim::Simulator;
use lerc_engine::trace::{ClockDomain, CriticalPathAnalysis, Rec, TraceConfig, TraceEvent};
use lerc_engine::workload::{self, Workload};
use lerc_engine::Engine;
use std::collections::BTreeMap;
use std::time::Duration;

fn cfg(policy: PolicyKind, cache_blocks: u64, workers: u32, trace: TraceConfig) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .mem(MemConfig {
            bandwidth_bytes_per_sec: u64::MAX / 2,
        })
        .trace(trace)
        .build()
        .expect("valid config")
}

fn run_sim(w: &Workload, c: EngineConfig) -> RunReport {
    Simulator::from_engine_config(c).run_workload(w).expect("sim run")
}

fn run_threaded(w: &Workload, c: EngineConfig) -> RunReport {
    ClusterEngine::new(c).run_workload(w).expect("threaded run")
}

/// Group a trace into (worker-track → logical-key sequence, driver-track
/// per-kind counts). Driver-side message batching is nondeterministic in
/// the threaded engine, so track 0 is compared by counts; worker tracks
/// must match as full ordered sequences.
fn shape(events: &[Rec]) -> (BTreeMap<u32, Vec<String>>, BTreeMap<&'static str, u64>) {
    let mut workers: BTreeMap<u32, Vec<String>> = BTreeMap::new();
    let mut driver: BTreeMap<&'static str, u64> = BTreeMap::new();
    for r in events {
        if r.track == 0 {
            *driver.entry(r.event.kind()).or_default() += 1;
        } else {
            workers.entry(r.track).or_default().push(r.event.logical_key());
        }
    }
    (workers, driver)
}

/// The tentpole contract: on a deterministic single-worker run with
/// ample cache (no spill, no failures, no broadcasts), the simulator and
/// the threaded engine emit IDENTICAL logical event sequences — equal
/// modulo timestamps.
#[test]
fn sim_and_threaded_emit_equal_logical_sequences() {
    let w = workload::zip_single(4, 4096);

    let (sim_trace, sim_rec) = TraceConfig::collect(1 << 14);
    run_sim(&w, cfg(PolicyKind::Lerc, 10_000, 1, sim_trace));
    let sim_events = sim_rec.take();
    assert_eq!(sim_rec.clock(), ClockDomain::Logical);
    assert_eq!(sim_rec.dropped(), 0);

    let (thr_trace, thr_rec) = TraceConfig::collect(1 << 14);
    run_threaded(&w, cfg(PolicyKind::Lerc, 10_000, 1, thr_trace));
    let thr_events = thr_rec.take();
    assert_eq!(thr_rec.clock(), ClockDomain::Wall);
    assert_eq!(thr_rec.dropped(), 0);

    assert!(!sim_events.is_empty() && !thr_events.is_empty());
    let (sim_workers, sim_driver) = shape(&sim_events);
    let (thr_workers, thr_driver) = shape(&thr_events);
    assert_eq!(
        sim_workers, thr_workers,
        "worker-track logical sequences diverged"
    );
    assert_eq!(sim_driver, thr_driver, "driver-track event counts diverged");

    // The run must cover the whole task lifecycle.
    for kind in ["task_admitted", "task_ready", "task_dispatched"] {
        assert_eq!(sim_driver.get(kind).copied(), Some(4), "{kind}");
    }
    let keys = sim_workers.get(&1).expect("worker 0 track");
    assert!(keys.iter().any(|k| k.starts_with("inputs_pinned ")));
    assert!(keys.iter().any(|k| k.starts_with("task_computed ")));
    assert!(keys.iter().any(|k| k.starts_with("task_published ")));
    assert!(keys.iter().any(|k| k.starts_with("block_inserted ")));
}

/// Tracing off must be provably zero-cost at the report level: the
/// simulator is deterministic, so an Off run and a Collect run must
/// produce byte-identical `RunReport`s (attribution and latency
/// histograms are always-on metrics, not trace-gated).
#[test]
fn trace_off_report_is_byte_identical() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let off = run_sim(&w, cfg(PolicyKind::Lerc, 4, 2, TraceConfig::Off));
    let (collect, rec) = TraceConfig::collect(1 << 14);
    let on = run_sim(&w, cfg(PolicyKind::Lerc, 4, 2, collect));
    assert!(!rec.take().is_empty(), "collect run recorded nothing");
    assert_eq!(format!("{off:?}"), format!("{on:?}"));
}

/// A full ring drops the newest events and counts them — it never blocks
/// and never corrupts the already-recorded prefix.
#[test]
fn ring_overflow_is_counted_never_blocking() {
    let (trace, rec) = TraceConfig::collect(4);
    rec.begin(2, ClockDomain::Logical);
    for i in 0..100u64 {
        trace.emit(1, Some(i), || TraceEvent::TaskReady {
            task: lerc_engine::common::ids::TaskId(i),
        });
    }
    // Unknown track: counted as dropped, not a panic.
    trace.emit(9, Some(0), || TraceEvent::TaskReady {
        task: lerc_engine::common::ids::TaskId(0),
    });
    assert_eq!(rec.dropped(), 96 + 1);
    let events = rec.take();
    assert_eq!(events.len(), 4);
    // The oldest events survive (drop-newest policy).
    assert_eq!(events[0].ts, 0);
    assert_eq!(events[3].ts, 3);
}

/// Off-mode emit is a single branch; the closure must never run.
#[test]
fn trace_off_never_constructs_events() {
    let trace = TraceConfig::Off;
    trace.emit(0, None, || -> TraceEvent {
        panic!("event constructed under TraceConfig::Off")
    });
}

fn check_attribution(r: &RunReport, engine: &str) {
    let expected = r.access.accesses - r.access.effective_hits;
    assert_eq!(
        r.attribution.total(),
        expected,
        "{engine}: attribution must cover every non-effective access \
         (accesses {} - effective {})",
        r.access.accesses,
        r.access.effective_hits
    );
    let blocking_sum: u64 = r.attribution.blocking.values().sum();
    assert_eq!(
        blocking_sum,
        r.attribution.total(),
        "{engine}: every attributed access names exactly one blocking block"
    );
    assert!(
        expected > 0,
        "{engine}: tight-memory run produced no ineffective hits to attribute"
    );
    assert!(!r.attribution.top_blocking(3).is_empty(), "{engine}");
}

/// Acceptance check: on `double_map_zip_agg` under tight memory the
/// attribution reconciles EXACTLY with AccessStats on both engines —
/// Σ causes == accesses − effective_hits, and every attributed access
/// names a blocking block.
#[test]
fn attribution_reconciles_with_access_stats() {
    let w = workload::generators::double_map_zip_agg(8, 4096);
    let sim = run_sim(&w, cfg(PolicyKind::Lru, 3, 2, TraceConfig::Off));
    check_attribution(&sim, "sim");
    let thr = run_threaded(&w, cfg(PolicyKind::Lru, 3, 2, TraceConfig::Off));
    check_attribution(&thr, "threaded");
}

/// Tentpole acceptance (DESIGN.md §10): the per-job JCT decomposition is
/// an EXACT identity on both engines — Σ segment nanos == analyzer JCT
/// for every job — and on the deterministic simulator the analyzer's JCT
/// equals the engine-reported `JobStats::jct` to the nanosecond.
#[test]
fn critical_path_identity_is_exact_on_both_engines() {
    // Ample cache: every task publishes promptly, so the analyzer's
    // completion point (last publish) is the engine's completion point.
    let (sim_trace, sim_rec) = TraceConfig::collect(1 << 14);
    let sim = Simulator::from_engine_config(cfg(PolicyKind::Lerc, 10_000, 2, sim_trace));
    let queue = lerc_engine::JobQueue::single(workload::multi_tenant_zip(3, 4, 4096));
    let fleet = Engine::run(&sim, &queue).expect("sim fleet run");
    let analysis = CriticalPathAnalysis::from_events(&sim_rec.take());
    assert!(!analysis.jobs.is_empty());
    assert!(analysis.identity_holds());
    for j in &analysis.jobs {
        assert_eq!(j.segment_total(), j.jct(), "job {}: Σ segments != JCT", j.job);
        assert!(!j.nodes.is_empty(), "job {}: empty critical path", j.job);
        let stats = fleet
            .jobs
            .iter()
            .find(|s| s.job == j.job)
            .expect("analyzed job missing from fleet report");
        assert_eq!(
            j.jct(),
            stats.jct.as_nanos() as u64,
            "job {}: analyzer JCT != engine JCT",
            j.job
        );
    }

    // Threaded engine: wall-clock times differ run to run, so the pin is
    // the structural identity, not exact values.
    let (thr_trace, thr_rec) = TraceConfig::collect(1 << 14);
    run_threaded(
        &workload::multi_tenant_zip(3, 4, 4096),
        cfg(PolicyKind::Lerc, 10_000, 2, thr_trace),
    );
    let thr = CriticalPathAnalysis::from_events(&thr_rec.take());
    assert!(!thr.jobs.is_empty());
    assert!(thr.identity_holds());
    for j in &thr.jobs {
        assert_eq!(j.segment_total(), j.jct(), "threaded job {}", j.job);
    }
}

/// Under tight memory the decomposition surfaces fetch segments split by
/// ineffective-hit cause, and the time-domain benefit map names blocking
/// blocks — while the Σ-segments identity still holds exactly.
#[test]
fn tight_memory_decomposition_charges_fetch_causes() {
    let w = workload::generators::double_map_zip_agg(8, 4096);
    let (trace, rec) = TraceConfig::collect(1 << 14);
    let report = run_sim(&w, cfg(PolicyKind::Lru, 3, 2, trace));
    assert!(report.access.accesses > report.access.effective_hits);
    let analysis = CriticalPathAnalysis::from_events(&rec.take());
    assert!(analysis.identity_holds());
    let causes: u64 = analysis
        .jobs
        .iter()
        .map(|j| j.kind_prefix_total("fetch_") - j.by_kind().get("fetch_mem").copied().unwrap_or(0))
        .sum();
    assert!(causes > 0, "no cause-attributed fetch time on a thrashing run");
    assert!(
        !analysis.top_benefit(3).is_empty(),
        "benefit map empty despite blocking blocks"
    );
}

/// Determinism pin: two identical sim runs must reconstruct IDENTICAL
/// critical paths — same node sequences, same segment decomposition.
#[test]
fn sim_critical_paths_are_deterministic_across_repeats() {
    let run = || {
        let w = workload::generators::double_map_zip_agg(8, 4096);
        let (trace, rec) = TraceConfig::collect(1 << 14);
        run_sim(&w, cfg(PolicyKind::Lru, 3, 2, trace));
        CriticalPathAnalysis::from_events(&rec.take())
    };
    let (a, b) = (run(), run());
    let nodes = |x: &CriticalPathAnalysis| {
        x.jobs.iter().map(|j| (j.job, j.nodes.clone())).collect::<Vec<_>>()
    };
    assert_eq!(nodes(&a), nodes(&b), "critical-path node sequences diverged");
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "segment decomposition diverged");
}

/// Regression (mid-run elastic join): trace tracks are sized to the
/// topology ceiling, not the starting fleet, so a `TopologyEvent::Join`
/// can never emit to an out-of-range track — zero drops on both engines,
/// and the joined worker's events land on its own track.
#[test]
fn joined_worker_track_is_in_range_on_both_engines() {
    let w = workload::generators::double_map_zip_agg(8, 4096);
    let total = w.task_count() as u64;
    let mk = |trace: TraceConfig| {
        let mut c = cfg(PolicyKind::Lru, 3, 2, trace);
        c.topology = TopologyPlan::join_at(2, total / 2);
        c
    };

    let (sim_trace, sim_rec) = TraceConfig::collect(1 << 14);
    let sim = run_sim(&w, mk(sim_trace));
    assert_eq!(sim.scale.workers_joined, 1);
    assert_eq!(sim_rec.dropped(), 0, "sim: join emitted to a dropped track");
    let sim_events = sim_rec.take();
    assert!(
        sim_events.iter().any(|r| r.track == 3),
        "sim: no events on the joined worker's track"
    );
    assert!(sim_events.iter().any(|r| matches!(r.event, TraceEvent::WorkerJoined { .. })));

    let (thr_trace, thr_rec) = TraceConfig::collect(1 << 14);
    let thr = run_threaded(&w, mk(thr_trace));
    assert_eq!(thr.scale.workers_joined, 1);
    assert_eq!(thr_rec.dropped(), 0, "threaded: join emitted to a dropped track");
    assert!(
        thr_rec.take().iter().any(|r| r.track == 3),
        "threaded: no events on the joined worker's track"
    );
}

/// The telemetry sampler (DESIGN.md §10): samples appear on both engines
/// when `EngineConfig::timeline` is set, sim timelines are deterministic
/// across repeats, and windowed ratios stay in [0, 1].
#[test]
fn timeline_sampler_populates_and_is_deterministic_on_sim() {
    let run = || {
        let w = workload::multi_tenant_zip(3, 6, 4096);
        let mut c = cfg(PolicyKind::Lerc, 10_000, 2, TraceConfig::Off);
        c.timeline = Some(TimelineConfig { every_dispatches: 4 });
        run_sim(&w, c)
    };
    let (a, b) = (run(), run());
    assert!(!a.timeline.is_empty(), "sampler produced no samples");
    assert_eq!(a.timeline, b.timeline, "sim timeline not deterministic");
    assert_eq!(a.timeline.worker_slots(), 2);
    let samples = &a.timeline.samples;
    assert!(samples.windows(2).all(|p| p[0].ts <= p[1].ts), "ts not monotone");
    assert!(samples.windows(2).all(|p| p[0].dispatched < p[1].dispatched));
    for r in a.timeline.window_effective_ratios() {
        assert!((0.0..=1.0).contains(&r), "ratio {r} out of range");
    }
    // The final sample is taken at teardown: it must see all the work.
    let last = samples.last().unwrap();
    assert_eq!(last.dispatched, a.tasks_run);
    assert_eq!(last.accesses, a.access.accesses);

    // Threaded engine: same knob, same shape (values are wall-clock).
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let mut c = cfg(PolicyKind::Lerc, 10_000, 2, TraceConfig::Off);
    c.timeline = Some(TimelineConfig { every_dispatches: 4 });
    let thr = run_threaded(&w, c);
    assert!(!thr.timeline.is_empty());
    assert_eq!(thr.timeline.worker_slots(), 2);
    assert_eq!(thr.timeline.samples.last().unwrap().accesses, thr.access.accesses);
}

/// Per-job latency histograms land in `JobStats` on both engines.
#[test]
fn job_latency_percentiles_are_populated() {
    let w = workload::multi_tenant_zip(3, 4, 4096);
    let sim = Simulator::from_engine_config(cfg(PolicyKind::Lerc, 1000, 2, TraceConfig::Off));
    let fleet = Engine::run(&sim, &lerc_engine::JobQueue::single(w)).expect("sim fleet run");
    assert!(!fleet.jobs.is_empty());
    for j in &fleet.jobs {
        assert_eq!(j.task_latency.count(), j.tasks_run, "job {}", j.job);
        assert!(j.task_latency.p50() > 0, "job {}", j.job);
        assert!(j.task_latency.p99() >= j.task_latency.p50());
    }
}
