//! End-to-end integration tests of the threaded cluster engine: every
//! workload × policy, numerics through the synthetic compute engine,
//! metric conservation laws, failure cases, and config knobs.

use lerc_engine::Engine;
use lerc_engine::common::config::{
    ComputeMode, DiskConfig, EngineConfig, NetConfig, PolicyKind,
};
use lerc_engine::common::ids::BlockId;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::workload::{self, Workload};
use std::time::Duration;

fn fast_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .mem(lerc_engine::common::config::MemConfig {
            bandwidth_bytes_per_sec: u64::MAX / 2,
        })
        .build()
        .expect("valid config")
}

fn run(w: &Workload, cfg: EngineConfig) -> lerc_engine::metrics::RunReport {
    ClusterEngine::new(cfg).run_workload(w).expect("engine run")
}

#[test]
fn every_workload_completes_under_every_policy() {
    let workloads = vec![
        workload::zip_single(6, 4096),
        workload::multi_tenant_zip(3, 4, 4096),
        workload::two_stage_zip_agg(6, 4096),
        workload::cross_validation(3, 4, 4096),
        workload::mixed_tenants(3, 4, 4096),
        workload::shared_input(2, 4, 4096),
        workload::etl_pipeline(4, 4096),
    ];
    for w in &workloads {
        let expect = w.task_count() as u64;
        for policy in PolicyKind::ALL {
            let r = run(w, fast_cfg(policy, 4, 2));
            assert_eq!(r.tasks_run, expect, "{} under {}", w.name, policy.name());
        }
    }
}

/// Conservation: accesses == mem_hits + disk_reads; effective ≤ mem hits.
#[test]
fn access_accounting_conserves() {
    for policy in PolicyKind::ALL {
        let w = workload::multi_tenant_zip(4, 6, 4096);
        let r = run(&w, fast_cfg(policy, 5, 3));
        let a = &r.access;
        assert_eq!(
            a.accesses,
            a.mem_hits + a.disk_reads,
            "{}: access split broken",
            policy.name()
        );
        assert!(a.effective_hits <= a.mem_hits, "{}", policy.name());
        assert!(a.remote_hits <= a.mem_hits, "{}", policy.name());
        // Every task accesses exactly its arity (zip = 2).
        assert_eq!(a.accesses, 2 * r.tasks_run);
    }
}

/// With cache larger than everything, every policy behaves identically:
/// all hits, all effective, no evictions.
#[test]
fn infinite_cache_is_policy_invariant() {
    let w = workload::multi_tenant_zip(3, 5, 4096);
    for policy in PolicyKind::ALL {
        let r = run(&w, fast_cfg(policy, 10_000, 2));
        assert_eq!(r.hit_ratio(), 1.0, "{}", policy.name());
        assert_eq!(r.effective_hit_ratio(), 1.0, "{}", policy.name());
        assert_eq!(r.evictions, 0, "{}", policy.name());
        assert_eq!(r.messages.peer_protocol_total(), 0, "{}", policy.name());
    }
}

/// Zero-size cache: everything reads from disk, nothing is effective,
/// and the engine still completes.
#[test]
fn zero_cache_still_completes() {
    let w = workload::zip_single(4, 4096);
    for policy in [PolicyKind::Lru, PolicyKind::Lerc] {
        let r = run(&w, fast_cfg(policy, 0, 2));
        assert_eq!(r.tasks_run, 4);
        assert_eq!(r.access.mem_hits, 0, "{}", policy.name());
        assert_eq!(r.effective_hit_ratio(), 0.0);
    }
}

/// Decision metrics are exactly reproducible for protocol-free policies
/// (no async traffic). Peer-aware policies are honestly asynchronous —
/// invalidation broadcasts race with ingest, as on a real cluster — so
/// only task counts are exact; the deterministic twin for LERC is the
/// simulator (see sim_vs_engine.rs).
#[test]
fn decision_metrics_are_reproducible() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    for policy in [PolicyKind::Lru, PolicyKind::Lrc] {
        let r1 = run(&w, fast_cfg(policy, 4, 2));
        let r2 = run(&w, fast_cfg(policy, 4, 2));
        assert_eq!(r1.access.mem_hits, r2.access.mem_hits, "{}", policy.name());
        assert_eq!(
            r1.access.effective_hits, r2.access.effective_hits,
            "{}",
            policy.name()
        );
        assert_eq!(r1.tasks_run, r2.tasks_run);
    }
    let r1 = run(&w, fast_cfg(PolicyKind::Lerc, 4, 2));
    let r2 = run(&w, fast_cfg(PolicyKind::Lerc, 4, 2));
    assert_eq!(r1.tasks_run, r2.tasks_run);
    assert_eq!(r1.access.accesses, r2.access.accesses);
}

/// Paper ordering end-to-end on the threaded engine. Disk costs must
/// dominate real scheduling/compute overhead for the modeled makespan to
/// rank policies, so use HDD-class latencies (test runs ~2s).
#[test]
fn paper_ordering_on_threaded_engine() {
    let w = workload::multi_tenant_zip(4, 8, 65536);
    let mk = |policy| {
        let mut cfg = fast_cfg(policy, 11, 2); // ~2/3 of 16 blocks/worker
        cfg.block_len = 65536;
        cfg.cache_capacity_per_worker = 11 * 65536 * 4;
        cfg.disk = DiskConfig {
            bandwidth_bytes_per_sec: 120 * 1024 * 1024,
            seek_latency: Duration::from_millis(4),
            unthrottled: false,
        };
        cfg.time_scale = 1.0;
        cfg
    };
    let lru = run(&w, mk(PolicyKind::Lru));
    let lerc = run(&w, mk(PolicyKind::Lerc));
    assert!(
        lerc.effective_hit_ratio() > lru.effective_hit_ratio(),
        "LERC {} vs LRU {}",
        lerc.effective_hit_ratio(),
        lru.effective_hit_ratio()
    );
    assert!(
        lerc.compute_makespan < lru.compute_makespan,
        "LERC {:?} vs LRU {:?}",
        lerc.compute_makespan,
        lru.compute_makespan
    );
}

/// Fig-3-style pinned cache: pinned blocks are never evicted, non-listed
/// blocks are never cached.
#[test]
fn pinned_cache_controls_contents() {
    let mut w = workload::zip_single(6, 4096);
    let a = w.dags[0].datasets[0].id;
    let bds = w.dags[0].datasets[1].id;
    let pinned: Vec<BlockId> = (0..3).map(|i| BlockId::new(a, i)).collect();
    w.pinned_cache = Some(pinned);
    let r = run(&w, fast_cfg(PolicyKind::Lru, 2, 2)); // tiny cap, pins exempt
    // Accesses: 12 total; hits only on pinned A0..A2 (B never cached).
    assert_eq!(r.access.mem_hits, 3);
    assert_eq!(r.access.effective_hits, 0, "no pair is complete");
    let _ = bds;
}

/// Outputs are persisted: a two-stage job must read stage-1 outputs
/// (from cache or disk) without error even under heavy eviction.
#[test]
fn two_stage_survives_output_eviction() {
    let w = workload::two_stage_zip_agg(8, 4096);
    let r = run(&w, fast_cfg(PolicyKind::Lru, 1, 2));
    assert_eq!(r.tasks_run, 16);
    assert!(r.access.disk_reads > 0);
}

/// Missing artifacts directory fails fast with a typed error.
#[test]
fn missing_artifacts_error_is_clean() {
    let mut cfg = fast_cfg(PolicyKind::Lru, 4, 1);
    cfg.compute = ComputeMode::Pjrt {
        artifacts_dir: "/nonexistent/path".into(),
    };
    let w = workload::zip_single(2, 4096);
    let err = ClusterEngine::new(cfg).run_workload(&w);
    assert!(err.is_err());
}

/// Workload validation rejects corrupt ingest orders.
#[test]
fn workload_validation_rejects_bad_ingest() {
    let mut w = workload::zip_single(4, 4096);
    w.ingest_order.pop();
    assert!(ClusterEngine::new(fast_cfg(PolicyKind::Lru, 4, 1))
        .run_workload(&w)
        .is_err());
    let mut w2 = workload::zip_single(4, 4096);
    let dup = w2.ingest_order[0];
    w2.ingest_order.push(dup);
    assert!(ClusterEngine::new(fast_cfg(PolicyKind::Lru, 4, 1))
        .run_workload(&w2)
        .is_err());
}

/// Remote reads happen for coalesce (adjacent indices live on different
/// workers) and are counted.
#[test]
fn coalesce_exercises_remote_reads() {
    let mut dags = workload::mixed_tenants(3, 4, 4096);
    dags.name = "coalesce-heavy".into();
    let r = run(&dags, fast_cfg(PolicyKind::Lru, 1000, 4));
    assert!(
        r.access.remote_hits > 0,
        "expected remote memory hits from coalesce tasks"
    );
}

/// Three-stage ETL (map -> zip -> aggregate) through the REAL XLA path:
/// all task kinds compose end to end with genuine compute.
#[test]
fn etl_pipeline_runs_on_pjrt() {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.tsv").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = fast_cfg(PolicyKind::Lerc, 100, 2);
    cfg.compute = ComputeMode::Pjrt {
        artifacts_dir: artifacts,
    };
    let w = workload::etl_pipeline(4, 4096);
    let r = ClusterEngine::new(cfg).run_workload(&w).unwrap();
    assert_eq!(r.tasks_run, 12); // 4 map + 4 zip + 4 agg
    assert_eq!(r.hit_ratio(), 1.0); // big cache: all stage outputs hit
}

/// Job completion times are recorded for every tenant.
#[test]
fn per_job_times_recorded() {
    let w = workload::multi_tenant_zip(5, 3, 4096);
    let r = run(&w, fast_cfg(PolicyKind::Lerc, 100, 2));
    assert_eq!(r.job_times.len(), 5);
}
