//! The simulator and the threaded engine share every policy-relevant
//! component; on barrier-phased workloads their *decision* metrics (hit
//! counts, effective hits, task counts) must agree, and their modeled
//! makespans must land within a tolerance band.

use lerc_engine::Engine;
use lerc_engine::common::config::{DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::driver::ClusterEngine;
use lerc_engine::sim::{SimConfig, Simulator};
use lerc_engine::workload;
use std::time::Duration;

fn cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            bandwidth_bytes_per_sec: 500 * 1024 * 1024,
            seek_latency: Duration::from_micros(200),
            unthrottled: false,
        })
        // Zero latency keeps both engines' protocol timing aligned so
        // decision metrics are comparable.
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .build()
        .expect("valid config")
}

/// On single-stage workloads with a full ingest barrier and per-worker
/// FIFO dispatch, the two engines replay identical cache decisions for
/// protocol-free policies. LERC's broadcasts are asynchronous in the
/// threaded engine (they race with ingest, as on a real cluster), so its
/// metrics agree within a band rather than exactly.
#[test]
fn decision_metrics_match_on_zip_workloads() {
    for (tenants, blocks, cache) in [(1u32, 8u32, 6u64), (3, 6, 4), (4, 8, 10)] {
        let w = workload::multi_tenant_zip(tenants, blocks, 4096);
        for policy in [PolicyKind::Lru, PolicyKind::Lrc] {
            let sim = Simulator::from_engine_config(cfg(policy, cache, 2))
                .run_workload(&w)
                .unwrap();
            let real = ClusterEngine::new(cfg(policy, cache, 2)).run_workload(&w).unwrap();
            assert_eq!(sim.tasks_run, real.tasks_run, "{}", policy.name());
            assert_eq!(
                sim.access.accesses, real.access.accesses,
                "{} t={tenants} b={blocks}",
                policy.name()
            );
            assert_eq!(
                sim.access.mem_hits, real.access.mem_hits,
                "{} t={tenants} b={blocks} c={cache}",
                policy.name()
            );
            assert_eq!(
                sim.access.effective_hits, real.access.effective_hits,
                "{} t={tenants} b={blocks} c={cache}",
                policy.name()
            );
        }
        // LERC: band comparison (async protocol timing differs).
        let sim = Simulator::from_engine_config(cfg(PolicyKind::Lerc, cache, 2))
            .run_workload(&w)
            .unwrap();
        let real = ClusterEngine::new(cfg(PolicyKind::Lerc, cache, 2))
            .run_workload(&w)
            .unwrap();
        assert_eq!(sim.tasks_run, real.tasks_run);
        assert_eq!(sim.access.accesses, real.access.accesses);
        let tol = (sim.access.accesses as f64 * 0.25).ceil() as i64;
        let dh = sim.access.mem_hits as i64 - real.access.mem_hits as i64;
        let de = sim.access.effective_hits as i64 - real.access.effective_hits as i64;
        assert!(
            dh.abs() <= tol,
            "LERC hits diverged: sim {} real {}",
            sim.access.mem_hits,
            real.access.mem_hits
        );
        assert!(
            de.abs() <= tol,
            "LERC effective diverged: sim {} real {}",
            sim.access.effective_hits,
            real.access.effective_hits
        );
    }
}

/// Modeled makespans agree within a tolerance band when modeled I/O
/// dominates (the threaded engine pays real scheduling/compute overhead
/// on top of the model, which matters only at micro scales).
#[test]
fn makespans_agree_within_band() {
    // Small real payloads (debug-build compute/fs work stays cheap) with
    // a slow modeled disk so the model dominates both engines' time.
    let w = workload::multi_tenant_zip(3, 8, 4096);
    let mk = |policy| {
        EngineConfig::builder()
            .num_workers(2)
            .block_len(4096)
            .cache_blocks(8)
            .policy(policy)
            .disk(DiskConfig {
                bandwidth_bytes_per_sec: 4 * 1024 * 1024,
                seek_latency: Duration::from_millis(5),
                unthrottled: false,
            })
            .net(NetConfig {
                per_message_latency: Duration::ZERO,
            })
            .build()
            .expect("valid config")
    };
    for policy in [PolicyKind::Lru, PolicyKind::Lerc] {
        let sim = Simulator::from_engine_config(mk(policy)).run_workload(&w).unwrap();
        let real = ClusterEngine::new(mk(policy)).run_workload(&w).unwrap();
        let s = sim.makespan.as_secs_f64();
        let r = real.makespan.as_secs_f64();
        assert!(
            r >= 0.5 * s && r <= 3.0 * s,
            "{}: sim {s:.4}s vs real {r:.4}s out of band",
            policy.name()
        );
    }
}

/// The simulator's LERC protocol traffic matches the threaded engine's
/// (same broadcasts, since decisions replay identically).
#[test]
fn peer_traffic_matches() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let sim = Simulator::from_engine_config(cfg(PolicyKind::Lerc, 4, 2))
        .run_workload(&w)
        .unwrap();
    let real = ClusterEngine::new(cfg(PolicyKind::Lerc, 4, 2)).run_workload(&w).unwrap();
    assert_eq!(
        sim.messages.invalidation_broadcasts,
        real.messages.invalidation_broadcasts
    );
    assert_eq!(sim.messages.eviction_reports, real.messages.eviction_reports);
}

/// Sim determinism across SimConfig compute-cost settings: metrics stay
/// fixed, only time shifts.
#[test]
fn compute_model_shifts_time_not_decisions() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let base = SimConfig::new(cfg(PolicyKind::Lerc, 4, 2));
    let mut slow = SimConfig::new(cfg(PolicyKind::Lerc, 4, 2));
    slow.compute_nanos_per_elem = 100.0;
    let r1 = Simulator::new(base).run_workload(&w).unwrap();
    let r2 = Simulator::new(slow).run_workload(&w).unwrap();
    assert_eq!(r1.access.mem_hits, r2.access.mem_hits);
    assert_eq!(r1.access.effective_hits, r2.access.effective_hits);
    assert!(r2.makespan > r1.makespan);
}
