//! Property tests for the spill tier over `workload::random_dag` seeds
//! with random budgets (hand-rolled generators, as in
//! `proptest_lineage.rs`):
//!
//! * **Group-atomic tier transitions** — random demotion offers against
//!   the real `SpillManager` are admitted whole or not at all, and the
//!   byte accounting re-sums exactly under arbitrary offer/release
//!   interleavings.
//! * **Observed inputs are byte-identical to the no-spill run** — for
//!   random DAGs and budgets, every sink block the spill-enabled
//!   threaded engine leaves behind matches the spill-less run bit for
//!   bit (restores and lineage recomputes reproduce exactly the bytes
//!   the tasks would have read anyway), and the simulator completes the
//!   same task set deterministically.

use lerc_engine::Engine;
use lerc_engine::common::config::{
    DiskConfig, EngineConfig, NetConfig, PolicyKind, SpillConfig,
};
use lerc_engine::common::ids::{BlockId, DatasetId};
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::common::tempdir::TempDir;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::sim::Simulator;
use lerc_engine::spill::SpillManager;
use lerc_engine::storage::DiskStore;
use lerc_engine::workload::{self, Workload};
use std::collections::HashSet;
use std::time::Duration;

const BLOCK_LEN: usize = 1024;
const BLOCK_BYTES: u64 = (BLOCK_LEN as u64) * 4;

fn fast_cfg(cache_blocks: u64) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(2)
        .block_len(BLOCK_LEN)
        .cache_blocks(cache_blocks)
        .policy(PolicyKind::Lerc)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .build()
        .expect("valid config")
}

fn sink_blocks(w: &Workload) -> Vec<BlockId> {
    let mut out = Vec::new();
    for dag in &w.dags {
        let parents: HashSet<DatasetId> =
            dag.datasets.iter().flat_map(|d| d.parents.iter().copied()).collect();
        for ds in dag.transforms() {
            if !parents.contains(&ds.id) {
                out.extend(ds.blocks());
            }
        }
    }
    out
}

#[test]
fn random_offers_are_group_atomic_with_exact_accounting() {
    for seed in 0..200u64 {
        let mut rng = SplitMix64::new(seed ^ 0x0FFE_12);
        let budget = rng.next_below(64) * 100;
        let mut mgr = SpillManager::new(if seed % 2 == 0 {
            SpillConfig::coordinated(budget)
        } else {
            SpillConfig::per_block(budget)
        });
        let mut next_block = 0u32;
        let mut resident_model: Vec<(BlockId, u64)> = Vec::new();
        for _ in 0..200 {
            match rng.next_below(3) {
                0 | 1 => {
                    // Offer a random set of fresh blocks.
                    let n = 1 + rng.next_below(4) as usize;
                    let set: Vec<(BlockId, u64)> = (0..n)
                        .map(|_| {
                            let b = BlockId::new(DatasetId(1), next_block);
                            next_block += 1;
                            (b, 1 + rng.next_below(200))
                        })
                        .collect();
                    // Every third resident is "dead" for the reclaimer.
                    let out = mgr.offer(&set, |b| b.index % 3 == 0);
                    for e in &out.evicted {
                        resident_model.retain(|(b, _)| b != e);
                    }
                    if out.admitted {
                        // All-or-nothing: the whole set is resident.
                        for &(b, bytes) in &set {
                            assert!(mgr.contains(b), "admitted member {b} missing");
                            assert_eq!(mgr.bytes_of(b), Some(bytes));
                            resident_model.push((b, bytes));
                        }
                    } else {
                        for &(b, _) in &set {
                            assert!(!mgr.contains(b), "refused member {b} resident");
                        }
                    }
                }
                _ => {
                    if !resident_model.is_empty() {
                        let i = rng.next_below(resident_model.len() as u64) as usize;
                        let (b, bytes) = resident_model.remove(i);
                        assert_eq!(mgr.release(b), Some(bytes));
                    }
                }
            }
            mgr.check_invariants().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let model_used: u64 = resident_model.iter().map(|(_, by)| *by).sum();
            assert_eq!(mgr.used(), model_used, "seed {seed}: accounting drifted");
            assert!(mgr.used() <= budget, "seed {seed}: over budget");
        }
    }
}

#[test]
fn sim_completes_random_dags_under_random_budgets_deterministically() {
    for seed in 0..24u64 {
        let mut rng = SplitMix64::new(seed ^ 0x5B17_7EE5);
        let w = workload::random_dag(seed, 10, BLOCK_LEN);
        let total = w.task_count() as u64;
        let budget = rng.next_below(16) * BLOCK_BYTES;
        let spill = if seed % 2 == 0 {
            SpillConfig::coordinated(budget)
        } else {
            SpillConfig::per_block(budget)
        };
        let run = || {
            let mut cfg = fast_cfg(2);
            cfg.spill = Some(spill);
            Simulator::from_engine_config(cfg).run_workload(&w).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(
            a.tasks_run,
            total + a.tier.spill_recompute_tasks,
            "seed {seed}: originals plus exactly the spill recomputes"
        );
        assert_eq!(
            a.access.accesses,
            a.access.mem_hits + a.tier.spill_reads + a.access.disk_reads,
            "seed {seed}: tiered conservation"
        );
        assert!(
            a.tier.restored_hits <= a.access.mem_hits,
            "seed {seed}: restored hits are a subset of memory hits"
        );
        assert_eq!(a.tier, b.tier, "seed {seed}: decisions must replay");
        assert_eq!(a.makespan, b.makespan, "seed {seed}");
    }
}

#[test]
fn observed_inputs_match_the_no_spill_run_byte_for_byte() {
    for seed in [3u64, 11, 29, 41, 67, 97] {
        let w = workload::random_dag(seed, 8, BLOCK_LEN);
        let mut rng = SplitMix64::new(seed ^ 0xB17E5);
        let budget = rng.next_below(8) * BLOCK_BYTES;

        let base_dir = TempDir::new("prop-spill-base").unwrap();
        let mut base_cfg = fast_cfg(2);
        base_cfg.disk_dir = Some(base_dir.path().to_path_buf());
        ClusterEngine::new(base_cfg).run_workload(&w).unwrap();

        let spill_dir = TempDir::new("prop-spill-on").unwrap();
        let mut cfg = fast_cfg(2);
        cfg.disk_dir = Some(spill_dir.path().to_path_buf());
        cfg.spill = Some(if seed % 2 == 0 {
            SpillConfig::coordinated(budget)
        } else {
            SpillConfig::per_block(budget)
        });
        let r = ClusterEngine::new(cfg).run_workload(&w).unwrap();
        assert_eq!(r.tasks_run, w.task_count() as u64 + r.tier.spill_recompute_tasks);

        let read = |dir: &std::path::Path| {
            DiskStore::new(
                dir,
                DiskConfig {
                    unthrottled: true,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let base_store = read(base_dir.path());
        let spill_store = read(spill_dir.path());
        for b in sink_blocks(&w) {
            let (want, _) = base_store.read(b).unwrap();
            let (got, _) = spill_store.read(b).unwrap();
            assert_eq!(want, got, "seed {seed}: sink {b} diverged under spill");
        }
    }
}
