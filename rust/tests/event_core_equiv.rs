//! ISSUE-6 equivalence suite: the discrete-event simulator core must be
//! observably identical to the synchronous-heap sim it replaced — same
//! spilled/restored/recovered block sets, same per-job outputs, same
//! decision metrics — across the spill, recovery, and multi-job
//! geometries, through every public entry point of the unified
//! [`Engine`] trait. Plus the two behavioral pins this PR adds on top:
//! `time_scale` divides back out of every reported duration, and the
//! opt-in fair-share network model shifts *time* without shifting
//! *structure*.

use lerc_engine::Engine;
use lerc_engine::common::config::{
    CtrlPlane, DiskConfig, EngineConfig, LinkConfig, NetConfig, NetModel, PolicyKind, SpillConfig,
};
use lerc_engine::common::ids::{BlockId, DatasetId};
use lerc_engine::common::tempdir::TempDir;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::metrics::NetStats;
use lerc_engine::recovery::FailurePlan;
use lerc_engine::sim::Simulator;
use lerc_engine::storage::DiskStore;
use lerc_engine::workload::{self, JobQueue, Workload};
use std::collections::HashSet;
use std::path::Path;
use std::time::Duration;

const BLOCK_LEN: usize = 1024;
const BLOCK_BYTES: u64 = (BLOCK_LEN as u64) * 4;

/// The sim ≡ threaded comparison recipe (tests/sim_vs_engine.rs): a
/// modeled disk fast enough for CI but dominant over real scheduling
/// noise, zero protocol latency, the broadcast plane in both engines.
fn compare_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(BLOCK_LEN)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            bandwidth_bytes_per_sec: 500 * 1024 * 1024,
            seek_latency: Duration::from_micros(200),
            unthrottled: false,
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .ctrl_plane(CtrlPlane::Broadcast)
        .build()
        .expect("valid config")
}

fn sink_blocks(w: &Workload) -> Vec<BlockId> {
    let mut out = Vec::new();
    for dag in &w.dags {
        let parents: HashSet<DatasetId> =
            dag.datasets.iter().flat_map(|d| d.parents.iter().copied()).collect();
        for ds in dag.transforms() {
            if !parents.contains(&ds.id) {
                out.extend(ds.blocks());
            }
        }
    }
    out
}

fn read_store(dir: &Path) -> DiskStore {
    DiskStore::new(
        dir,
        DiskConfig {
            unthrottled: true,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Spill geometries: the event core's demotion/restore decisions are a
/// deterministic replay — double runs produce byte-equal decision logs,
/// and the threaded engine reproduces the same sets (LRU placement is
/// co-located and protocol-free, so the sets match exactly).
#[test]
fn spill_sets_replay_exactly_across_geometries() {
    for (blocks, cache_blocks, budget_blocks) in [(8u32, 3u64, 32u64), (12, 4, 16)] {
        let w = workload::double_map_zip_agg(blocks, BLOCK_LEN);
        for spill in [
            SpillConfig::coordinated(budget_blocks * BLOCK_BYTES),
            SpillConfig::per_block(budget_blocks * BLOCK_BYTES),
        ] {
            let mut cfg = compare_cfg(PolicyKind::Lru, cache_blocks, 2);
            cfg.spill = Some(spill);
            let a = Simulator::from_engine_config(cfg.clone()).run_workload(&w).unwrap();
            let b = Simulator::from_engine_config(cfg.clone()).run_workload(&w).unwrap();
            assert_eq!(a.tier.spilled_log, b.tier.spilled_log, "b={blocks}: sim not deterministic");
            assert_eq!(a.tier.restored_log, b.tier.restored_log);
            assert_eq!(a.makespan, b.makespan);
            let real = ClusterEngine::new(cfg).run_workload(&w).unwrap();
            assert_eq!(a.tasks_run, real.tasks_run, "b={blocks}");
            assert_eq!(a.tier.spilled_log, real.tier.spilled_log, "b={blocks}: spilled diverged");
            assert_eq!(a.tier.restored_log, real.tier.restored_log, "b={blocks}: restored set");
            assert_eq!(a.tier.spill_recompute_tasks, real.tier.spill_recompute_tasks);
        }
    }
}

/// Recovery geometry: a seeded mid-job kill loses the same block sets
/// and synthesizes the same recompute closure on every run of the event
/// core, and the threaded engine's kill accounting conserves the same
/// totals.
#[test]
fn recovery_sets_replay_exactly() {
    let w = workload::double_map_zip_agg(10, BLOCK_LEN);
    let total = w.task_count() as u64;
    let mk = || {
        let mut cfg = compare_cfg(PolicyKind::Lru, 4, 2);
        cfg.failures = FailurePlan::kill_at(1, total / 2);
        cfg
    };
    let a = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    let b = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    assert_eq!(a.recovery, b.recovery, "recovered sets diverged between sim runs");
    assert_eq!(a.tasks_run, b.tasks_run);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.recovery.workers_killed, 1);
    assert!(a.recovery.recompute_tasks > 0, "kill must cost lineage recomputes");
    assert_eq!(a.tasks_run, total + a.recovery.recompute_tasks);

    let real = ClusterEngine::new(mk()).run_workload(&w).unwrap();
    assert_eq!(real.recovery.workers_killed, 1);
    assert_eq!(real.tasks_run, total + real.recovery.recompute_tasks);
}

/// The API-unification pin: all four public entry points — trait `run`
/// and `run_workload` on both engines — compute the same thing. The two
/// simulator entries and the two threaded entries must agree exactly
/// with each other, and (for a protocol-free DAG-aware policy on the
/// comparison recipe) the sim must replay the threaded engine's
/// decision metrics too.
#[test]
fn all_four_entry_points_agree() {
    let w = workload::multi_tenant_zip(2, 6, BLOCK_LEN);
    let q = JobQueue::single(w.clone());
    let cfg = || compare_cfg(PolicyKind::Lrc, 5, 2);

    let sim_fleet = Engine::run(&Simulator::from_engine_config(cfg()), &q).unwrap();
    let sim_run = Simulator::from_engine_config(cfg()).run_workload(&w).unwrap();
    let eng_fleet = Engine::run(&ClusterEngine::new(cfg()), &q).unwrap();
    let eng_run = ClusterEngine::new(cfg()).run_workload(&w).unwrap();

    // Same engine, different entry point: identical reports.
    assert_eq!(sim_fleet.aggregate.tasks_run, sim_run.tasks_run);
    assert_eq!(sim_fleet.aggregate.access.accesses, sim_run.access.accesses);
    assert_eq!(sim_fleet.aggregate.access.mem_hits, sim_run.access.mem_hits);
    assert_eq!(sim_fleet.aggregate.access.effective_hits, sim_run.access.effective_hits);
    assert_eq!(sim_fleet.aggregate.makespan, sim_run.makespan);
    assert_eq!(eng_fleet.aggregate.tasks_run, eng_run.tasks_run);
    assert_eq!(eng_fleet.aggregate.access.accesses, eng_run.access.accesses);
    assert_eq!(eng_fleet.aggregate.access.mem_hits, eng_run.access.mem_hits);
    assert_eq!(eng_fleet.aggregate.access.effective_hits, eng_run.access.effective_hits);

    // Sim vs threaded: decision equality on the comparison recipe.
    assert_eq!(sim_run.tasks_run, eng_run.tasks_run);
    assert_eq!(sim_run.access.accesses, eng_run.access.accesses);
    assert_eq!(sim_run.access.mem_hits, eng_run.access.mem_hits);
    assert_eq!(sim_run.access.effective_hits, eng_run.access.effective_hits);
}

/// Multi-job geometry through the trait: per-job task counts and sink
/// bytes are identical across repeated runs, and the event core agrees
/// with the threaded engine on what every job computed.
#[test]
fn multijob_sink_outputs_byte_identical_through_the_trait() {
    let queue = workload::multijob_zip_shared(2, 8, BLOCK_LEN, true, 4);
    let run = |dir: &Path| {
        let mut cfg = compare_cfg(PolicyKind::Lerc, 4, 2);
        cfg.disk_dir = Some(dir.to_path_buf());
        Engine::run(&ClusterEngine::new(cfg), &queue).unwrap()
    };
    let d1 = TempDir::new("equiv-mj-1").unwrap();
    let d2 = TempDir::new("equiv-mj-2").unwrap();
    let f1 = run(d1.path());
    let f2 = run(d2.path());
    let (s1, s2) = (read_store(d1.path()), read_store(d2.path()));
    for job in &queue.jobs {
        let id = job.workload.dags[0].job;
        let j1 = f1.job(id).expect("job stats");
        let j2 = f2.job(id).expect("job stats");
        assert_eq!(j1.tasks_run, j2.tasks_run, "{id}");
        for blk in sink_blocks(&job.workload) {
            let (x, _) = s1.read(blk).unwrap();
            let (y, _) = s2.read(blk).unwrap();
            assert_eq!(x, y, "sink {blk} of {id} diverged between runs");
        }
    }
    // The event core runs the same queue to the same per-job task counts.
    let sim_engine = Simulator::from_engine_config(compare_cfg(PolicyKind::Lerc, 4, 2));
    let sim = Engine::run(&sim_engine, &queue).unwrap();
    assert_eq!(sim.aggregate.tasks_run, f1.aggregate.tasks_run);
    for job in &queue.jobs {
        let id = job.workload.dags[0].job;
        assert_eq!(sim.job(id).unwrap().tasks_run, f1.job(id).unwrap().tasks_run, "{id}");
    }
}

/// The satellite-3 pin: `time_scale` compresses wall clock during the
/// run and divides back out of every reported duration — makespan and
/// per-job JCTs from a 4×-compressed run must land in the same modeled
/// band as the uncompressed run, not 4× lower.
#[test]
fn time_scale_divides_back_out_of_reported_times() {
    let queue = workload::multijob_zip_shared(2, 6, BLOCK_LEN, true, 3);
    let mk = |scale: f64| {
        EngineConfig::builder()
            .num_workers(2)
            .block_len(BLOCK_LEN)
            .cache_blocks(6)
            .policy(PolicyKind::Lru)
            // Slow modeled disk: modeled time dominates real scheduling
            // noise, so the two runs are comparable within a band.
            .disk(DiskConfig {
                bandwidth_bytes_per_sec: 4 * 1024 * 1024,
                seek_latency: Duration::from_millis(5),
                unthrottled: false,
            })
            .net(NetConfig {
                per_message_latency: Duration::ZERO,
            })
            .time_scale(scale)
            .build()
            .expect("valid config")
    };
    let full = Engine::run(&ClusterEngine::new(mk(1.0)), &queue).unwrap();
    let compressed = Engine::run(&ClusterEngine::new(mk(0.25)), &queue).unwrap();
    let band = |a: Duration, b: Duration, what: &str| {
        let (a, b) = (a.as_secs_f64(), b.as_secs_f64());
        assert!(
            b >= 0.4 * a && b <= 2.5 * a,
            "{what}: {a:.4}s at scale 1.0 vs {b:.4}s at 0.25 — time_scale leaked into reports"
        );
    };
    band(full.aggregate.makespan, compressed.aggregate.makespan, "makespan");
    band(full.mean_jct(), compressed.mean_jct(), "mean JCT");
    band(full.max_jct(), compressed.max_jct(), "max JCT");
}

/// Fair-share contention shifts time, not structure: with tiny links
/// every cache miss crawls through a contended ingress, so the makespan
/// grows and queueing delay appears — but the same tasks run and the
/// same accesses are served. Flat runs must keep a zeroed net block.
#[test]
fn fair_share_contention_slows_time_but_preserves_structure() {
    let w = workload::multi_tenant_zip(3, 8, BLOCK_LEN);
    let base = compare_cfg(PolicyKind::Lru, 2, 4);
    let mut fair = base.clone();
    fair.net_model = NetModel::FairShare(LinkConfig {
        ingress_bytes_per_sec: 2 * 1024 * 1024,
        egress_bytes_per_sec: 2 * 1024 * 1024,
    });
    let flat = Simulator::from_engine_config(base).run_workload(&w).unwrap();
    let contended = Simulator::from_engine_config(fair).run_workload(&w).unwrap();
    assert_eq!(flat.net, NetStats::default(), "flat mode must not model flows");
    assert_eq!(flat.tasks_run, contended.tasks_run);
    assert_eq!(flat.access.accesses, contended.access.accesses);
    assert!(contended.net.flows > 0, "fair-share run modeled no flows");
    assert!(
        contended.net.queueing_nanos > 0,
        "tiny links with zip reads must queue somewhere"
    );
    assert!(
        contended.makespan > flat.makespan,
        "contended makespan {:?} not above flat {:?}",
        contended.makespan,
        flat.makespan
    );
}
