//! Property test over random DAG pairs: running two jobs through one
//! online queue — random arrival gap, random priorities, interleaved
//! dispatch, shared cache — produces exactly the same per-job sink
//! bytes (and per-job task counts) as running each job alone. Cache
//! contention may reorder and slow things; it must never change WHAT a
//! job computes.

use lerc_engine::Engine;
use lerc_engine::common::config::{DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::common::ids::{BlockId, DatasetId};
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::common::tempdir::TempDir;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::storage::DiskStore;
use lerc_engine::workload::{self, JobQueue, Workload};
use std::collections::HashSet;
use std::path::Path;
use std::time::Duration;

fn fast_cfg(policy: PolicyKind, cache_blocks: u64) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(2)
        .block_len(1024)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .build()
        .expect("valid config")
}

fn sink_blocks(w: &Workload) -> Vec<BlockId> {
    let mut out = Vec::new();
    for dag in &w.dags {
        let parents: HashSet<DatasetId> =
            dag.datasets.iter().flat_map(|d| d.parents.iter().copied()).collect();
        for ds in dag.transforms() {
            if !parents.contains(&ds.id) {
                out.extend(ds.blocks());
            }
        }
    }
    out
}

fn read_store(dir: &Path) -> DiskStore {
    DiskStore::new(
        dir,
        DiskConfig {
            unthrottled: true,
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn interleaved_random_job_pairs_match_isolated_sink_bytes() {
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(seed ^ 0x0B5E55ED);
        let a = workload::random_dag_for_job(seed, 0, 64, 10, 1024);
        let b = workload::random_dag_for_job(seed + 1000, 1, 128, 10, 1024);
        let arrival = rng.next_below(12);
        let (pa, pb) = (rng.next_below(4) as u8, rng.next_below(4) as u8);
        let mut queue = JobQueue {
            name: format!("pair(seed={seed})"),
            jobs: Vec::new(),
        };
        queue.submit(a.clone(), 0, pa);
        queue.submit(b.clone(), arrival, pb);
        queue.validate().unwrap();

        // Tight cache (4 blocks/worker): the jobs genuinely contend.
        let fleet_dir = TempDir::new("prop-mj").unwrap();
        let mut cfg = fast_cfg(PolicyKind::Lerc, 4);
        cfg.disk_dir = Some(fleet_dir.path().to_path_buf());
        let fleet = Engine::run(&ClusterEngine::new(cfg), &queue).unwrap();
        assert_eq!(
            fleet.aggregate.tasks_run,
            queue.task_count() as u64,
            "seed {seed}: every task of both jobs ran"
        );
        let fleet_store = read_store(fleet_dir.path());

        for w in [&a, &b] {
            let solo_dir = TempDir::new("prop-mj-solo").unwrap();
            let mut solo_cfg = fast_cfg(PolicyKind::Lerc, 4);
            solo_cfg.disk_dir = Some(solo_dir.path().to_path_buf());
            let solo = ClusterEngine::new(solo_cfg).run_workload(w).unwrap();
            let job = w.dags[0].job;
            let stats = fleet.job(job).expect("job stats");
            assert_eq!(stats.tasks_run, solo.tasks_run, "seed {seed} {job}");
            let solo_store = read_store(solo_dir.path());
            for blk in sink_blocks(w) {
                let (interleaved, _) = fleet_store.read(blk).unwrap();
                let (alone, _) = solo_store.read(blk).unwrap();
                assert_eq!(
                    interleaved, alone,
                    "seed {seed}: sink {blk} of {job} diverged under interleaving"
                );
            }
        }
    }
}
