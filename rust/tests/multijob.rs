//! Online multi-job acceptance suite: interleaved execution is per-job
//! byte-identical to isolated runs (both engines, both control planes),
//! arrival/priority/admission semantics are deterministic and identical
//! between the simulator and the threaded engine, cross-job reference
//! counts keep shared blocks protected while any job still needs them,
//! and a mid-queue kill rebuilds lineage only for live jobs.

use lerc_engine::Engine;
use lerc_engine::cache::sharded::ShardedStore;
use lerc_engine::common::config::{CtrlPlane, DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::common::ids::{BlockId, DatasetId, GroupId, JobId};
use lerc_engine::common::tempdir::TempDir;
use lerc_engine::dag::analysis::RefCounts;
use lerc_engine::dag::task::enumerate_tasks;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::recovery::FailurePlan;
use lerc_engine::sim::Simulator;
use lerc_engine::storage::DiskStore;
use lerc_engine::workload::{self, JobQueue, Workload};
use std::collections::HashSet;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn fast_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .build()
        .expect("valid config")
}

fn sim_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .build()
        .expect("valid config")
}

/// Blocks of every sink dataset (job results) across a workload.
fn sink_blocks(w: &Workload) -> Vec<BlockId> {
    let mut out = Vec::new();
    for dag in &w.dags {
        let parents: HashSet<DatasetId> =
            dag.datasets.iter().flat_map(|d| d.parents.iter().copied()).collect();
        for ds in dag.transforms() {
            if !parents.contains(&ds.id) {
                out.extend(ds.blocks());
            }
        }
    }
    out
}

fn read_store(dir: &Path) -> DiskStore {
    DiskStore::new(
        dir,
        DiskConfig {
            unthrottled: true,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Acceptance: interleaved two-job execution (50% shared ingest) leaves
/// every job's sink blocks byte-identical to running that job alone — in
/// the threaded engine under BOTH control planes.
#[test]
fn interleaved_two_jobs_match_isolated_sink_bytes_both_planes() {
    let queue = workload::multijob_zip_shared(2, 6, 4096, true, 4);
    for mode in [CtrlPlane::Broadcast, CtrlPlane::HomeRouted] {
        let fleet_dir = TempDir::new("mj-fleet").unwrap();
        let mut cfg = fast_cfg(PolicyKind::Lerc, 4, 2);
        cfg.ctrl_plane = mode;
        cfg.disk_dir = Some(fleet_dir.path().to_path_buf());
        let fleet = Engine::run(&ClusterEngine::new(cfg), &queue).unwrap();
        assert_eq!(fleet.jobs.len(), 2);
        assert_eq!(fleet.aggregate.tasks_run, queue.task_count() as u64);
        let fleet_store = read_store(fleet_dir.path());

        for spec in &queue.jobs {
            let solo_dir = TempDir::new("mj-solo").unwrap();
            let mut solo_cfg = fast_cfg(PolicyKind::Lerc, 4, 2);
            solo_cfg.ctrl_plane = mode;
            solo_cfg.disk_dir = Some(solo_dir.path().to_path_buf());
            let solo = ClusterEngine::new(solo_cfg).run_workload(&spec.workload).unwrap();
            let solo_store = read_store(solo_dir.path());
            let job = spec.workload.dags[0].job;
            let job_stats = fleet.job(job).expect("per-job stats present");
            assert_eq!(job_stats.tasks_run, solo.tasks_run, "{job} task count");
            for b in sink_blocks(&spec.workload) {
                let (interleaved, _) = fleet_store.read(b).unwrap();
                let (alone, _) = solo_store.read(b).unwrap();
                assert_eq!(interleaved, alone, "{mode:?}: sink {b} differs for {job}");
            }
        }
    }
}

/// With every job arriving at dispatch 0, per-worker event orders are
/// deterministic, so the simulator and the threaded engine replay
/// identical cache decisions on the shared-ingest queue for
/// protocol-free policies (the multi-job extension of
/// `tests/sim_vs_engine.rs`). LERC's asynchronous broadcasts race with
/// ingest in the threaded engine, so it gets a band, not equality.
#[test]
fn sim_and_threaded_agree_on_multijob_decisions() {
    let queue = workload::multijob_zip_shared(2, 6, 4096, true, 0);
    let mk = |policy: PolicyKind| {
        EngineConfig::builder()
            .num_workers(2)
            .block_len(4096)
            .cache_blocks(4)
            .policy(policy)
            .disk(DiskConfig {
                bandwidth_bytes_per_sec: 500 * 1024 * 1024,
                seek_latency: Duration::from_micros(200),
                unthrottled: false,
            })
            .net(NetConfig {
                per_message_latency: Duration::ZERO,
            })
            .build()
            .expect("valid config")
    };
    for policy in [PolicyKind::Lru, PolicyKind::Lrc] {
        let sim = Engine::run(&Simulator::from_engine_config(mk(policy)), &queue).unwrap();
        let real = Engine::run(&ClusterEngine::new(mk(policy)), &queue).unwrap();
        assert_eq!(sim.aggregate.tasks_run, real.aggregate.tasks_run, "{}", policy.name());
        assert_eq!(sim.aggregate.access.accesses, real.aggregate.access.accesses);
        assert_eq!(
            sim.aggregate.access.mem_hits,
            real.aggregate.access.mem_hits,
            "{}",
            policy.name()
        );
        assert_eq!(
            sim.aggregate.access.effective_hits,
            real.aggregate.access.effective_hits,
            "{}",
            policy.name()
        );
        for (s, r) in sim.jobs.iter().zip(&real.jobs) {
            assert_eq!(s.job, r.job);
            assert_eq!(s.tasks_run, r.tasks_run, "{} job {}", policy.name(), s.job);
            assert_eq!(s.access.accesses, r.access.accesses);
        }
    }
    let sim = Engine::run(&Simulator::from_engine_config(mk(PolicyKind::Lerc)), &queue).unwrap();
    let real = Engine::run(&ClusterEngine::new(mk(PolicyKind::Lerc)), &queue).unwrap();
    assert_eq!(sim.aggregate.tasks_run, real.aggregate.tasks_run);
    assert_eq!(sim.aggregate.access.accesses, real.aggregate.access.accesses);
    let tol = (sim.aggregate.access.accesses as f64 * 0.25).ceil() as i64;
    let dh = sim.aggregate.access.mem_hits as i64 - real.aggregate.access.mem_hits as i64;
    assert!(dh.abs() <= tol, "LERC hits diverged: {dh}");
}

/// Arrival indices gate admission deterministically, and a queue that
/// quiesces before an arrival index can be reached pulls the job in
/// instead of deadlocking.
#[test]
fn arrival_gates_admission_and_stall_clamps() {
    // Gap 3: job 1 admitted exactly at dispatch 3.
    let gapped = workload::multijob_zip_shared(2, 4, 4096, false, 3);
    let sim = Simulator::from_engine_config(sim_cfg(PolicyKind::Lerc, 50, 2));
    let fleet = Engine::run(&sim, &gapped).unwrap();
    assert_eq!(fleet.job(JobId(1)).unwrap().admitted_at_dispatch, 3);
    assert_eq!(fleet.jobs.len(), 2);
    assert!(fleet.jobs.iter().all(|j| j.jct > Duration::ZERO));

    // Absurd arrival: job 0 has only 4 tasks, so index 10_000 is
    // unreachable — the clamp admits job 1 once the queue quiesces.
    let mut stalled = workload::multijob_zip_shared(2, 4, 4096, false, 0);
    stalled.jobs[1].arrival = 10_000;
    stalled.validate().unwrap();
    let sim = Simulator::from_engine_config(sim_cfg(PolicyKind::Lerc, 50, 2));
    let fleet = Engine::run(&sim, &stalled).unwrap();
    assert_eq!(fleet.aggregate.tasks_run, stalled.task_count() as u64);
    let j1 = fleet.job(JobId(1)).unwrap();
    assert_eq!(j1.arrival, 10_000);
    assert_eq!(
        j1.admitted_at_dispatch, 4,
        "clamped to job 0's task count, not the requested index"
    );

    // The threaded engine clamps at the same dispatch index.
    let eng = ClusterEngine::new(fast_cfg(PolicyKind::Lerc, 50, 2));
    let fleet = Engine::run(&eng, &stalled).unwrap();
    assert_eq!(fleet.job(JobId(1)).unwrap().admitted_at_dispatch, 4);
    assert_eq!(fleet.aggregate.tasks_run, stalled.task_count() as u64);
}

/// The deterministic simulator replays a multi-job queue identically
/// run over run (arrivals, priorities, shared ingest and all).
#[test]
fn multijob_sim_is_deterministic() {
    let queue = workload::multijob_poisson(4, 6, 4096, 5.0, 23);
    let run = || {
        let sim = Simulator::from_engine_config(sim_cfg(PolicyKind::Lerc, 4, 4));
        Engine::run(&sim, &queue).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.aggregate.makespan, b.aggregate.makespan);
    assert_eq!(a.aggregate.access.mem_hits, b.aggregate.access.mem_hits);
    assert_eq!(a.aggregate.access.effective_hits, b.aggregate.access.effective_hits);
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.jct, y.jct, "job {}", x.job);
        assert_eq!(x.admitted_at_dispatch, y.admitted_at_dispatch);
    }
}

/// Priority mix: the queue completes, priorities are recorded on the
/// per-job stats, and the short high-priority interactive jobs finish
/// (admission → completion) faster than the long batch jobs they
/// interleave with.
#[test]
fn priority_mix_completes_and_interactive_jobs_finish_faster() {
    let queue = workload::multijob_priority_mix(4, 6, 4096, 3);
    let sim = Simulator::from_engine_config(sim_cfg(PolicyKind::Lerc, 6, 2));
    let fleet = Engine::run(&sim, &queue).unwrap();
    assert_eq!(fleet.aggregate.tasks_run, queue.task_count() as u64);
    for j in &fleet.jobs {
        let expect = if j.job % 2 == 1 { 3 } else { 0 };
        assert_eq!(j.priority, expect, "J{} priority plumbed through", j.job);
        assert!(j.jct > Duration::ZERO, "J{} finished", j.job);
    }
    // The first interactive job (half-size aggregate, admitted into a
    // cluster that just cleared the first batch job's ingest) finishes
    // well under the batch job it rode in behind.
    let batch0 = fleet.job(JobId(0)).unwrap().jct;
    let interactive1 = fleet.job(JobId(1)).unwrap().jct;
    assert!(
        interactive1 < batch0,
        "interactive jct {interactive1:?} not under batch jct {batch0:?}"
    );
}

/// Cross-job reference positivity: a shared ingest block keeps a
/// positive aggregate reference count (and survives eviction pressure
/// while pinned) when job B retires its last reference but job A still
/// holds one — the ISSUE-4 shared-block lifecycle.
#[test]
fn shared_block_stays_referenced_and_pinned_across_jobs() {
    // RefCounts level: aggregate over two jobs' tasks.
    let queue = workload::multijob_zip_shared(2, 2, 1024, true, 0);
    let mut next = 0u64;
    let a_tasks = enumerate_tasks(&queue.jobs[0].workload.dags[0], &mut next);
    let b_tasks = enumerate_tasks(&queue.jobs[1].workload.dags[0], &mut next);
    let mut rc = RefCounts::default();
    rc.add_tasks(&a_tasks);
    rc.add_tasks(&b_tasks);
    let shared = BlockId::new(DatasetId(0), 0);
    assert_eq!(rc.get(shared), 2, "one reference per job");
    // Job B retires ITS last reference to the shared block.
    rc.on_task_complete(&b_tasks[0]);
    assert!(rc.get(shared) > 0, "job A's reference must survive B's retirement");
    rc.on_task_complete(&a_tasks[0]);
    assert_eq!(rc.get(shared), 0);

    // Store level: job A's group pin keeps the shared block resident
    // under eviction pressure, and unrelated unpins don't release it.
    let store = ShardedStore::new(4 * 1024 * 4, PolicyKind::Lerc, 1);
    let payload: lerc_engine::cache::store::BlockData = Arc::from(vec![0.5f32; 1024]);
    store.insert(shared, payload.clone());
    let a_gid = GroupId(a_tasks[0].id.0);
    assert!(store.pin_group(a_gid, &[shared]), "job A pins the shared block");
    // Job B's group over the same block retires (unpin of a DIFFERENT
    // group id): A's pin must hold.
    let b_gid = GroupId(b_tasks[0].id.0);
    assert!(store.pin_group(b_gid, &[shared]));
    store.unpin_group(b_gid);
    for i in 1..12 {
        store.insert(BlockId::new(DatasetId(200), i), payload.clone());
    }
    assert!(store.contains(shared), "pinned shared block evicted under pressure");
    store.unpin_group(a_gid);
    assert_eq!(store.pinned_count(), 0, "A's unpin released the last hold");
}

/// Two-job queue for the kill-scoping test: job A is a plain 4-task zip
/// arriving at 0; job B (arriving at A's last dispatch) is two-stage —
/// zip then aggregate — so a kill at dispatch 8 lands after A finished
/// and B's zips completed but before B's aggregates dispatch. The
/// completed prefix is a deterministic *set* in both engines.
fn kill_scoping_queue() -> JobQueue {
    use lerc_engine::dag::graph::JobDag;
    let mut q = workload::multijob_zip_shared(1, 4, 4096, false, 0);
    let mut dag = JobDag::new(JobId(1), 128);
    let k = dag.input("K", 4, 4096);
    let v = dag.input("V", 4, 4096);
    let c = dag.zip("C", k, v);
    dag.aggregate("D", c);
    let ingest_order = dag
        .dataset(k)
        .blocks()
        .chain(dag.dataset(v).blocks())
        .collect();
    q.submit(
        Workload {
            name: "two_stage_b".into(),
            dags: vec![dag],
            ingest_order,
            pinned_cache: None,
        },
        4,
        0,
    );
    q.name = "kill_scoping".into();
    q
}

/// A kill while job A has finished and job B is mid-flight rebuilds
/// lineage ONLY for job B: A's lost results are not recomputed (they
/// were delivered), and B's outputs still match an isolated run.
#[test]
fn kill_rebuilds_lineage_only_for_live_jobs() {
    let queue = kill_scoping_queue();
    let total = queue.task_count() as u64; // 4 + 8
    let kill_at = 8; // A's 4 + B's 4 zips; B's aggregates still held

    // Sim first: deterministic loss accounting. Worker 0 dies holding
    // A's kv_0/kv_2 (delivered sinks — not rebuilt) and B's C_0/C_2
    // (still referenced by the pending aggregates — rebuilt).
    let mut cfg = sim_cfg(PolicyKind::Lerc, 100, 2);
    cfg.failures = FailurePlan::kill_at(0, kill_at);
    let fleet = Engine::run(&Simulator::from_engine_config(cfg), &queue).unwrap();
    let ja = fleet.job(JobId(0)).unwrap();
    let jb = fleet.job(JobId(1)).unwrap();
    assert_eq!(ja.recompute_tasks, 0, "finished job A must not rebuild lineage");
    assert_eq!(jb.recompute_tasks, 2, "exactly B's lost still-referenced zips");
    assert_eq!(
        fleet.aggregate.recovery.recompute_tasks,
        jb.recompute_tasks,
        "every recompute belongs to the live job"
    );
    assert_eq!(fleet.aggregate.tasks_run, total + jb.recompute_tasks);

    // Threaded engine: same scoping, and B's sinks are byte-identical
    // to an isolated run while A's lost (already delivered) results
    // are gone from the disk tier.
    let fleet_dir = TempDir::new("mj-kill").unwrap();
    let mut ecfg = fast_cfg(PolicyKind::Lerc, 100, 2);
    ecfg.disk_dir = Some(fleet_dir.path().to_path_buf());
    ecfg.failures = FailurePlan::kill_at(0, kill_at);
    let fleet = Engine::run(&ClusterEngine::new(ecfg), &queue).unwrap();
    assert_eq!(fleet.job(JobId(0)).unwrap().recompute_tasks, 0);
    assert_eq!(fleet.job(JobId(1)).unwrap().recompute_tasks, 2);

    let solo_dir = TempDir::new("mj-kill-solo").unwrap();
    let mut scfg = fast_cfg(PolicyKind::Lerc, 100, 2);
    scfg.disk_dir = Some(solo_dir.path().to_path_buf());
    let _ = ClusterEngine::new(scfg).run_workload(&queue.jobs[1].workload).unwrap();
    let fleet_store = read_store(fleet_dir.path());
    let solo_store = read_store(solo_dir.path());
    for b in sink_blocks(&queue.jobs[1].workload) {
        let (after_kill, _) = fleet_store.read(b).unwrap();
        let (alone, _) = solo_store.read(b).unwrap();
        assert_eq!(after_kill, alone, "live job's sink {b} differs after recovery");
    }
    // Job A's sinks homed at the dead worker were deliberately not
    // re-materialized.
    let lost_a: Vec<BlockId> = sink_blocks(&queue.jobs[0].workload)
        .into_iter()
        .filter(|b| b.index % 2 == 0) // homes at killed worker 0 of 2
        .collect();
    assert!(!lost_a.is_empty());
    for b in lost_a {
        assert!(
            fleet_store.read(b).is_err(),
            "finished job's lost sink {b} should stay gone"
        );
    }
}

/// `run_workload` is exactly `run` over a single job arriving at 0: the
/// aggregate of the one-job queue equals the classic report.
#[test]
fn single_job_queue_equals_classic_run() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let sim = Simulator::from_engine_config(sim_cfg(PolicyKind::Lerc, 4, 4));
    let classic = sim.run_workload(&w).unwrap();
    let fleet = Engine::run(&sim, &JobQueue::single(w.clone())).unwrap();
    assert_eq!(classic.makespan, fleet.aggregate.makespan);
    assert_eq!(classic.access.mem_hits, fleet.aggregate.access.mem_hits);
    assert_eq!(classic.access.effective_hits, fleet.aggregate.access.effective_hits);
    assert_eq!(classic.tasks_run, fleet.aggregate.tasks_run);
    assert_eq!(fleet.jobs.len(), w.dags.len(), "one JobStats per submitted dag");
    let per_job_accesses: u64 = fleet.jobs.iter().map(|j| j.access.accesses).sum();
    assert_eq!(per_job_accesses, fleet.aggregate.access.accesses);
}
