//! Failure-injection and lineage-recovery acceptance suite: a seeded
//! mid-job worker kill must leave byte-identical final outputs, recompute
//! only the minimal ancestor closure, keep the home-routing invariant
//! after metadata repair, and preserve LERC's all-or-nothing advantage
//! (fewer ineffective hits than LRU) through the churn.

use lerc_engine::Engine;
use lerc_engine::common::config::{CtrlPlane, DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::common::ids::{BlockId, DatasetId, JobId};
use lerc_engine::common::tempdir::TempDir;
use lerc_engine::dag::graph::JobDag;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::recovery::FailurePlan;
use lerc_engine::sim::Simulator;
use lerc_engine::storage::DiskStore;
use lerc_engine::workload::{self, Workload};
use std::collections::HashSet;
use std::path::Path;
use std::time::Duration;

fn fast_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .build()
        .expect("valid config")
}

fn sim_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .build()
        .expect("valid config")
}

/// Blocks of every sink dataset (job results) across the workload.
fn sink_blocks(w: &Workload) -> Vec<BlockId> {
    let mut out = Vec::new();
    for dag in &w.dags {
        let parents: HashSet<DatasetId> =
            dag.datasets.iter().flat_map(|d| d.parents.iter().copied()).collect();
        for ds in dag.transforms() {
            if !parents.contains(&ds.id) {
                out.extend(ds.blocks());
            }
        }
    }
    out
}

fn read_store(dir: &Path) -> DiskStore {
    DiskStore::new(
        dir,
        DiskConfig {
            unthrottled: true,
            ..Default::default()
        },
    )
    .unwrap()
}

/// map(A) -> M -> coalesce -> X: the unaligned geometry where a kill
/// strands some lost intermediates with no live consumers.
fn map_coalesce_workload(blocks: u32, block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let a = dag.input("A", blocks, block_len);
    let m = dag.map("M", a);
    dag.coalesce("X", m);
    let ingest_order = dag.dataset(a).blocks().collect();
    Workload {
        name: "map_coalesce".into(),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

#[test]
fn sim_recovers_deterministically_from_a_mid_job_kill() {
    let w = workload::multi_tenant_zip(4, 10, 4096);
    let total_tasks = w.task_count() as u64; // 40
    let run = || {
        let mut cfg = sim_cfg(PolicyKind::Lerc, 5, 4);
        cfg.failures = FailurePlan::kill_at(1, total_tasks / 2);
        Simulator::from_engine_config(cfg).run_workload(&w).unwrap()
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.recovery.workers_killed, 1);
    assert!(r1.recovery.blocks_lost_durable > 0);
    assert!(r1.recovery.recompute_tasks > 0);
    assert_eq!(
        r1.tasks_run,
        total_tasks + r1.recovery.recompute_tasks,
        "every original task plus exactly the recompute closure"
    );
    // Deterministic replay: identical losses, identical recovery.
    assert_eq!(r1.makespan, r2.makespan);
    assert_eq!(r1.access.mem_hits, r2.access.mem_hits);
    assert_eq!(r1.recovery, r2.recovery);
    // Accounting stays conserved through the churn.
    assert_eq!(r1.access.accesses, r1.access.mem_hits + r1.access.disk_reads);
}

#[test]
fn sim_recovery_completes_for_every_policy() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let total = w.task_count() as u64;
    for p in PolicyKind::ALL {
        let mut cfg = sim_cfg(p, 3, 4);
        cfg.failures = FailurePlan::kill_at(2, total / 2);
        let r = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
        assert_eq!(r.recovery.workers_killed, 1, "{}", p.name());
        assert_eq!(r.tasks_run, total + r.recovery.recompute_tasks, "{}", p.name());
    }
}

#[test]
fn engine_kill_leaves_byte_identical_final_outputs() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let total = w.task_count() as u64; // 18
    let clean_dir = TempDir::new("recovery-clean").unwrap();
    let kill_dir = TempDir::new("recovery-kill").unwrap();

    let mut clean_cfg = fast_cfg(PolicyKind::Lerc, 100, 2);
    clean_cfg.disk_dir = Some(clean_dir.path().to_path_buf());
    let clean = ClusterEngine::new(clean_cfg).run_workload(&w).unwrap();
    assert_eq!(clean.recovery.workers_killed, 0);

    let mut kill_cfg = fast_cfg(PolicyKind::Lerc, 100, 2);
    kill_cfg.disk_dir = Some(kill_dir.path().to_path_buf());
    kill_cfg.failures = FailurePlan::kill_at(1, total / 2);
    let killed = ClusterEngine::new(kill_cfg).run_workload(&w).unwrap();
    assert_eq!(killed.recovery.workers_killed, 1);
    assert!(killed.recovery.blocks_lost_durable > 0);
    assert_eq!(killed.tasks_run, total + killed.recovery.recompute_tasks);
    assert_eq!(killed.job_times.len(), w.dags.len(), "every job finished");

    let clean_store = read_store(clean_dir.path());
    let kill_store = read_store(kill_dir.path());
    for b in sink_blocks(&w) {
        let (a, _) = clean_store.read(b).unwrap();
        let (k, _) = kill_store.read(b).unwrap();
        assert_eq!(a, k, "sink block {b} differs after recovery");
    }
}

#[test]
fn only_the_minimal_ancestor_closure_is_recomputed() {
    // 8 map tasks + 4 coalesce tasks over 2 workers; kill worker 0 at
    // dispatch 10, i.e. after the 8 maps plus X_0 and X_1 completed (the
    // per-worker-FIFO readiness order makes that prefix deterministic in
    // both engines) while X_2/X_3 are still held. Lost at worker 0 (even
    // homes, materialized): M_0, M_2, M_4, M_6 and X_0. Needed roots:
    // M_4 and M_6 (still referenced by the pending X_2/X_3) and the
    // live job's sink X_0, whose closure pulls in map_0 (M_1 survives
    // at worker 1). M_2 is lost but has no live consumer — it must NOT
    // be recomputed.
    let w = map_coalesce_workload(8, 4096);
    let total = w.task_count() as u64; // 12
    let expect_recompute = 4u64; // map_0, map_4, map_6, coalesce_0
    let expect_lost = 5u64; // M_0, M_2, M_4, M_6, X_0

    let mut cfg = sim_cfg(PolicyKind::Lerc, 1000, 2);
    cfg.failures = FailurePlan::kill_at(0, total - 2);
    let sim = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert_eq!(sim.recovery.blocks_lost_durable, expect_lost);
    assert_eq!(sim.recovery.recompute_tasks, expect_recompute);
    assert_eq!(sim.tasks_run, total + expect_recompute);

    // The threaded engine replays the same deterministic loss.
    let mut ecfg = fast_cfg(PolicyKind::Lerc, 1000, 2);
    ecfg.failures = FailurePlan::kill_at(0, total - 2);
    let eng = ClusterEngine::new(ecfg).run_workload(&w).unwrap();
    assert_eq!(eng.recovery.blocks_lost_durable, expect_lost);
    assert_eq!(eng.recovery.recompute_tasks, expect_recompute);
    assert_eq!(eng.tasks_run, total + expect_recompute);
}

#[test]
fn a_finished_jobs_lost_sinks_are_not_recomputed() {
    // Kill after the whole job completed: every lost block is either
    // unreferenced or a delivered result — nothing is recomputed (the
    // multi-job scoping rule; `tests/multijob.rs` exercises the
    // two-job variant where only the live job rebuilds lineage).
    let w = map_coalesce_workload(8, 4096);
    let total = w.task_count() as u64; // 12
    let mut cfg = sim_cfg(PolicyKind::Lerc, 1000, 2);
    cfg.failures = FailurePlan::kill_at(0, total);
    let sim = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert_eq!(sim.recovery.blocks_lost_durable, 6); // M_0,2,4,6 + X_0,2
    assert_eq!(sim.recovery.recompute_tasks, 0);
    assert_eq!(sim.tasks_run, total);
}

/// The home-routing invariant holds after failure repair: on the paper's
/// zip geometry, Broadcast and HomeRouted replay identical cache
/// decisions through a kill — peer groups were re-registered at the new
/// homes and ref/effective counts re-seeded, so only message *counts*
/// may differ (same bar as `tests/ctrl_plane.rs` sets fault-free).
#[test]
fn ctrl_plane_modes_agree_through_a_kill() {
    let w = workload::multi_tenant_zip(4, 8, 4096);
    let total = w.task_count() as u64; // 32
    let run = |mode: CtrlPlane| {
        let mut cfg = fast_cfg(PolicyKind::Lerc, 6, 4);
        cfg.ctrl_plane = mode;
        cfg.failures = FailurePlan::kill_at(2, total / 2);
        ClusterEngine::new(cfg).run_workload(&w).unwrap()
    };
    let b = run(CtrlPlane::Broadcast);
    let h = run(CtrlPlane::HomeRouted);
    // recovery_nanos is wall-clock in the threaded engine — compare the
    // deterministic loss/repair fields, not the timing.
    assert_eq!(b.recovery.workers_killed, h.recovery.workers_killed);
    assert_eq!(b.recovery.blocks_lost_cached, h.recovery.blocks_lost_cached);
    assert_eq!(b.recovery.blocks_lost_durable, h.recovery.blocks_lost_durable);
    assert_eq!(b.recovery.recompute_tasks, h.recovery.recompute_tasks);
    assert_eq!(b.recovery.recompute_bytes, h.recovery.recompute_bytes);
    assert_eq!(b.tasks_run, h.tasks_run);
    assert_eq!(b.access.accesses, h.access.accesses);
    assert_eq!(b.access.mem_hits, h.access.mem_hits);
    assert_eq!(b.access.effective_hits, h.access.effective_hits);
    assert_eq!(b.access.disk_reads, h.access.disk_reads);
    assert_eq!(b.evictions, h.evictions);
    // Routing may shrink deliveries, never the invalidation events.
    assert_eq!(b.messages.invalidation_broadcasts, h.messages.invalidation_broadcasts);
    assert!(h.messages.broadcast_deliveries <= b.messages.broadcast_deliveries);
}

#[test]
fn restarted_worker_rejoins_and_the_job_completes() {
    let w = workload::multi_tenant_zip(4, 10, 4096);
    let total = w.task_count() as u64;
    let run = || {
        let mut cfg = sim_cfg(PolicyKind::Lerc, 5, 4);
        cfg.failures = FailurePlan::kill_at(1, total / 3).with_restart(total / 3);
        Simulator::from_engine_config(cfg).run_workload(&w).unwrap()
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.recovery.workers_killed, 1);
    assert_eq!(r1.recovery.workers_restarted, 1);
    assert_eq!(r1.tasks_run, total + r1.recovery.recompute_tasks);
    assert_eq!(r1.recovery, r2.recovery);
    assert_eq!(r1.makespan, r2.makespan);

    // Threaded engine: same plan, same completion guarantee.
    let mut ecfg = fast_cfg(PolicyKind::Lerc, 5, 4);
    ecfg.failures = FailurePlan::kill_at(1, total / 3).with_restart(total / 3);
    let eng = ClusterEngine::new(ecfg).run_workload(&w).unwrap();
    assert_eq!(eng.recovery.workers_restarted, 1);
    assert_eq!(eng.tasks_run, total + eng.recovery.recompute_tasks);
}

/// Acceptance (c): after a mid-job kill on the multi-tenant zip
/// workload, LERC recovers with fewer ineffective hits than LRU — the
/// group-coherence advantage survives churn (the recovery bench emits
/// the same comparison to BENCH_recovery.json).
#[test]
fn lerc_recovers_with_fewer_ineffective_hits_than_lru() {
    let w = workload::multi_tenant_zip(8, 12, 4096);
    let total = w.task_count() as u64; // 96
    let run = |p: PolicyKind| {
        let mut cfg = sim_cfg(p, 4, 4);
        cfg.failures = FailurePlan::kill_at(1, total / 2);
        Simulator::from_engine_config(cfg).run_workload(&w).unwrap()
    };
    let lru = run(PolicyKind::Lru);
    let lerc = run(PolicyKind::Lerc);
    assert!(
        lerc.ineffective_hits() < lru.ineffective_hits(),
        "LERC {} vs LRU {} ineffective hits",
        lerc.ineffective_hits(),
        lru.ineffective_hits()
    );
    assert!(lerc.effective_hit_ratio() >= lru.effective_hit_ratio());
}

#[test]
fn killing_every_worker_is_an_error_not_a_silent_run() {
    use lerc_engine::recovery::FailureEvent;
    use lerc_engine::WorkerId;
    let w = workload::multi_tenant_zip(2, 4, 4096);
    let mut cfg = sim_cfg(PolicyKind::Lerc, 100, 2);
    cfg.failures = FailurePlan {
        events: vec![
            FailureEvent {
                worker: WorkerId(0),
                at_dispatch: 2,
                restart_after: None,
            },
            FailureEvent {
                worker: WorkerId(1),
                at_dispatch: 4,
                restart_after: None,
            },
        ],
    };
    let err = Simulator::from_engine_config(cfg).run_workload(&w).unwrap_err();
    assert!(err.to_string().contains("killed every worker"), "{err}");
}

#[test]
fn empty_plan_changes_nothing() {
    let w = workload::multi_tenant_zip(3, 6, 4096);
    let base_sim = Simulator::from_engine_config(sim_cfg(PolicyKind::Lerc, 4, 4));
    let base = base_sim.run_workload(&w).unwrap();
    let mut cfg = sim_cfg(PolicyKind::Lerc, 4, 4);
    cfg.failures = FailurePlan::none();
    let with_plan = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert_eq!(base.makespan, with_plan.makespan);
    assert_eq!(base.recovery, with_plan.recovery);
    assert_eq!(base.recovery.workers_killed, 0);
}
