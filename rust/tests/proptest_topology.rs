//! Property test over random `TopologyPlan`s: a seeded mix of kills
//! (with restart churn) and pending-slot joins, replayed on the event
//! core against a fixed-fleet reference. Elastic topology may move
//! blocks and cost lineage recomputes; it must never change WHAT the
//! workload computes, and every planned event must fire exactly once,
//! deterministically.

use lerc_engine::common::config::{DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::common::ids::WorkerId;
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::recovery::{TopologyEvent, TopologyPlan};
use lerc_engine::sim::Simulator;
use lerc_engine::workload;
use std::time::Duration;

const WORKERS: u32 = 2;

fn cfg_with(plan: TopologyPlan) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(WORKERS)
        .block_len(1024)
        .cache_blocks(4)
        .policy(PolicyKind::Lru)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .topology(plan)
        .build()
        .expect("generated plan must validate")
}

/// Build a random-but-valid plan: every kill targets an initial worker
/// and restarts (so the fleet never drains to zero), every join targets
/// a fresh pending slot, and all triggers land strictly inside the run
/// so each event is guaranteed to fire.
fn random_plan(rng: &mut SplitMix64, total: u64) -> (TopologyPlan, u64, u64) {
    let mut plan = TopologyPlan::none();
    let joins = rng.next_below(3); // 0..=2 pending slots come online
    for j in 0..joins {
        plan = plan.then(TopologyEvent::Join {
            worker: WorkerId(WORKERS + j as u32),
            at_dispatch: 1 + rng.next_below(total - 2),
        });
    }
    let kills = rng.next_below(3); // 0..=2 kill/restart churn events
    for k in 0..kills {
        // Disjoint kill windows (trigger spaced past the prior revive)
        // so churn never drains the whole initial fleet at once.
        plan = plan.then(TopologyEvent::Kill {
            worker: WorkerId(rng.next_below(WORKERS as u64) as u32),
            at_dispatch: 3 + k * 8 + rng.next_below(3),
            restart_after: Some(1 + rng.next_below(3)),
        });
    }
    (plan, joins, kills)
}

#[test]
fn random_topology_plans_replay_exactly_against_fixed_fleet() {
    let w = workload::double_map_zip_agg(8, 1024);
    let total = w.task_count() as u64;
    let reference = Simulator::from_engine_config(cfg_with(TopologyPlan::none()))
        .run_workload(&w)
        .unwrap();
    assert_eq!(reference.tasks_run, total);
    assert_eq!(reference.scale.workers_joined, 0);
    assert_eq!(reference.recovery.workers_killed, 0);

    for seed in 0..12u64 {
        let mut rng = SplitMix64::new(seed ^ 0x70_0B57);
        let (plan, joins, kills) = random_plan(&mut rng, total);
        let a = Simulator::from_engine_config(cfg_with(plan.clone()))
            .run_workload(&w)
            .unwrap();
        let b = Simulator::from_engine_config(cfg_with(plan.clone()))
            .run_workload(&w)
            .unwrap();

        // Deterministic replay: the same plan produces the same run.
        assert_eq!(a.scale, b.scale, "seed {seed}: scale stats diverged");
        assert_eq!(a.recovery, b.recovery, "seed {seed}: recovery diverged");
        assert_eq!(a.tasks_run, b.tasks_run, "seed {seed}");
        assert_eq!(a.makespan, b.makespan, "seed {seed}");

        // Every planned event fires exactly once (all triggers < total).
        assert_eq!(a.scale.workers_joined, joins, "seed {seed}: joins fired");
        assert_eq!(a.recovery.workers_killed, kills, "seed {seed}: kills fired");

        // Work conservation vs the fixed fleet: the plan may cost
        // lineage recomputes, never lose or duplicate workload tasks.
        assert_eq!(
            a.tasks_run,
            total + a.recovery.recompute_tasks,
            "seed {seed}: tasks lost or double-counted under {}",
            plan_desc(&plan)
        );
        assert!(
            a.access.accesses >= reference.access.accesses,
            "seed {seed}: planned run served fewer accesses than the reference"
        );
        if joins == 0 && kills == 0 {
            // An empty plan IS the fixed fleet.
            assert_eq!(a.tasks_run, reference.tasks_run, "seed {seed}");
            assert_eq!(a.makespan, reference.makespan, "seed {seed}");
        }
    }
}

fn plan_desc(plan: &TopologyPlan) -> String {
    match plan {
        TopologyPlan::Events(evs) => format!("{} events", evs.len()),
        TopologyPlan::Auto(_) => "autoscale".into(),
    }
}
