//! Property tests over every cache policy (in-tree harness — the offline
//! build has no proptest crate; randomness is deterministic SplitMix64
//! with the failing seed printed on panic).

use lerc_engine::cache::policy::{new_policy, PolicyEvent, Tick};
use lerc_engine::common::config::PolicyKind;
use lerc_engine::common::ids::{BlockId, DatasetId};
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::common::fxhash::FxHashSet;

const CASES: u64 = 200;

fn b(i: u64) -> BlockId {
    BlockId::new(DatasetId((i / 64) as u32), (i % 64) as u32)
}

/// A random event trace applied to a policy alongside a model `HashSet`
/// of cached blocks. After every step the policy and model must agree on
/// membership count, victims must be cached and unpinned, and removal of
/// all blocks must drain the policy.
fn random_trace(kind: PolicyKind, seed: u64) {
    let mut rng = SplitMix64::new(seed);
    let mut p = new_policy(kind);
    let mut model: FxHashSet<BlockId> = FxHashSet::default();
    let mut tick: Tick = 0;
    let universe = 48;

    for _step in 0..400 {
        tick += 1;
        let blk = b(rng.next_below(universe));
        match rng.next_below(100) {
            0..=39 => {
                // Insert (or re-insert — policies must treat as rescore).
                p.on_event(PolicyEvent::Insert { block: blk, tick });
                model.insert(blk);
            }
            40..=59 => {
                if model.contains(&blk) {
                    p.on_event(PolicyEvent::Access { block: blk, tick });
                }
            }
            60..=74 => {
                if model.remove(&blk) {
                    p.on_event(PolicyEvent::Remove { block: blk });
                }
            }
            75..=84 => {
                p.on_event(PolicyEvent::RefCount {
                    block: blk,
                    count: rng.next_below(5) as u32,
                });
            }
            85..=94 => {
                p.on_event(PolicyEvent::EffectiveCount {
                    block: blk,
                    count: rng.next_below(3) as u32,
                });
            }
            _ => {
                // Evict via the policy itself, with random pins.
                let pinned: FxHashSet<BlockId> = model
                    .iter()
                    .filter(|_| rng.next_below(4) == 0)
                    .copied()
                    .collect();
                match p.victim(&pinned) {
                    Some(v) => {
                        assert!(
                            model.contains(&v),
                            "[{kind:?} seed={seed}] victim {v} not cached"
                        );
                        assert!(
                            !pinned.contains(&v),
                            "[{kind:?} seed={seed}] victim {v} was pinned"
                        );
                        p.on_event(PolicyEvent::Remove { block: v });
                        model.remove(&v);
                    }
                    None => {
                        // Only legal when every cached block is pinned.
                        assert!(
                            model.iter().all(|m| pinned.contains(m)),
                            "[{kind:?} seed={seed}] victim=None with evictable blocks"
                        );
                    }
                }
            }
        }
        assert_eq!(
            p.len(),
            model.len(),
            "[{kind:?} seed={seed}] membership diverged"
        );
    }

    // Drain.
    let remaining: Vec<BlockId> = model.iter().copied().collect();
    for blk in remaining {
        p.on_event(PolicyEvent::Remove { block: blk });
    }
    assert!(p.is_empty(), "[{kind:?} seed={seed}] not drained");
    assert!(p.victim(&FxHashSet::default()).is_none());
}

#[test]
fn all_policies_agree_with_model_under_random_traces() {
    for kind in PolicyKind::ALL {
        for seed in 0..CASES {
            random_trace(kind, seed);
        }
    }
}

/// Victim sequences must be exhaustive and duplicate-free: evicting until
/// empty touches every cached block exactly once.
#[test]
fn eviction_until_empty_is_a_permutation() {
    for kind in PolicyKind::ALL {
        for seed in 0..50 {
            let mut rng = SplitMix64::new(seed ^ 0xABCD);
            let mut p = new_policy(kind);
            let n = 1 + rng.next_below(40);
            let mut inserted = FxHashSet::default();
            for i in 0..n {
                p.on_event(PolicyEvent::Insert {
                    block: b(i),
                    tick: rng.next_below(1000),
                });
                inserted.insert(b(i));
            }
            let mut seen = FxHashSet::default();
            let none = FxHashSet::default();
            while let Some(v) = p.victim(&none) {
                assert!(seen.insert(v), "[{kind:?} seed={seed}] duplicate victim");
                p.on_event(PolicyEvent::Remove { block: v });
            }
            assert_eq!(seen, inserted, "[{kind:?} seed={seed}]");
        }
    }
}

/// LERC-specific: the victim always has the minimal effective count among
/// unpinned cached blocks (its defining property).
#[test]
fn lerc_victim_minimizes_effective_count() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x5EED);
        let mut p = new_policy(PolicyKind::Lerc);
        let n = 2 + rng.next_below(30);
        let mut eff = std::collections::HashMap::new();
        for i in 0..n {
            let e = rng.next_below(4) as u32;
            p.on_event(PolicyEvent::EffectiveCount { block: b(i), count: e });
            p.on_event(PolicyEvent::Insert { block: b(i), tick: i });
            eff.insert(b(i), e);
        }
        let v = p.victim(&FxHashSet::default()).unwrap();
        let min = eff.values().min().copied().unwrap();
        assert_eq!(
            eff[&v], min,
            "seed={seed}: victim eff {} but min is {min}",
            eff[&v]
        );
    }
}

/// LRC-specific: same property for plain reference counts.
#[test]
fn lrc_victim_minimizes_ref_count() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x10C);
        let mut p = new_policy(PolicyKind::Lrc);
        let n = 2 + rng.next_below(30);
        let mut refs = std::collections::HashMap::new();
        for i in 0..n {
            let r = rng.next_below(6) as u32;
            p.on_event(PolicyEvent::RefCount { block: b(i), count: r });
            p.on_event(PolicyEvent::Insert { block: b(i), tick: i });
            refs.insert(b(i), r);
        }
        let v = p.victim(&FxHashSet::default()).unwrap();
        let min = refs.values().min().copied().unwrap();
        assert_eq!(refs[&v], min, "seed={seed}");
    }
}

/// LERC degenerates to LRC ordering when every effective count is equal.
#[test]
fn lerc_equals_lrc_when_eff_uniform() {
    for seed in 0..100 {
        let mut rng = SplitMix64::new(seed ^ 0xD06);
        let mut lerc = new_policy(PolicyKind::Lerc);
        let mut lrc = new_policy(PolicyKind::Lrc);
        let n = 2 + rng.next_below(25);
        for i in 0..n {
            let r = rng.next_below(5) as u32;
            for p in [&mut lerc, &mut lrc] {
                p.on_event(PolicyEvent::RefCount { block: b(i), count: r });
            }
            lerc.on_event(PolicyEvent::EffectiveCount { block: b(i), count: 1 });
            for p in [&mut lerc, &mut lrc] {
                p.on_event(PolicyEvent::Insert { block: b(i), tick: i });
            }
        }
        let none = FxHashSet::default();
        for _ in 0..n {
            let a = lerc.victim(&none);
            let c = lrc.victim(&none);
            assert_eq!(a, c, "seed={seed}: LERC diverged from LRC under uniform eff");
            if let Some(v) = a {
                lerc.on_event(PolicyEvent::Remove { block: v });
                lrc.on_event(PolicyEvent::Remove { block: v });
            }
        }
    }
}

/// LRU sanity under the same trace framework: victim is always the block
/// with the oldest last-access tick.
#[test]
fn lru_victim_is_oldest() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x14D);
        let mut p = new_policy(PolicyKind::Lru);
        let mut last = std::collections::HashMap::new();
        let mut tick = 0u64;
        for i in 0..20 {
            tick += 1;
            p.on_event(PolicyEvent::Insert { block: b(i), tick });
            last.insert(b(i), tick);
        }
        for _ in 0..30 {
            tick += 1;
            let i = rng.next_below(20);
            p.on_event(PolicyEvent::Access { block: b(i), tick });
            last.insert(b(i), tick);
        }
        let v = p.victim(&FxHashSet::default()).unwrap();
        let oldest = last.iter().min_by_key(|(_, &t)| t).map(|(k, _)| *k).unwrap();
        assert_eq!(v, oldest, "seed={seed}");
    }
}
