//! ISSUE-9 elastic-topology suite: `TopologyPlan` joins are deterministic,
//! compose with the recovery path, never split a peer group, and leave
//! sink outputs byte-identical — the mixed kill/join plan changes *where*
//! work runs, never *what* it computes. Autoscale decisions replay
//! identically in the event core and the threaded engine.

use lerc_engine::Engine;
use lerc_engine::common::config::{
    CtrlPlane, DiskConfig, EngineConfig, NetConfig, PolicyKind, SpillConfig,
};
use lerc_engine::common::ids::{BlockId, DatasetId, WorkerId};
use lerc_engine::common::tempdir::TempDir;
use lerc_engine::driver::ClusterEngine;
use lerc_engine::recovery::{AutoscaleConfig, TopologyEvent, TopologyPlan};
use lerc_engine::sim::Simulator;
use lerc_engine::storage::DiskStore;
use lerc_engine::trace::{TraceConfig, TraceEvent};
use lerc_engine::workload::{self, Workload};
use std::collections::HashSet;
use std::path::Path;
use std::time::Duration;

const BLOCK_LEN: usize = 1024;
const BLOCK_BYTES: u64 = (BLOCK_LEN as u64) * 4;

/// The sim ≡ threaded comparison recipe (tests/sim_vs_engine.rs): a
/// modeled disk fast enough for CI but dominant over real scheduling
/// noise, zero protocol latency, the broadcast plane in both engines.
fn compare_cfg(policy: PolicyKind, cache_blocks: u64, workers: u32) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(BLOCK_LEN)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            bandwidth_bytes_per_sec: 500 * 1024 * 1024,
            seek_latency: Duration::from_micros(200),
            unthrottled: false,
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .ctrl_plane(CtrlPlane::Broadcast)
        .build()
        .expect("valid config")
}

fn sink_blocks(w: &Workload) -> Vec<BlockId> {
    let mut out = Vec::new();
    for dag in &w.dags {
        let parents: HashSet<DatasetId> =
            dag.datasets.iter().flat_map(|d| d.parents.iter().copied()).collect();
        for ds in dag.transforms() {
            if !parents.contains(&ds.id) {
                out.extend(ds.blocks());
            }
        }
    }
    out
}

fn read_store(dir: &Path) -> DiskStore {
    DiskStore::new(
        dir,
        DiskConfig {
            unthrottled: true,
            ..Default::default()
        },
    )
    .unwrap()
}

/// A join that lands while a kill's recompute closure is still being
/// replayed: the replan must fold the newcomer into placement without
/// losing any lineage work. Deterministic in the event core; the
/// threaded engine conserves the same task totals.
#[test]
fn join_during_active_recovery_replans_to_completion() {
    let w = workload::double_map_zip_agg(10, BLOCK_LEN);
    let total = w.task_count() as u64;
    let mk = || {
        let mut cfg = compare_cfg(PolicyKind::Lru, 4, 2);
        cfg.topology = TopologyPlan::kill_at(1, total / 2).then(TopologyEvent::Join {
            worker: WorkerId(2),
            at_dispatch: total / 2 + 2,
        });
        cfg
    };
    let a = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    let b = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    assert_eq!(a.recovery, b.recovery, "recovered sets diverged between sim runs");
    assert_eq!(a.scale, b.scale, "scale stats diverged between sim runs");
    assert_eq!(a.tasks_run, b.tasks_run);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.recovery.workers_killed, 1);
    assert_eq!(a.scale.workers_joined, 1);
    assert!(a.recovery.recompute_tasks > 0, "kill must cost lineage recomputes");
    assert_eq!(a.tasks_run, total + a.recovery.recompute_tasks);

    let real = ClusterEngine::new(mk()).run_workload(&w).unwrap();
    assert_eq!(real.recovery.workers_killed, 1);
    assert_eq!(real.scale.workers_joined, 1);
    assert_eq!(real.tasks_run, total + real.recovery.recompute_tasks);
}

/// A join while peer groups sit in the spill tier: spill fragments
/// re-home to the newcomer in the same all-or-nothing offers the spill
/// path uses, and subsequent group restores promote at the *new* home —
/// the run completes with the usual restore accounting intact.
#[test]
fn join_while_groups_spilled_restores_at_new_home() {
    let w = workload::double_map_zip_agg(12, BLOCK_LEN);
    let total = w.task_count() as u64;
    let mk = || {
        let mut cfg = compare_cfg(PolicyKind::Lru, 3, 2);
        cfg.spill = Some(SpillConfig::coordinated(32 * BLOCK_BYTES));
        cfg.topology = TopologyPlan::join_at(2, total / 2);
        cfg
    };
    let a = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    let b = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    assert_eq!(a.tier.spilled_log, b.tier.spilled_log, "sim not deterministic");
    assert_eq!(a.tier.restored_log, b.tier.restored_log);
    assert_eq!(a.scale, b.scale);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.scale.workers_joined, 1);
    assert!(a.tier.spilled_blocks > 0, "tight cache must spill under budget");
    assert!(
        a.tier.restored_blocks > 0 || a.tier.spill_reads > 0,
        "spilled inputs must be read back somewhere"
    );

    let real = ClusterEngine::new(mk()).run_workload(&w).unwrap();
    assert_eq!(real.scale.workers_joined, 1);
    assert_eq!(a.tasks_run, real.tasks_run, "sim and threaded disagree on work done");
}

/// The data-integrity pin behind the whole topology feature: sink bytes
/// are a pure function of the workload. A mixed kill/join plan may move
/// blocks and re-plan lineage, but the durable sink outputs must be
/// byte-identical to a plan-free run, and the event core must agree with
/// the threaded engine on the structural outcome.
#[test]
fn sink_outputs_byte_identical_under_mixed_topology_plans() {
    let queue = workload::multijob_zip_shared(2, 8, BLOCK_LEN, true, 4);
    let plan = || {
        TopologyPlan::kill_at(1, 6).then(TopologyEvent::Join {
            worker: WorkerId(2),
            at_dispatch: 10,
        })
    };
    let run = |dir: &Path, topo: TopologyPlan| {
        let mut cfg = compare_cfg(PolicyKind::Lerc, 4, 2);
        cfg.disk_dir = Some(dir.to_path_buf());
        cfg.topology = topo;
        Engine::run(&ClusterEngine::new(cfg), &queue).unwrap()
    };
    let d0 = TempDir::new("topo-mixed-0").unwrap();
    let d1 = TempDir::new("topo-mixed-1").unwrap();
    let d2 = TempDir::new("topo-mixed-2").unwrap();
    let flat = run(d0.path(), TopologyPlan::none());
    let p1 = run(d1.path(), plan());
    let p2 = run(d2.path(), plan());
    assert_eq!(p1.aggregate.scale.workers_joined, 1);
    assert_eq!(p1.aggregate.recovery.workers_killed, 1);
    assert_eq!(
        p1.aggregate.scale, p2.aggregate.scale,
        "threaded topology run not deterministic"
    );
    let (s0, s1, s2) = (read_store(d0.path()), read_store(d1.path()), read_store(d2.path()));
    for job in &queue.jobs {
        let id = job.workload.dags[0].job;
        for blk in sink_blocks(&job.workload) {
            let (base, _) = s0.read(blk).unwrap();
            let (x, _) = s1.read(blk).unwrap();
            let (y, _) = s2.read(blk).unwrap();
            assert_eq!(x, y, "sink {blk} of {id} diverged between planned runs");
            assert_eq!(x, base, "sink {blk} of {id} corrupted by the topology plan");
        }
    }
    // The event core runs the same plan to the same structural outcome.
    let mut sim_cfg = compare_cfg(PolicyKind::Lerc, 4, 2);
    sim_cfg.topology = plan();
    let sim = Engine::run(&Simulator::from_engine_config(sim_cfg), &queue).unwrap();
    assert_eq!(sim.aggregate.scale.workers_joined, 1);
    assert_eq!(sim.aggregate.recovery.workers_killed, 1);
    assert_eq!(sim.aggregate.tasks_run, p1.aggregate.tasks_run);
    assert_eq!(flat.aggregate.scale.workers_joined, 0);
}

/// The group-atomicity pin: every warm migration of a peer group is a
/// single all-or-nothing batch. The trace must show each migrated group
/// exactly once, with one (from, to) pair carrying all its blocks — a
/// split group would surface as the same group id migrating twice or the
/// accounting disagreeing with `ScaleStats`.
#[test]
fn join_never_splits_a_peer_group() {
    let w = workload::multi_tenant_zip(3, 6, BLOCK_LEN);
    let total = w.task_count() as u64;
    let (trace, rec) = TraceConfig::collect(1 << 14);
    let mut cfg = compare_cfg(PolicyKind::Lerc, 100, 2);
    cfg.trace = trace;
    cfg.topology = TopologyPlan::join_at(2, total / 2);
    let report = Simulator::from_engine_config(cfg).run_workload(&w).unwrap();
    assert_eq!(report.scale.workers_joined, 1);
    assert!(
        report.scale.blocks_migrated > 0,
        "an ample warm cache must re-home at least one block to the newcomer"
    );

    let events = rec.take();
    let mut joined = 0u64;
    let mut seen_groups: HashSet<u64> = HashSet::new();
    let mut migrated_events = 0u64;
    let mut migrated_blocks = 0u64;
    for r in &events {
        match &r.event {
            TraceEvent::WorkerJoined { worker } => {
                joined += 1;
                assert_eq!(*worker, WorkerId(2));
            }
            TraceEvent::GroupMigrated { group, from, to, blocks } => {
                migrated_events += 1;
                migrated_blocks += blocks;
                assert!(*blocks > 0, "empty migration batch for group {group:?}");
                assert_eq!(*to, WorkerId(2), "migration must target the joining worker");
                assert_ne!(from, to);
                assert!(
                    seen_groups.insert(group.0),
                    "group {group:?} migrated twice — a split batch"
                );
            }
            _ => {}
        }
    }
    assert_eq!(joined, 1, "exactly one worker_joined event");
    assert_eq!(
        migrated_events, report.scale.groups_migrated,
        "trace and ScaleStats disagree on atomic group moves"
    );
    assert!(
        migrated_blocks <= report.scale.blocks_migrated,
        "group-batch members exceed total migrated blocks"
    );
}

/// Autoscale smoke: a deep ready queue on a one-worker fleet must grow
/// it, the decisions replay deterministically, and the threaded engine
/// reaches the same fleet size from the same checkpoints.
#[test]
fn autoscale_grows_a_saturated_fleet_deterministically() {
    let w = workload::multi_tenant_zip(3, 8, BLOCK_LEN);
    let mk = || {
        let mut cfg = compare_cfg(PolicyKind::Lru, 100, 1);
        cfg.topology = TopologyPlan::autoscale(AutoscaleConfig {
            min_workers: 1,
            max_workers: 4,
            check_every: 4,
            scale_up_ready: 2,
            scale_down_ready: 0,
            mem_high: 1.1, // unreachable: decisions are purely ready-driven
            mem_low: 0.0,
        });
        cfg
    };
    let a = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    let b = Simulator::from_engine_config(mk()).run_workload(&w).unwrap();
    assert_eq!(a.scale, b.scale, "autoscale decisions diverged between sim runs");
    assert_eq!(a.makespan, b.makespan);
    assert!(a.scale.workers_joined >= 1, "saturated fleet never scaled up");
    assert_eq!(a.scale.workers_retired, 0, "scale-down disabled by thresholds");
    assert_eq!(a.tasks_run, w.task_count() as u64);

    let real = ClusterEngine::new(mk()).run_workload(&w).unwrap();
    assert_eq!(real.tasks_run, w.task_count() as u64);
    assert_eq!(
        real.scale.workers_joined, a.scale.workers_joined,
        "threaded autoscale reached a different fleet size"
    );
}

/// Builder-level plan validation: joins must name pending slots, a slot
/// joins at most once, kills cannot target still-pending slots, and
/// autoscale bounds must be sane. Legacy `failures` plans still build
/// (via the deprecated shim) and upgrade losslessly.
#[test]
fn builder_rejects_malformed_topology_plans() {
    let base = || {
        EngineConfig::builder()
            .num_workers(2)
            .block_len(BLOCK_LEN)
            .cache_blocks(8)
            .policy(PolicyKind::Lru)
    };
    // Join of an already-alive slot.
    assert!(base().topology(TopologyPlan::join_at(1, 4)).build().is_err());
    // Double join of the same pending slot.
    assert!(
        base()
            .topology(TopologyPlan::join_at(2, 4).then(TopologyEvent::Join {
                worker: WorkerId(2),
                at_dispatch: 8,
            }))
            .build()
            .is_err()
    );
    // Kill of a pending slot before its join fires.
    assert!(
        base()
            .topology(TopologyPlan::join_at(2, 8).then(TopologyEvent::Kill {
                worker: WorkerId(2),
                at_dispatch: 4,
                restart_after: None,
            }))
            .build()
            .is_err()
    );
    // Inverted autoscale bounds.
    assert!(
        base()
            .topology(TopologyPlan::autoscale(AutoscaleConfig {
                min_workers: 4,
                max_workers: 2,
                ..Default::default()
            }))
            .build()
            .is_err()
    );
    // A well-formed mixed plan builds.
    let cfg = base()
        .topology(TopologyPlan::kill_at(1, 4).then(TopologyEvent::Join {
            worker: WorkerId(2),
            at_dispatch: 6,
        }))
        .build()
        .unwrap();
    assert_eq!(cfg.worker_ceiling(), 3);
}
