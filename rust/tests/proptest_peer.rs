//! Property tests over the §III-C peer-tracking protocol: the
//! ≤1-broadcast-per-group bound, master/worker replica consistency, and
//! effective-count correctness against a brute-force model.

use lerc_engine::common::ids::{BlockId, DatasetId, GroupId, TaskId};
use lerc_engine::common::rng::SplitMix64;
use lerc_engine::dag::analysis::PeerGroup;
use lerc_engine::peer::{PeerTrackerMaster, WorkerPeerTracker};
use std::collections::{HashMap, HashSet};

fn b(i: u64) -> BlockId {
    BlockId::new(DatasetId(0), i as u32)
}

fn random_groups(rng: &mut SplitMix64, universe: u64) -> Vec<PeerGroup> {
    let n = 1 + rng.next_below(20);
    (0..n)
        .map(|g| {
            let arity = 1 + rng.next_below(3) as usize;
            let mut members = HashSet::new();
            while members.len() < arity {
                members.insert(b(rng.next_below(universe)));
            }
            PeerGroup {
                id: GroupId(g),
                task: TaskId(g),
                members: members.into_iter().collect(),
                output: b(1000 + g),
            }
        })
        .collect()
}

/// Brute-force model of the protocol: group state as plain sets.
struct Model {
    groups: Vec<(PeerGroup, bool, bool)>, // (group, complete, retired)
}

impl Model {
    fn new(groups: &[PeerGroup]) -> Self {
        Self {
            groups: groups.iter().map(|g| (g.clone(), true, false)).collect(),
        }
    }

    fn evict(&mut self, blk: BlockId) {
        for (g, complete, retired) in self.groups.iter_mut() {
            if *complete && !*retired && g.members.contains(&blk) {
                *complete = false;
            }
        }
    }

    fn retire(&mut self, task: TaskId) {
        for (g, _, retired) in self.groups.iter_mut() {
            if g.task == task {
                *retired = true;
            }
        }
    }

    fn effective_count(&self, blk: BlockId) -> u32 {
        self.groups
            .iter()
            .filter(|(g, complete, retired)| *complete && !*retired && g.members.contains(&blk))
            .count() as u32
    }
}

#[test]
fn tracker_matches_bruteforce_model_under_random_events() {
    for seed in 0..300u64 {
        let mut rng = SplitMix64::new(seed);
        let universe = 24;
        let groups = random_groups(&mut rng, universe);
        let mut tracker = WorkerPeerTracker::default();
        tracker.register(&groups, &[]);
        let mut model = Model::new(&groups);

        for _ in 0..100 {
            match rng.next_below(3) {
                0 => {
                    let blk = b(rng.next_below(universe));
                    tracker.apply_eviction_broadcast(blk);
                    model.evict(blk);
                }
                1 => {
                    let task = TaskId(rng.next_below(groups.len() as u64));
                    tracker.retire_task(task);
                    model.retire(task);
                }
                _ => {
                    let blk = b(rng.next_below(universe));
                    assert_eq!(
                        tracker.effective_count(blk),
                        model.effective_count(blk),
                        "seed={seed} block={blk}"
                    );
                }
            }
        }
        // Full final audit.
        for i in 0..universe {
            assert_eq!(
                tracker.effective_count(b(i)),
                model.effective_count(b(i)),
                "seed={seed} final block={i}"
            );
        }
    }
}

#[test]
fn at_most_one_broadcast_per_group_life() {
    for seed in 0..300u64 {
        let mut rng = SplitMix64::new(seed ^ 0xBEEF);
        let universe = 24;
        let groups = random_groups(&mut rng, universe);
        let mut master = PeerTrackerMaster::default();
        master.register(&groups);

        // Random storm of eviction reports (with duplicates) + retires.
        let mut broadcast_for_group: HashMap<GroupId, u32> = HashMap::new();
        let mut retired: HashSet<TaskId> = HashSet::new();
        for _ in 0..200 {
            if rng.next_below(10) == 0 {
                let t = TaskId(rng.next_below(groups.len() as u64));
                master.retire_task(t);
                retired.insert(t);
                continue;
            }
            let blk = b(rng.next_below(universe));
            // Snapshot which live groups are complete AND contain blk.
            let affected: Vec<GroupId> = groups
                .iter()
                .filter(|g| {
                    g.members.contains(&blk)
                        && !retired.contains(&g.task)
                        && master.group_complete(g.task) == Some(true)
                })
                .map(|g| g.id)
                .collect();
            let decision = master.on_eviction_report(blk);
            if decision.is_some() {
                assert!(!affected.is_empty(), "seed={seed}: broadcast with no group");
                for gid in affected {
                    *broadcast_for_group.entry(gid).or_default() += 1;
                }
            }
        }
        for (gid, n) in &broadcast_for_group {
            assert_eq!(*n, 1, "seed={seed}: group {gid} invalidated {n} times");
        }
        assert!(
            master.stats.broadcasts_sent <= groups.len() as u64,
            "seed={seed}: {} broadcasts > {} groups",
            master.stats.broadcasts_sent,
            groups.len()
        );
    }
}

#[test]
fn master_and_worker_replicas_stay_consistent() {
    for seed in 0..200u64 {
        let mut rng = SplitMix64::new(seed ^ 0xC0FFEE);
        let universe = 24;
        let groups = random_groups(&mut rng, universe);
        let mut master = PeerTrackerMaster::default();
        master.register(&groups);
        let mut workers: Vec<WorkerPeerTracker> = (0..3)
            .map(|_| {
                let mut t = WorkerPeerTracker::default();
                t.register(&groups, &[]);
                t
            })
            .collect();

        for _ in 0..150 {
            if rng.next_below(5) == 0 {
                let task = TaskId(rng.next_below(groups.len() as u64));
                master.retire_task(task);
                for w in workers.iter_mut() {
                    w.retire_task(task);
                }
            } else {
                let blk = b(rng.next_below(universe));
                // Protocol: report goes to master; workers only act on the
                // resulting broadcast.
                if let Some(bc) = master.on_eviction_report(blk) {
                    for w in workers.iter_mut() {
                        w.apply_eviction_broadcast(bc);
                    }
                }
            }
        }
        // All replicas agree on group completeness with the master.
        for g in &groups {
            let m = master.group_complete(g.task);
            for (wi, w) in workers.iter().enumerate() {
                assert_eq!(
                    w.group_complete(g.task),
                    m,
                    "seed={seed}: worker {wi} diverged on {:?}",
                    g.id
                );
            }
        }
    }
}

#[test]
fn effective_count_never_exceeds_group_membership() {
    for seed in 0..200u64 {
        let mut rng = SplitMix64::new(seed ^ 0xFACADE);
        let universe = 16;
        let groups = random_groups(&mut rng, universe);
        let mut t = WorkerPeerTracker::default();
        t.register(&groups, &[]);
        let membership: HashMap<BlockId, u32> = {
            let mut m: HashMap<BlockId, u32> = HashMap::new();
            for g in &groups {
                for blk in &g.members {
                    *m.entry(*blk).or_default() += 1;
                }
            }
            m
        };
        for _ in 0..80 {
            let blk = b(rng.next_below(universe));
            let eff = t.effective_count(blk);
            assert!(
                eff <= membership.get(&blk).copied().unwrap_or(0),
                "seed={seed}: eff {eff} exceeds membership"
            );
            if rng.next_below(2) == 0 {
                t.apply_eviction_broadcast(blk);
            }
        }
    }
}

#[test]
fn broadcast_deltas_report_exact_new_counts() {
    for seed in 0..200u64 {
        let mut rng = SplitMix64::new(seed ^ 0xDE17A);
        let universe = 16;
        let groups = random_groups(&mut rng, universe);
        let mut t = WorkerPeerTracker::default();
        t.register(&groups, &[]);
        for _ in 0..40 {
            let blk = b(rng.next_below(universe));
            let (deltas, _) = t.apply_eviction_broadcast(blk);
            for (m, count) in deltas {
                assert_eq!(
                    count,
                    t.effective_count(m),
                    "seed={seed}: stale delta for {m}"
                );
            }
        }
    }
}
