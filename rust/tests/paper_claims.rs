//! Every checkable claim the paper makes, as a test. These are the
//! acceptance criteria of the reproduction (EXPERIMENTS.md documents the
//! measured values).

use lerc_engine::Engine;
use lerc_engine::common::config::{EngineConfig, PolicyKind};
use lerc_engine::harness::experiments::{
    comm_overhead, fig3_all_or_nothing, fig5_6_7_sweep, sticky_single_decision, toy_fig1_table,
    ExpOptions,
};
use lerc_engine::sim::Simulator;
use lerc_engine::workload;

fn paper_opts() -> ExpOptions {
    // Scaled paper geometry (fast enough for CI, same cache-fraction axis).
    ExpOptions {
        workers: 4,
        tenants: 6,
        blocks_per_file: 20,
        block_len: 4096,
        fractions: vec![0.33, 0.5, 0.66],
        policies: PolicyKind::PAPER.to_vec(),
        seed: 17,
    }
}

/// §I / Fig 1: "block c is the only right choice of eviction … with LERC,
/// block c is evicted, which is the optimal decision."
#[test]
fn claim_fig1_lerc_evicts_c() {
    let rows = toy_fig1_table(&[PolicyKind::Lerc]);
    assert_eq!(rows[0].evicted, "c");
    assert!((rows[0].effective_hit_ratio - 0.5).abs() < 1e-9);
}

/// §II-C / Fig 3: "despite the linearly growing cache hit ratio … task
/// completion time is notably reduced only after the two peering blocks
/// have been cached."
#[test]
fn claim_fig3_all_or_nothing_staircase() {
    let rows = fig3_all_or_nothing(10, 4096).unwrap();
    // Linear hit ratio.
    for (k, r) in rows.iter().enumerate() {
        assert!((r.hit_ratio - k as f64 / 20.0).abs() < 1e-9, "k={k}");
    }
    // Steps only on completed pairs.
    let base = rows[0].total_runtime.as_secs_f64();
    for k in (1..rows.len()).step_by(2) {
        let d = rows[k - 1].total_runtime.as_secs_f64() - rows[k].total_runtime.as_secs_f64();
        assert!(d.abs() < 0.02 * base, "half-pair k={k} moved runtime");
    }
    for k in (2..rows.len()).step_by(2) {
        let d = rows[k - 1].total_runtime.as_secs_f64() - rows[k].total_runtime.as_secs_f64();
        assert!(d > 0.0, "completed pair k={k} did not reduce runtime");
    }
}

/// §IV-A / Fig 5: "as the size of RDD cache increases, total experiment
/// runtime decreases under all three policies", "LRC consistently
/// outperforms LRU" (weak form: never worse), and "LERC further reduces
/// the completion time over LRC".
#[test]
fn claim_fig5_runtime_ordering() {
    let rows = fig5_6_7_sweep(&paper_opts()).unwrap();
    let get = |f: f64, p: &str| {
        rows.iter()
            .find(|r| (r.cache_fraction - f).abs() < 1e-3 && r.policy == p)
            .unwrap()
    };
    for &f in &paper_opts().fractions {
        assert!(get(f, "LERC").makespan_s <= get(f, "LRC").makespan_s + 1e-9);
        assert!(get(f, "LRC").makespan_s <= get(f, "LRU").makespan_s + 1e-9);
    }
    // Monotone improvement with cache size, per policy.
    for p in ["LRU", "LRC", "LERC"] {
        let times: Vec<f64> = paper_opts()
            .fractions
            .iter()
            .map(|&f| get(f, p).makespan_s)
            .collect();
        for w in times.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "{p}: runtime not monotone in cache");
        }
    }
}

/// §IV headline: "LERC speeds up job completion by up to 37% and 19%
/// compared to LRU and LRC" — the shape requirement is a double-digit
/// gain vs LRU and a positive gain vs LRC at the 2/3-cache point.
#[test]
fn claim_headline_speedups() {
    let rows = fig5_6_7_sweep(&paper_opts()).unwrap();
    let get = |p: &str| {
        rows.iter()
            .find(|r| (r.cache_fraction - 0.66).abs() < 1e-3 && r.policy == p)
            .unwrap()
            .makespan_s
    };
    let vs_lru = 100.0 * (1.0 - get("LERC") / get("LRU"));
    let vs_lrc = 100.0 * (1.0 - get("LERC") / get("LRC"));
    assert!(vs_lru >= 15.0, "LERC vs LRU gain {vs_lru:.1}% too small");
    assert!(vs_lrc >= 0.0, "LERC vs LRC gain {vs_lrc:.1}% negative");
}

/// §IV-B / Fig 6: "LRC achieves the highest cache hit ratio, while LERC
/// closely follows" (LERC within a whisker, never above LRC).
#[test]
fn claim_fig6_hit_ratio_ordering() {
    let rows = fig5_6_7_sweep(&paper_opts()).unwrap();
    for &f in &paper_opts().fractions {
        let get = |p: &str| {
            rows.iter()
                .find(|r| (r.cache_fraction - f).abs() < 1e-3 && r.policy == p)
                .unwrap()
        };
        assert!(get("LRC").hit_ratio >= get("LERC").hit_ratio - 1e-9, "f={f}");
        assert!(get("LRC").hit_ratio >= get("LRU").hit_ratio - 1e-9, "f={f}");
        assert!(
            get("LRC").hit_ratio - get("LERC").hit_ratio < 0.1,
            "LERC should closely follow LRC at f={f}"
        );
    }
}

/// §IV-B / Fig 7: "LERC always achieves the highest effective cache hit
/// ratio. The smaller the cache, the more advantageous LERC is." Plus:
/// "the effective cache hit ratio of LRU is always near zero."
#[test]
fn claim_fig7_effective_ratio() {
    let opts = paper_opts();
    let rows = fig5_6_7_sweep(&opts).unwrap();
    let get = |f: f64, p: &str| {
        rows.iter()
            .find(|r| (r.cache_fraction - f).abs() < 1e-3 && r.policy == p)
            .unwrap()
    };
    let mut advantage = Vec::new();
    for &f in &opts.fractions {
        let lerc = get(f, "LERC").effective_hit_ratio;
        let lrc = get(f, "LRC").effective_hit_ratio;
        let lru = get(f, "LRU").effective_hit_ratio;
        assert!(lerc >= lrc - 1e-9, "f={f}");
        assert!(lerc >= lru - 1e-9, "f={f}");
        assert!(lru < 0.05, "LRU effective ratio {lru} not near zero at f={f}");
        advantage.push(lerc - lrc);
    }
    // Convergence: as the cache grows, LRC closes on LERC, so the
    // advantage at the LARGEST cache must not be the maximum.
    let max_before_last = advantage[..advantage.len() - 1]
        .iter()
        .cloned()
        .fold(f64::MIN, f64::max);
    assert!(
        *advantage.last().unwrap() <= max_before_last + 1e-9,
        "LERC advantage should shrink as cache grows: {advantage:?}"
    );
}

/// §IV-B: "the effective cache hit ratio serves as a more relevant metric"
/// — effective ratio must rank policies by runtime where hit ratio fails.
#[test]
fn claim_effective_ratio_is_the_relevant_metric() {
    let rows = fig5_6_7_sweep(&paper_opts()).unwrap();
    for &f in &paper_opts().fractions {
        let series: Vec<_> = rows
            .iter()
            .filter(|r| (r.cache_fraction - f).abs() < 1e-3)
            .collect();
        // Sort by runtime ascending; effective ratio must be descending.
        let mut by_time = series.clone();
        by_time.sort_by(|a, b| a.makespan_s.partial_cmp(&b.makespan_s).unwrap());
        for w in by_time.windows(2) {
            assert!(
                w[0].effective_hit_ratio >= w[1].effective_hit_ratio - 1e-9,
                "f={f}: faster policy had lower effective ratio"
            );
        }
        // Plain hit ratio does NOT rank runtime at small caches: LRC ties
        // LERC on hits but LERC is faster (checked above) — i.e. hit
        // ratio alone cannot explain the runtime order. Nothing to assert
        // beyond the effective-metric consistency.
    }
}

/// §III-C: "at most one broadcasting is triggered for the entire group of
/// peer blocks", cluster-wide, across cache pressures.
#[test]
fn claim_protocol_message_bound() {
    let opts = paper_opts();
    for row in comm_overhead(&opts).unwrap() {
        assert!(row.broadcasts <= row.peer_groups);
        assert!(row.eviction_reports >= row.broadcasts);
    }
}

/// §III-A: the sticky strawman surrenders a shared block that still has
/// effective references; LERC keeps it.
#[test]
fn claim_sticky_strawman_inefficiency() {
    let decision = sticky_single_decision();
    let lerc = decision.iter().find(|(p, _)| p == "LERC").unwrap().1;
    let sticky = decision.iter().find(|(p, _)| p == "Sticky").unwrap().1;
    assert!(lerc > sticky);
}

/// §II-B: cross-validation-style reuse — DAG-aware policies must keep the
/// high-reference training set and beat LRU.
#[test]
fn claim_lrc_motivating_workload() {
    let w = workload::cross_validation(5, 16, 4096);
    let input = w.input_bytes();
    let run = |policy| {
        let cfg = EngineConfig::builder()
            .num_workers(4)
            .cache_capacity_per_worker(input / 2 / 4)
            .block_len(4096)
            .policy(policy)
            .build()
            .expect("valid config");
        Simulator::from_engine_config(cfg).run_workload(&w).unwrap()
    };
    let lru = run(PolicyKind::Lru);
    let lrc = run(PolicyKind::Lrc);
    assert!(lrc.hit_ratio() > lru.hit_ratio());
    assert!(lrc.compute_makespan <= lru.compute_makespan);
}
