//! Regression coverage for the DESIGN.md §1 scope note: with
//! `overlap_ingest` on, an ingest-triggered eviction can observe a
//! ref-count one drain cycle staler under HomeRouted than under
//! Broadcast, and an invalidation broadcast can race a worker's
//! `pin_group` on the same blocks. The staleness is allowed to change
//! which victim a policy picks (documented divergence); what it must
//! NEVER do is corrupt state: no partial group pins, no lost blocks, no
//! accounting drift, no stall. These tests pin that soundness bar.

use lerc_engine::Engine;
use lerc_engine::cache::policy::PolicyEvent;
use lerc_engine::cache::sharded::ShardedStore;
use lerc_engine::common::config::{CtrlPlane, DiskConfig, EngineConfig, NetConfig, PolicyKind};
use lerc_engine::common::ids::{BlockId, DatasetId, GroupId};
use lerc_engine::driver::ClusterEngine;
use lerc_engine::workload;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn overlap_cfg(
    policy: PolicyKind,
    cache_blocks: u64,
    workers: u32,
    mode: CtrlPlane,
) -> EngineConfig {
    EngineConfig::builder()
        .num_workers(workers)
        .block_len(4096)
        .cache_blocks(cache_blocks)
        .policy(policy)
        .disk(DiskConfig {
            unthrottled: true,
            ..Default::default()
        })
        .net(NetConfig {
            per_message_latency: Duration::ZERO,
        })
        .overlap_ingest(true)
        .ctrl_plane(mode)
        .build()
        .expect("valid config")
}

/// End-to-end: ingest-triggered evictions race coalesced ref-count
/// flushes for the whole run (tasks dispatch mid-ingest). Every policy
/// and both planes must complete with conserved accounting and sane
/// effective-hit bounds — staleness may shift decisions, never soundness.
#[test]
fn overlap_ingest_races_stay_sound() {
    let w = workload::multi_tenant_zip(4, 8, 4096);
    for mode in [CtrlPlane::Broadcast, CtrlPlane::HomeRouted] {
        for policy in [PolicyKind::Lerc, PolicyKind::Lrc, PolicyKind::Sticky] {
            for workers in [2u32, 4] {
                let cfg = overlap_cfg(policy, 3, workers, mode);
                let r = ClusterEngine::new(cfg).run_workload(&w).unwrap();
                let tag = format!("{} {:?} w={workers}", policy.name(), mode);
                assert_eq!(r.tasks_run, 32, "{tag}");
                let a = &r.access;
                assert_eq!(a.accesses, a.mem_hits + a.disk_reads, "{tag}: leaked access");
                assert!(a.effective_hits <= a.mem_hits, "{tag}: effective > hits");
                assert_eq!(a.accesses, 64, "{tag}: every task reads its two inputs");
            }
        }
    }
}

/// The pin-vs-invalidation race at the store level: one thread pins and
/// unpins whole groups (the worker's task path), another floods inserts
/// that trigger evictions (the ingest path), a third fires the
/// invalidation events a racing broadcast would deliver. Pinning a
/// just-invalidated group is *allowed* (invalidation is metadata; the
/// blocks are still resident) — but the all-or-nothing pin invariant
/// must hold at every instant and no pin may leak.
#[test]
fn pin_group_vs_invalidation_vs_eviction_stress() {
    let b = |i: u32| BlockId::new(DatasetId(0), i);
    // Room for ~24 of the 64 churn blocks per run: real eviction pressure.
    let store = Arc::new(ShardedStore::new(24 * 64 * 4, PolicyKind::Lerc, 4));
    let stop = Arc::new(AtomicBool::new(false));

    // Pinner: group-pin pairs out of the low block range, like a task
    // pinning its peer-group, then release.
    let pinner = {
        let store = store.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut pinned_ok = 0u64;
            let mut round = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let gid = GroupId(round % 8);
                let i = (round % 8) as u32 * 2;
                let members = [b(i), b(i + 1)];
                if store.pin_group(gid, &members) {
                    pinned_ok += 1;
                    // While pinned, the invariant must hold.
                    store.check_group_invariants().expect("partial pin observed");
                    store.unpin_group(gid);
                }
                round += 1;
            }
            pinned_ok
        })
    };

    // Evictor: churn inserts through the same capacity.
    let evictor = {
        let store = store.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut i = 0u32;
            while !stop.load(Ordering::Relaxed) {
                let idx = i % 64;
                store.insert(b(idx), Arc::from(vec![0.5f32; 64]));
                i = i.wrapping_add(1);
            }
        })
    };

    // Invalidator: deliver the broadcasts a racing eviction would cause.
    let invalidator = {
        let store = store.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut i = 0u32;
            while !stop.load(Ordering::Relaxed) {
                let g = (i % 8) * 2;
                let members = [b(g), b(g + 1)];
                store.policy_event(PolicyEvent::GroupBroken { members: &members });
                for &m in &members {
                    store.policy_event(PolicyEvent::EffectiveCount { block: m, count: 0 });
                }
                i = i.wrapping_add(1);
            }
        })
    };

    // Main thread audits the invariant throughout.
    let t0 = std::time::Instant::now();
    while t0.elapsed() < Duration::from_millis(400) {
        store.check_group_invariants().expect("invariant broken under race");
    }
    stop.store(true, Ordering::Relaxed);
    let pinned_ok = pinner.join().unwrap();
    evictor.join().unwrap();
    invalidator.join().unwrap();

    // All pins released; store internally consistent.
    assert_eq!(store.pinned_group_count(), 0);
    assert_eq!(store.pinned_count(), 0);
    store.check_invariants().unwrap();
    assert!(pinned_ok > 0, "the pinner never got a full group — no race coverage");
}
