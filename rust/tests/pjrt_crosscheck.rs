//! Integration: the PJRT path (HLO-text artifact → compile → execute)
//! must agree with the pure-Rust synthetic oracle on every task kind.
//!
//! Requires `make artifacts` to have run; tests skip (pass vacuously) if
//! the artifacts directory is missing so `cargo test` works pre-build.

use lerc_engine::common::rng::SplitMix64;
use lerc_engine::runtime::{ComputeEngine, PjrtEngine, SyntheticEngine};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.tsv").exists().then_some(dir)
}

fn payload(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.next_f32_signed()).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let denom = x.abs().max(y.abs()).max(1.0);
        assert!(
            (x - y).abs() / denom <= tol,
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn pjrt_matches_synthetic_on_all_task_kinds() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let pjrt = PjrtEngine::load(&dir).expect("pjrt engine");
    let synth = SyntheticEngine::new();
    let n = 4096;
    let a = payload(1, n);
    let b = payload(2, n);

    for kind in [
        "zip_task",
        "coalesce_task",
        "agg_task",
        "partition_task",
        "zip_reduce_task",
        "map_task",
    ] {
        let arity = pjrt.manifest().get(kind, n).unwrap().arity;
        let inputs: Vec<&[f32]> = if arity == 2 {
            vec![&a, &b]
        } else {
            vec![&a]
        };
        let got = pjrt.execute(kind, n, &inputs).expect(kind);
        let want = synth.execute(kind, n, &inputs).expect(kind);
        if kind == "partition_task" {
            // Bit-cast i32 ids must match exactly.
            assert_eq!(got.payload, want.payload, "{kind} ids");
        } else {
            assert_close(&got.payload, &want.payload, 1e-5, kind);
        }
        assert_close(&got.stats, &want.stats, 1e-3, &format!("{kind} stats"));
    }
}

#[test]
fn pjrt_warmup_compiles_everything() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let pjrt = PjrtEngine::load(&dir).expect("pjrt engine");
    let n = pjrt.warmup().expect("warmup");
    assert!(n >= 12, "expected >= 12 artifacts, compiled {n}");
}

#[test]
fn compute_handle_serves_pjrt_across_threads() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    use lerc_engine::runtime::pjrt::ComputeHandle;
    use std::sync::Arc;

    let (handle, service) = ComputeHandle::spawn(move || PjrtEngine::load(&dir)).unwrap();
    let _service = service.with_handle(handle.clone());

    let mut joins = vec![];
    for t in 0..4 {
        let h = handle.clone();
        joins.push(std::thread::spawn(move || {
            let a = Arc::new(payload(t, 4096));
            let b = Arc::new(payload(t + 100, 4096));
            let out = h.execute("zip_task", 4096, vec![a.clone(), b.clone()]).unwrap();
            assert_eq!(out.payload.len(), 2 * 4096);
            // Spot-check interleaving.
            assert_eq!(out.payload[0], a[0]);
            assert_eq!(out.payload[1], b[0]);
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}
