//! Contended fair-share network model (DESIGN.md §6).
//!
//! Each worker owns three links: an ingress NIC, an egress NIC, and a
//! local-disk channel. A transfer is a *flow* crossing up to three
//! links (e.g. a spilled-block read served remotely crosses the home's
//! disk and egress plus the reader's ingress); concurrent flows on a
//! link share its bandwidth equally, so a flow's rate is
//! `min(max_rate, min over links of bw/flows_on_link)` — the dslab
//! `throughput-model` pattern, with completion estimates recomputed on
//! every flow arrival and departure.
//!
//! Bookkeeping is lazy: progress accrues per flow only when its rate
//! changes (an arrival/departure touched one of its links) or when it
//! completes, and completion estimates live in a binary heap with
//! per-flow generation stamps so superseded entries are skipped rather
//! than removed. Rate changes therefore cost O(flows sharing the
//! touched links · log flows), not O(all flows), which is what lets
//! `benches/event_scale.rs` push thousands of workers.

use crate::common::config::LinkConfig;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::metrics::NetStats;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Duration;

/// What a completed flow unblocks (returned from [`FairShareNet::advance`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowTag {
    /// An input fetch for the op running at this worker.
    TaskRead { worker: u32 },
    /// A pre-dispatch group-restore read for the task with this raw id.
    Restore { task: u64 },
    /// Fire-and-forget traffic (async demote writes): nothing waits on
    /// it, but it still occupies its links.
    Background,
}

/// The links a flow crosses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Remote memory read: source egress + destination ingress.
    Remote { src: u32, dst: u32 },
    /// External/durable read landing at `dst` (recovery reloads,
    /// fallback durable reads): destination ingress only.
    Ingress { dst: u32 },
    /// Local disk traffic at `home` (restore reads, demote writes).
    Disk { home: u32 },
    /// Spilled-block read served across the network: home disk + home
    /// egress + destination ingress.
    DiskRemote { home: u32, dst: u32 },
}

struct Link {
    /// Bandwidth in bytes per nanosecond.
    bw: f64,
    flows: FxHashSet<u64>,
    /// Total bytes carried by completed flows (utilization accounting).
    bytes: u64,
}

struct Flow {
    links: [u32; 3],
    nlinks: u8,
    /// Bytes left to transfer (fractional while rates shift).
    remaining: f64,
    /// Fixed latency nanos burned before the transfer proper.
    fixed_left: u64,
    /// Current rate in bytes per nanosecond.
    rate: f64,
    /// Source-side cap in bytes per nanosecond (e.g. memory bandwidth).
    max_rate: f64,
    /// Last time `remaining`/`fixed_left` were accrued to.
    last_t: u64,
    start_t: u64,
    /// Uncontended duration (fixed + bytes at the bottleneck rate):
    /// the baseline that defines this flow's queueing delay.
    ideal_nanos: u64,
    bytes: u64,
    tag: FlowTag,
    /// Bumped on every rate change; stale heap entries carry old gens.
    gen: u64,
}

/// Fair-share link set for one simulated cluster.
pub struct FairShareNet {
    links: Vec<Link>,
    flows: FxHashMap<u64, Flow>,
    /// (estimated completion, flow id, flow gen) — min-heap.
    heap: BinaryHeap<Reverse<(u64, u64, u64)>>,
    next_id: u64,
    stat_flows: u64,
    stat_bytes: u64,
    queue_nanos: u64,
}

impl FairShareNet {
    /// `disk_bandwidth` prices each worker's disk channel (the same
    /// number `DiskConfig::io_cost` charges in flat mode, minus the
    /// per-op seek, which callers pass as the flow's fixed latency).
    pub fn new(workers: u32, link: LinkConfig, disk_bandwidth: u64) -> Self {
        let mut links = Vec::with_capacity(workers as usize * 3);
        let mk = |bps: u64| Link {
            bw: bps as f64 / 1e9,
            flows: FxHashSet::default(),
            bytes: 0,
        };
        for _ in 0..workers {
            links.push(mk(link.ingress_bytes_per_sec));
            links.push(mk(link.egress_bytes_per_sec));
            links.push(mk(disk_bandwidth));
        }
        Self {
            links,
            flows: FxHashMap::default(),
            heap: BinaryHeap::new(),
            next_id: 0,
            stat_flows: 0,
            stat_bytes: 0,
            queue_nanos: 0,
        }
    }

    fn ingress(w: u32) -> u32 {
        3 * w
    }

    fn egress(w: u32) -> u32 {
        3 * w + 1
    }

    fn disk(w: u32) -> u32 {
        3 * w + 2
    }

    fn resolve(route: Route) -> ([u32; 3], usize) {
        match route {
            Route::Remote { src, dst } => ([Self::egress(src), Self::ingress(dst), 0], 2),
            Route::Ingress { dst } => ([Self::ingress(dst), 0, 0], 1),
            Route::Disk { home } => ([Self::disk(home), 0, 0], 1),
            Route::DiskRemote { home, dst } => {
                ([Self::disk(home), Self::egress(home), Self::ingress(dst)], 3)
            }
        }
    }

    /// Start a flow of `bytes` over `route`, capped at
    /// `max_rate_bytes_per_sec` (the source medium's bandwidth), after
    /// a `fixed` latency (seek / per-message latency). Rates of every
    /// flow sharing the touched links are recomputed.
    pub fn start(
        &mut self,
        now: u64,
        bytes: u64,
        route: Route,
        max_rate_bytes_per_sec: u64,
        fixed: Duration,
        tag: FlowTag,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let (links, nlinks) = Self::resolve(route);
        let max_rate = max_rate_bytes_per_sec as f64 / 1e9;
        let mut ideal_rate = max_rate;
        for &l in &links[..nlinks] {
            ideal_rate = ideal_rate.min(self.links[l as usize].bw);
        }
        let fixed_nanos = fixed.as_nanos() as u64;
        debug_assert!(ideal_rate > 0.0, "zero-bandwidth link in fair-share model");
        let ideal_nanos = fixed_nanos + (bytes as f64 / ideal_rate).ceil() as u64;
        for &l in &links[..nlinks] {
            self.links[l as usize].flows.insert(id);
        }
        self.flows.insert(
            id,
            Flow {
                links,
                nlinks: nlinks as u8,
                remaining: bytes as f64,
                fixed_left: fixed_nanos,
                rate: 0.0,
                max_rate,
                last_t: now,
                start_t: now,
                ideal_nanos,
                bytes,
                tag,
                gen: 0,
            },
        );
        self.stat_flows += 1;
        self.stat_bytes += bytes;
        let affected = self.affected_by(&links[..nlinks]);
        self.recompute(&affected, now);
        id
    }

    /// Earliest in-flight completion time, if any transfer is in flight.
    pub fn next_completion_time(&mut self) -> Option<u64> {
        loop {
            let &Reverse((est, id, gen)) = self.heap.peek()?;
            match self.flows.get(&id) {
                Some(f) if f.gen == gen => return Some(est),
                _ => {
                    self.heap.pop();
                }
            }
        }
    }

    /// Complete every flow whose (current) estimate is due at `now`,
    /// free its link shares, recompute survivors, and return what the
    /// completions unblock, in deterministic (time, start-order) order.
    pub fn advance(&mut self, now: u64) -> Vec<FlowTag> {
        let mut done = Vec::new();
        let mut touched: Vec<u32> = Vec::new();
        loop {
            let Some(&Reverse((est, id, gen))) = self.heap.peek() else {
                break;
            };
            match self.flows.get(&id) {
                Some(f) if f.gen == gen => {
                    if est > now {
                        break;
                    }
                }
                _ => {
                    self.heap.pop();
                    continue;
                }
            }
            self.heap.pop();
            let f = self.flows.remove(&id).expect("live flow");
            let served = est.saturating_sub(f.start_t);
            self.queue_nanos += served.saturating_sub(f.ideal_nanos);
            for &l in &f.links[..f.nlinks as usize] {
                let link = &mut self.links[l as usize];
                link.flows.remove(&id);
                link.bytes += f.bytes;
                touched.push(l);
            }
            done.push(f.tag);
        }
        if !touched.is_empty() {
            touched.sort_unstable();
            touched.dedup();
            let affected = self.affected_by(&touched);
            self.recompute(&affected, now);
        }
        done
    }

    pub fn in_flight(&self) -> usize {
        self.flows.len()
    }

    /// Cumulative bytes across all flows started so far (the telemetry
    /// sampler's monotone link-traffic counter — see DESIGN.md §10).
    pub fn carried_bytes(&self) -> u64 {
        self.stat_bytes
    }

    /// Roll up link/flow accounting. `horizon_nanos` (the run's
    /// makespan) normalizes per-link carried bytes into utilizations.
    pub fn stats(&self, horizon_nanos: u64) -> NetStats {
        let mut max_u = 0.0f64;
        let mut sum = 0.0f64;
        if horizon_nanos > 0 {
            for l in &self.links {
                let cap = l.bw * horizon_nanos as f64;
                let u = if cap > 0.0 { l.bytes as f64 / cap } else { 0.0 };
                max_u = max_u.max(u);
                sum += u;
            }
        }
        let n = self.links.len().max(1) as f64;
        NetStats {
            flows: self.stat_flows,
            bytes: self.stat_bytes,
            queueing_nanos: self.queue_nanos,
            max_link_utilization: max_u,
            mean_link_utilization: sum / n,
        }
    }

    /// Every flow sharing any of `links` (sorted, deduped).
    fn affected_by(&self, links: &[u32]) -> Vec<u64> {
        let mut ids: Vec<u64> = Vec::new();
        for &l in links {
            ids.extend(self.links[l as usize].flows.iter().copied());
        }
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Accrue each affected flow to `now` under its old rate, then
    /// re-derive its fair share and push a fresh completion estimate.
    fn recompute(&mut self, ids: &[u64], now: u64) {
        for &id in ids {
            let f = self.flows.get_mut(&id).expect("affected flow is live");
            let mut dt = now.saturating_sub(f.last_t);
            f.last_t = now;
            if f.fixed_left > 0 {
                let burn = f.fixed_left.min(dt);
                f.fixed_left -= burn;
                dt -= burn;
            }
            if dt > 0 {
                f.remaining -= dt as f64 * f.rate;
                if f.remaining < 0.0 {
                    f.remaining = 0.0;
                }
            }
            let mut rate = f.max_rate;
            for &l in &f.links[..f.nlinks as usize] {
                let link = &self.links[l as usize];
                rate = rate.min(link.bw / link.flows.len().max(1) as f64);
            }
            f.rate = rate;
            f.gen += 1;
            let xfer = if f.remaining > 0.0 {
                (f.remaining / rate).ceil() as u64
            } else {
                0
            };
            let est = now + f.fixed_left + xfer;
            self.heap.push(Reverse((est, id, f.gen)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // 1 byte per nanosecond on every link: transfer nanos == bytes.
    const GBNS: u64 = 1_000_000_000;

    fn net(workers: u32) -> FairShareNet {
        FairShareNet::new(
            workers,
            LinkConfig {
                ingress_bytes_per_sec: GBNS,
                egress_bytes_per_sec: GBNS,
            },
            GBNS,
        )
    }

    fn drain(n: &mut FairShareNet) -> Vec<(u64, FlowTag)> {
        let mut out = Vec::new();
        while let Some(t) = n.next_completion_time() {
            for tag in n.advance(t) {
                out.push((t, tag));
            }
        }
        out
    }

    #[test]
    fn uncontended_flow_finishes_at_ideal_time() {
        let mut n = net(2);
        n.start(
            0,
            1000,
            Route::Remote { src: 0, dst: 1 },
            GBNS,
            Duration::from_nanos(100),
            FlowTag::Background,
        );
        let done = drain(&mut n);
        assert_eq!(done, vec![(1100, FlowTag::Background)]);
        assert_eq!(n.in_flight(), 0);
        let s = n.stats(1100);
        assert_eq!(s.flows, 1);
        assert_eq!(s.bytes, 1000);
        assert_eq!(s.queueing_nanos, 0);
    }

    #[test]
    fn two_flows_share_a_link_and_departure_speeds_the_survivor() {
        let mut n = net(2);
        // Both land on worker 1's ingress: fair share = half rate each.
        n.start(
            0,
            1000,
            Route::Ingress { dst: 1 },
            GBNS,
            Duration::ZERO,
            FlowTag::TaskRead { worker: 1 },
        );
        n.start(
            0,
            500,
            Route::Ingress { dst: 1 },
            GBNS,
            Duration::ZERO,
            FlowTag::Background,
        );
        // Short flow: 500 bytes at 0.5 B/ns = t=1000. Long flow then has
        // 500 bytes left at full rate: t=1500 — exactly the link's
        // 1500-byte serialization bound.
        let done = drain(&mut n);
        assert_eq!(
            done,
            vec![
                (1000, FlowTag::Background),
                (1500, FlowTag::TaskRead { worker: 1 })
            ]
        );
        let s = n.stats(1500);
        // Long flow ideal 1000, served 1500; short ideal 500, served 1000.
        assert_eq!(s.queueing_nanos, 1000);
        assert!(s.max_link_utilization > 0.99 && s.max_link_utilization <= 1.0);
    }

    #[test]
    fn arrival_slows_an_in_flight_transfer() {
        let mut n = net(2);
        n.start(
            0,
            1000,
            Route::Ingress { dst: 0 },
            GBNS,
            Duration::ZERO,
            FlowTag::TaskRead { worker: 0 },
        );
        assert_eq!(n.next_completion_time(), Some(1000));
        // Halfway through, a second flow contends: 500 bytes left now
        // move at half rate → finish at 500 + 1000 = 1500.
        n.start(
            500,
            2000,
            Route::Ingress { dst: 0 },
            GBNS,
            Duration::ZERO,
            FlowTag::Background,
        );
        assert_eq!(n.next_completion_time(), Some(1500));
    }

    #[test]
    fn max_rate_caps_below_link_bandwidth() {
        let mut n = net(1);
        // Source cap at 0.25 B/ns: 1000 bytes take 4000 ns even alone.
        n.start(
            0,
            1000,
            Route::Disk { home: 0 },
            GBNS / 4,
            Duration::ZERO,
            FlowTag::Background,
        );
        assert_eq!(n.next_completion_time(), Some(4000));
    }

    #[test]
    fn three_link_route_bottlenecks_on_the_busiest_link() {
        let mut n = net(2);
        // Saturate worker 0's disk with one background flow, then route
        // a spilled read across disk(0) + egress(0) + ingress(1): it
        // fair-shares the disk (rate 0.5) while the NIC links are idle.
        n.start(
            0,
            10_000,
            Route::Disk { home: 0 },
            GBNS,
            Duration::ZERO,
            FlowTag::Background,
        );
        n.start(
            0,
            1000,
            Route::DiskRemote { home: 0, dst: 1 },
            GBNS,
            Duration::ZERO,
            FlowTag::TaskRead { worker: 1 },
        );
        assert_eq!(n.next_completion_time(), Some(2000));
    }
}
