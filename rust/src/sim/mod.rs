//! Discrete-event simulator: the deterministic twin of the threaded
//! engine.
//!
//! Shares every policy-relevant component with [`crate::driver`] — the
//! same [`ShardedStore`](crate::cache::ShardedStore), the same
//! [`WorkerPeerTracker`](crate::peer::WorkerPeerTracker), the same
//! [`TaskTracker`](crate::scheduler::TaskTracker) — but advances a virtual
//! clock instead of sleeping, models compute with a calibrated cost
//! function instead of executing XLA, and stores pooled dummy payloads
//! instead of real data. This makes parameter sweeps (Fig 5–7) thousands
//! of times faster and *exactly* reproducible, while the threaded engine
//! validates that the model matches reality (see
//! `rust/tests/sim_vs_engine.rs`).

pub mod engine;
pub mod event_core;
pub mod network;

pub use engine::{SimConfig, Simulator};
pub use event_core::{EventCore, SimEvent};
pub use network::{FairShareNet, FlowTag, Route};
