//! The event-driven cluster simulator.
//!
//! The simulator replays the *broadcast* control plane regardless of
//! `EngineConfig::ctrl_plane`: its `MessageStats` are the paper's §III-C
//! accounting model (one ref-count delivery per worker per completion,
//! invalidation fan-out = workers), which the figure harness compares
//! against. The threaded engine's home-routed mode changes message
//! *counts*, not cache *decisions*, so decision metrics (hits, effective
//! hits, evictions) remain comparable across all three.
//!
//! The run loop is a discrete-event core ([`super::event_core`]): one
//! binary-heap queue of typed events (op completions, read completions,
//! restore completions, admission, message arrivals, network wake-ups)
//! with a `(time, seq)` total order, so same-time events fire in
//! schedule order and every run is deterministic.
//!
//! Read charges come in two models, selected by
//! `EngineConfig::net_model`:
//!
//! * [`NetModel::Flat`] (default) prices every fetch through
//!   [`tiered::read_cost`] — a fixed per-read duration, unaffected by
//!   what else is in flight. This is the historical model; the
//!   equivalence suite pins it against the threaded engine.
//! * [`NetModel::FairShare`] routes remote reads, spill I/O, restores,
//!   and durable reloads through [`super::network::FairShareNet`]:
//!   per-worker ingress/egress/disk links whose concurrent flows share
//!   bandwidth max-min style, with completion times recomputed on every
//!   arrival and departure. Structural metrics (tasks run, accesses,
//!   spilled/restored/recovered sets under symmetric loads) are
//!   preserved; timing-order-dependent decisions may legitimately shift
//!   as contention reorders completions, and `RunReport::net` carries
//!   per-link utilization and queueing delay.

use crate::cache::policy::PolicyEvent;
use crate::cache::sharded::ShardedStore;
use crate::cache::store::{BlockData, BlockTier};
use crate::common::config::{EngineConfig, NetModel};
use crate::common::error::Result;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, GroupId, JobId, TaskId, WorkerId};
use crate::dag::analysis::{peer_groups, PeerGroup, RefCounts};
use crate::dag::task::{enumerate_tasks, Task};
use crate::metrics::attribution::{attribute_group, ServedFrom};
use crate::metrics::{
    AccessStats, AttributionStats, FleetReport, JobStats, LatencyHistogram, MessageStats,
    RecoveryStats, RunReport, ScaleStats, TierStats,
};
use crate::peer::{PeerTrackerMaster, WorkerPeerTracker};
use crate::recovery::{
    plan_dropped_blocks, plan_worker_loss, LineageIndex, RecomputeSet, RepairAction,
};
use crate::scheduler::{AliveSet, TaskTracker};
use crate::sim::event_core::{EventCore, SimEvent};
use crate::sim::network::{FairShareNet, FlowTag, Route};
use crate::spill::{block_key, demote_evicted, served_from, GroupRestorer, SpillManager};
use crate::trace::{ClockDomain, TraceEvent};
use crate::storage::tiered::{self, TierSource};
use crate::workload::JobQueue;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Duration;

/// Simulation-only knobs on top of the engine config.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub engine: EngineConfig,
    /// Modeled compute cost: `fixed + nanos_per_elem * block_len`.
    /// Default calibrated against the PJRT CPU path (~1 ns/elem + 200 µs
    /// dispatch) — see EXPERIMENTS.md §Calibration.
    pub compute_fixed: Duration,
    pub compute_nanos_per_elem: f64,
}

impl SimConfig {
    pub fn new(engine: EngineConfig) -> Self {
        Self {
            engine,
            compute_fixed: Duration::from_micros(200),
            compute_nanos_per_elem: 1.0,
        }
    }

    fn compute_cost(&self, elems: usize) -> Duration {
        self.compute_fixed
            + Duration::from_nanos((self.compute_nanos_per_elem * elems as f64) as u64)
    }
}

/// Pending work item on a worker queue.
#[derive(Debug, Clone)]
enum SimOp {
    /// (block, len, cache?, pin?)
    Ingest(BlockId, usize, bool, bool),
    Run(TaskId),
}

/// Effects applied when an op completes.
#[derive(Debug)]
enum Finish {
    Ingest(BlockId, usize, bool, bool),
    Task(TaskId),
}

struct SimWorker {
    store: ShardedStore,
    peers: WorkerPeerTracker,
    access: AccessStats,
    queue: VecDeque<SimOp>,
    busy: bool,
    finishing: Option<Finish>,
    /// Spill-area accounting (None unless `EngineConfig::spill` is set).
    spill: Option<SpillManager>,
    /// Data-path spill counters for this worker.
    tier: TierStats,
    /// Modeled spill I/O nanos accrued off-op (demote writes, restore
    /// reads); charged onto this worker's next op duration. Flat mode
    /// only — the fair-share model carries the same I/O as disk flows.
    tier_debt: u64,
    /// Fair-share mode, current op: compute + output-write nanos to run
    /// after the last input fetch lands.
    post_nanos: u64,
    /// Fair-share mode, current op: network/disk fetch flows still in
    /// flight (including pre-dispatch restores the op waits on).
    wait_flows: u32,
    /// Fair-share mode, current op: earliest time local-memory (non-flow)
    /// fetches allow the fetch phase to end.
    fetch_floor: u64,
    /// Cumulative modeled busy nanos (telemetry sampler, DESIGN.md §10);
    /// accrued when an op completes.
    busy_nanos: u64,
    /// Logical time the in-flight op started.
    op_start: u64,
}

/// Deterministic simulator over a workload.
pub struct Simulator {
    cfg: SimConfig,
}

impl Simulator {
    pub fn new(cfg: SimConfig) -> Self {
        Self { cfg }
    }

    pub fn from_engine_config(engine: EngineConfig) -> Self {
        Self::new(SimConfig::new(engine))
    }

    /// Online multi-job twin of the threaded engine: identical arrival
    /// semantics (admission at dispatch-index boundaries, stall clamp
    /// when the queue quiesces early), per-job ingest barriers,
    /// priorities, and cross-job reference aggregation. Decision
    /// equivalence with the threaded engine is exact for queues arriving
    /// at dispatch 0 and band-level for gapped arrivals — DESIGN.md §4.
    fn execute(&self, queue: &JobQueue) -> Result<FleetReport> {
        queue.validate()?;
        self.cfg.engine.validate()?;
        let ecfg = &self.cfg.engine;
        // Elastic topology (DESIGN.md §9): every worker-indexed structure
        // is sized to the ceiling — the highest slot any join can bring
        // online — and slots beyond `num_workers` start dead. Pure
        // kill/restart plans have ceiling == num_workers, so their
        // layout (and the placement modulus) is unchanged.
        let topo = ecfg.effective_topology();
        let w_count = ecfg.worker_ceiling() as usize;
        // Flight recorder (DESIGN.md §8): track 0 is the control plane,
        // track 1+w is worker w. Every emission passes the logical clock
        // explicitly; when `trace` is Off the closure is never built.
        let trace = ecfg.trace.clone();
        if let Some(rec) = trace.recorder() {
            rec.begin(w_count + 1, ClockDomain::Logical);
        }
        let lat = ecfg.net.per_message_latency;
        let peer_aware = ecfg.policy.peer_aware();
        let dag_aware = ecfg.policy.dag_aware();
        // The spill tier's demotion planner asks the worker peer replicas
        // which blocks pending tasks still read (`unconsumed`,
        // `live_co_members`), so group registration and retirement must
        // flow even under policies that do not consume them.
        let track_groups = peer_aware || ecfg.spill.is_some();

        // --- online job state (grows at each admission) ------------------
        let mut order: Vec<usize> = (0..queue.jobs.len()).collect();
        order.sort_by_key(|&i| (queue.jobs[i].arrival, i));
        let mut next_spec = 0usize;

        let mut next_task_id = 0u64;
        let mut all_tasks: Vec<Task> = Vec::new();
        let mut refcounts = RefCounts::default();
        let mut task_index: FxHashMap<TaskId, Task> = FxHashMap::default();
        let mut tracker = TaskTracker::default();
        let mut master = PeerTrackerMaster::default();
        let mut msgs = MessageStats::default();

        let n_specs = queue.jobs.len();
        let mut spec_pending: Vec<usize> = vec![0; n_specs];
        let mut spec_gated: Vec<bool> = vec![false; n_specs];
        let mut admitted_at: Vec<u64> = vec![0; n_specs];
        let mut admitted_now: Vec<u64> = vec![0; n_specs];
        let mut spec_of_job: FxHashMap<JobId, usize> = FxHashMap::default();
        let mut ingest_owner: FxHashMap<BlockId, usize> = FxHashMap::default();
        let mut pending_total = 0usize;
        let mut tasks_run_per_job: BTreeMap<u32, u64> = BTreeMap::new();
        let mut recompute_per_job: BTreeMap<u32, u64> = BTreeMap::new();
        let mut job_jct: BTreeMap<u32, Duration> = BTreeMap::new();
        let mut per_job_access: FxHashMap<JobId, AccessStats> = FxHashMap::default();
        let mut block_len_of: FxHashMap<BlockId, usize> = FxHashMap::default();

        // --- topology plan (same semantics as the threaded engine) -------
        let mut lineage = LineageIndex::default();
        let mut alive = AliveSet::with_pending(ecfg.num_workers, w_count as u32);
        let mut actions: Vec<(u64, RepairAction)> = topo.action_queue(w_count as u32);
        // Recovery's re-registration source; only repair branches read
        // it, so fault-free / non-peer-aware runs skip the clones.
        let keep_groups = track_groups && !topo.is_empty();
        // Autoscale (TopologyPlan::Auto): dispatch is additionally held
        // at `next_check`, where the policy reads ready-queue depth and
        // alive-fleet memory pressure at the same quiescent gate the
        // failure plan uses, then enqueues a Join or a retire Kill.
        let auto_cfg = topo.autoscale_config().cloned();
        let mut next_check: u64 =
            auto_cfg.as_ref().map(|a| a.check_every).unwrap_or(u64::MAX);
        let mut scale = ScaleStats::default();
        let mut registered_groups: Vec<PeerGroup> = Vec::new();
        let mut recovery = RecoveryStats::default();
        let mut recompute_pending: FxHashSet<TaskId> = FxHashSet::default();
        let mut recovery_started: Option<u64> = None;
        // Always-on observability metrics (DESIGN.md §8) — not trace-
        // gated, so Off-vs-Collect reports stay byte-identical.
        let mut attribution = AttributionStats::default();
        let mut recompute_set = RecomputeSet::default();
        let mut lat_per_job: BTreeMap<u32, LatencyHistogram> = BTreeMap::new();
        let mut wait_per_job: BTreeMap<u32, LatencyHistogram> = BTreeMap::new();
        let mut ready_ts: FxHashMap<TaskId, u64> = FxHashMap::default();
        let mut disp_ts: FxHashMap<TaskId, u64> = FxHashMap::default();

        // --- spill tier (DESIGN.md §5; None = pre-spill behavior) --------
        let spill_on = ecfg.spill.is_some();
        let mut restorer: Option<GroupRestorer> = ecfg.spill.as_ref().map(GroupRestorer::new);
        // Dataset ids of ingest datasets: everything else is a transform
        // block (spill-managed; its "durable" copy is only the async
        // flush the model falls back to for already-dispatched readers).
        let mut ingest_datasets: FxHashSet<u32> = FxHashSet::default();
        // Drop → recompute is planned at most once per block: a
        // re-dropped recompute output is served from the durable
        // async-flush copy instead of looping recompute forever.
        let mut spill_recomputed: FxHashSet<BlockId> = FxHashSet::default();
        // Restore pins held per in-flight task (released at completion).
        let mut restore_pins: FxHashMap<TaskId, Vec<BlockId>> = FxHashMap::default();
        // Driver-side spill counters (restores issued, recomputes planned).
        let mut tier_global = TierStats::default();

        // --- contended network (DESIGN.md §6; None = flat charges) -------
        let fair_link = match ecfg.net_model {
            NetModel::Flat => None,
            NetModel::FairShare(l) => Some(l),
        };
        let disk_bw = ecfg.disk.bandwidth_bytes_per_sec;
        let mut net: Option<FairShareNet> =
            fair_link.map(|l| FairShareNet::new(w_count as u32, l, disk_bw));
        // Generation stamp on NetWake events: only the latest scheduled
        // wake-up is live, earlier ones are superseded no-ops.
        let mut net_epoch: u64 = 0;
        // Restore flows in flight for tasks not yet started; folded into
        // the worker's `wait_flows` when the op begins.
        let mut restores_inflight: FxHashMap<TaskId, u32> = FxHashMap::default();
        // Which worker is currently running each in-flight task.
        let mut running_task: FxHashMap<TaskId, u32> = FxHashMap::default();

        // --- workers ----------------------------------------------------
        let mut workers: Vec<SimWorker> = (0..w_count)
            .map(|_| SimWorker {
                // Always the Locked read path, whatever `cfg.read_path`
                // says: the sim is single-threaded, and Locked keeps the
                // byte-identical tick stream its equivalence pins rely on.
                store: ShardedStore::new(
                    ecfg.cache_capacity_per_worker,
                    ecfg.policy,
                    ecfg.cache_shards,
                ),
                peers: WorkerPeerTracker::default(),
                access: AccessStats::default(),
                queue: VecDeque::new(),
                busy: false,
                finishing: None,
                spill: ecfg.spill.map(SpillManager::new),
                tier: TierStats::default(),
                tier_debt: 0,
                post_nanos: 0,
                wait_flows: 0,
                fetch_floor: 0,
                busy_nanos: 0,
                op_start: 0,
            })
            .collect();

        // Payload pool: one allocation per distinct block length.
        let mut pool: FxHashMap<usize, BlockData> = FxHashMap::default();
        let mut payload = |len: usize| -> BlockData {
            pool.entry(len)
                .or_insert_with(|| Arc::from(vec![0.5f32; len]))
                .clone()
        };

        // --- event loop ----------------------------------------------------
        let mut core: EventCore<SimEvent> = EventCore::new();
        let mut now = 0u64;
        let mut compute_start: Option<u64> = None;
        let mut job_done_at: BTreeMap<u32, Duration> = BTreeMap::new();
        let mut dispatched = 0u64;
        // Telemetry sampler (DESIGN.md §10): samples at dispatch
        // boundaries — the deterministic clock both engines share.
        // `every == 0` means off, and `Timeline::new(0)` equals the
        // default empty timeline, preserving the Off-vs-Collect
        // byte-identity of reports.
        let tl_every = ecfg.timeline.map(|t| t.every_dispatches).unwrap_or(0);
        let mut timeline = crate::metrics::Timeline::new(tl_every);

        // (Re)arm the network wake-up at the earliest in-flight
        // completion. Called after every flow arrival/departure; the
        // epoch stamp retires any previously scheduled wake.
        macro_rules! net_wake {
            () => {{
                if let Some(n) = net.as_mut() {
                    if let Some(t) = n.next_completion_time() {
                        net_epoch += 1;
                        core.schedule_at(t, SimEvent::NetWake(net_epoch));
                    }
                }
            }};
        }

        // One telemetry sample (DESIGN.md §10): cumulative counters and
        // instantaneous gauges read at a dispatch boundary; windowed
        // rates fall out of differencing adjacent samples.
        macro_rules! tl_sample {
            () => {{
                let mut s = crate::metrics::TimelineSample {
                    ts: now,
                    dispatched,
                    ready_depth: tracker.ready_len() as u64,
                    alive_workers: alive.alive_count(),
                    ..Default::default()
                };
                for wid in alive.alive_workers() {
                    let wk = &workers[wid.0 as usize];
                    s.mem_blocks += wk.store.len() as u64;
                    s.mem_bytes += wk.store.used();
                    if let Some(sp) = wk.spill.as_ref() {
                        s.spill_blocks += sp.len() as u64;
                        s.spill_bytes += sp.used();
                    }
                    s.accesses += wk.access.accesses;
                    s.mem_hits += wk.access.mem_hits;
                    s.effective_hits += wk.access.effective_hits;
                }
                for wk in &workers {
                    s.worker_busy.push(wk.busy_nanos);
                }
                if let Some(n) = net.as_ref() {
                    s.net_flows = n.in_flight() as u64;
                    s.net_bytes = n.carried_bytes();
                }
                timeline.push(s);
            }};
        }

        // Start every worker that has queued ingest work.
        macro_rules! try_start {
            ($w:expr) => {{
                let wi = $w;
                if !workers[wi].busy {
                    if let Some(op) = workers[wi].queue.pop_front() {
                        // Off-op spill I/O (demote writes, restore reads)
                        // delays this worker's next op. Flat mode only:
                        // the fair-share model carries that I/O as flows.
                        let debt =
                            Duration::from_nanos(std::mem::take(&mut workers[wi].tier_debt));
                        let flat_dur: Option<Duration> = match &op {
                            SimOp::Ingest(_, len, _, _) => {
                                Some(ecfg.disk.io_cost((*len * 4) as u64))
                            }
                            SimOp::Run(tid) => {
                                let task = &task_index[tid];
                                // Evaluate fetches now; effects recorded now,
                                // output materializes at completion. Input
                                // streams are CONCURRENT (HDFS-style), so
                                // fetch time is the max over inputs — this
                                // is what produces the paper's Fig 3
                                // staircase: caching one of two peers does
                                // not shorten the task. Under fair-share the
                                // same concurrency holds structurally: every
                                // input is its own flow and the fetch phase
                                // ends when the last one lands.
                                let mut fetch = Duration::ZERO;
                                let mut local_fixed = Duration::ZERO;
                                let mut flows: u32 = 0;
                                let mut all_mem = true;
                                let arity = task.inputs.len() as u64;
                                let mut served: Vec<(BlockId, ServedFrom)> =
                                    Vec::with_capacity(task.inputs.len());
                                let ja = per_job_access.entry(task.job).or_default();
                                for &b in &task.inputs {
                                    let home = alive.home_of(b).0 as usize;
                                    let (hit, home_tier) = if spill_on {
                                        let (data, tier) =
                                            workers[home].store.get_with_tier(b);
                                        (data.is_some(), tier)
                                    } else {
                                        (workers[home].store.get(b).is_some(), None)
                                    };
                                    served.push((b, served_from(hit, home_tier, home == wi)));
                                    workers[wi].access.accesses += 1;
                                    ja.accesses += 1;
                                    let bytes = (task.input_len * 4) as u64;
                                    if hit {
                                        // A restored resident is a memory
                                        // hit like any other, additionally
                                        // reported as a restored hit in
                                        // TierStats (see driver/worker.rs).
                                        if home_tier == Some(BlockTier::Memory) {
                                            workers[wi].tier.restored_hits += 1;
                                        }
                                        workers[wi].access.mem_hits += 1;
                                        ja.mem_hits += 1;
                                        if home != wi {
                                            workers[wi].access.remote_hits += 1;
                                            ja.remote_hits += 1;
                                        }
                                        match net.as_mut() {
                                            Some(n) if home != wi => {
                                                n.start(
                                                    now,
                                                    bytes,
                                                    Route::Remote {
                                                        src: home as u32,
                                                        dst: wi as u32,
                                                    },
                                                    ecfg.mem.bandwidth_bytes_per_sec,
                                                    lat,
                                                    FlowTag::TaskRead { worker: wi as u32 },
                                                );
                                                flows += 1;
                                            }
                                            Some(_) => {
                                                local_fixed =
                                                    local_fixed.max(ecfg.mem.read_cost(bytes));
                                            }
                                            None => {
                                                let src = if home == wi {
                                                    TierSource::LocalMemory
                                                } else {
                                                    TierSource::RemoteMemory
                                                };
                                                fetch = fetch
                                                    .max(tiered::read_cost(ecfg, src, bytes));
                                            }
                                        }
                                    } else if home_tier == Some(BlockTier::SpilledLocal) {
                                        // Read-through from the spill area
                                        // (ReadThrough policy): disk-priced,
                                        // never an effective hit.
                                        all_mem = false;
                                        workers[wi].tier.spill_reads += 1;
                                        match net.as_mut() {
                                            Some(n) => {
                                                if !ecfg.disk.unthrottled {
                                                    let route = if home == wi {
                                                        Route::Disk { home: home as u32 }
                                                    } else {
                                                        Route::DiskRemote {
                                                            home: home as u32,
                                                            dst: wi as u32,
                                                        }
                                                    };
                                                    n.start(
                                                        now,
                                                        bytes,
                                                        route,
                                                        ecfg.disk.bandwidth_bytes_per_sec,
                                                        ecfg.disk.seek_latency,
                                                        FlowTag::TaskRead {
                                                            worker: wi as u32,
                                                        },
                                                    );
                                                    flows += 1;
                                                }
                                            }
                                            None => {
                                                fetch = fetch.max(tiered::read_cost(
                                                    ecfg,
                                                    TierSource::SpilledLocal,
                                                    bytes,
                                                ));
                                            }
                                        }
                                    } else {
                                        all_mem = false;
                                        if home_tier == Some(BlockTier::Dropped) {
                                            // Consumer was dispatched before
                                            // the drop landed: served from
                                            // the durable async-flush copy.
                                            workers[wi].tier.fallback_durable_reads += 1;
                                        }
                                        workers[wi].access.disk_reads += 1;
                                        workers[wi].access.disk_bytes += bytes;
                                        ja.disk_reads += 1;
                                        ja.disk_bytes += bytes;
                                        match net.as_mut() {
                                            Some(n) => {
                                                if !ecfg.disk.unthrottled {
                                                    n.start(
                                                        now,
                                                        bytes,
                                                        Route::Ingress { dst: wi as u32 },
                                                        ecfg.disk.bandwidth_bytes_per_sec,
                                                        ecfg.disk.seek_latency,
                                                        FlowTag::TaskRead {
                                                            worker: wi as u32,
                                                        },
                                                    );
                                                    flows += 1;
                                                }
                                            }
                                            None => {
                                                fetch = fetch.max(tiered::read_cost(
                                                    ecfg,
                                                    TierSource::Durable,
                                                    bytes,
                                                ));
                                            }
                                        }
                                    }
                                }
                                if all_mem {
                                    workers[wi].access.effective_hits += arity;
                                    ja.effective_hits += arity;
                                } else {
                                    // Same attribution rule as the threaded
                                    // worker: the whole broken group is
                                    // charged, one trace event per access.
                                    let t = *tid;
                                    attribute_group(
                                        &served,
                                        |bb| recompute_set.contains(bb),
                                        &mut attribution,
                                        |member, blocking, cause| {
                                            trace.emit(wi + 1, Some(now), || {
                                                TraceEvent::IneffectiveHit {
                                                    task: t,
                                                    worker: WorkerId(wi as u32),
                                                    block: member,
                                                    blocking,
                                                    cause,
                                                }
                                            });
                                        },
                                    );
                                }
                                trace.emit(wi + 1, Some(now), || TraceEvent::InputsPinned {
                                    task: *tid,
                                    worker: WorkerId(wi as u32),
                                });
                                let out_write = if ecfg.sync_output_writes {
                                    ecfg.disk.io_cost((task.output_len * 4) as u64)
                                } else {
                                    Duration::ZERO // async writer, off critical path
                                };
                                let post = self
                                    .cfg
                                    .compute_cost(task.input_len * task.inputs.len())
                                    + out_write;
                                if net.is_some() {
                                    // Fair-share: the op completes when its
                                    // last fetch flow (and any pre-dispatch
                                    // restore still in flight) lands, then
                                    // compute + output-write runs.
                                    let pending =
                                        restores_inflight.remove(tid).unwrap_or(0);
                                    let wk = &mut workers[wi];
                                    wk.post_nanos = (post + debt).as_nanos() as u64;
                                    wk.fetch_floor = now + local_fixed.as_nanos() as u64;
                                    wk.wait_flows = flows + pending;
                                    running_task.insert(*tid, wi as u32);
                                    if wk.wait_flows == 0 {
                                        core.schedule_at(
                                            wk.fetch_floor,
                                            SimEvent::ReadComplete(wi as u32),
                                        );
                                    }
                                    None
                                } else {
                                    Some(fetch + post)
                                }
                            }
                        };
                        workers[wi].finishing = Some(match op {
                            SimOp::Ingest(b, len, cache, pin) => {
                                Finish::Ingest(b, len, cache, pin)
                            }
                            SimOp::Run(t) => Finish::Task(t),
                        });
                        workers[wi].busy = true;
                        workers[wi].op_start = now;
                        match flat_dur {
                            Some(dur) => {
                                let dur = dur + debt;
                                core.schedule_at(
                                    now + dur.as_nanos() as u64,
                                    SimEvent::OpComplete(wi as u32),
                                );
                            }
                            None => net_wake!(),
                        }
                    }
                }
            }};
        }

        // Admit one job (same steps, same order as the threaded engine's
        // `admit!`): enumerate tasks, register peer groups on the master
        // and every alive worker replica (the sim models the broadcast
        // plane), aggregate references and re-seed the new absolute
        // counts, enqueue not-yet-ingested blocks (content-key dedup),
        // gate the job behind its own ingest barrier.
        macro_rules! admit {
            ($si:expr) => {{
                let si: usize = $si;
                let spec = &queue.jobs[si];
                admitted_at[si] = dispatched;
                admitted_now[si] = now;
                let mut spec_tasks: Vec<Task> = Vec::new();
                for dag in &spec.workload.dags {
                    spec_of_job.insert(dag.job, si);
                    tracker.set_priority(dag.job, spec.priority);
                    let tasks = enumerate_tasks(dag, &mut next_task_id);
                    if track_groups {
                        let groups = peer_groups(&tasks);
                        // Same check as the threaded engine's admission:
                        // a group whose shared member is materialized but
                        // uncached (evicted, or ingested cache=false) is
                        // broken from birth — no disk read re-promotes it.
                        // A *spilled* member does not break the group
                        // (spill::member_breaks_group).
                        let incomplete: Vec<GroupId> = groups
                            .iter()
                            .filter(|g| {
                                g.members.iter().any(|m| {
                                    crate::spill::member_breaks_group(
                                        &workers[alive.home_of(*m).0 as usize].store,
                                        tracker.is_materialized(*m),
                                        *m,
                                    )
                                })
                            })
                            .map(|g| g.id)
                            .collect();
                        master.register(&groups);
                        master.mark_incomplete(&incomplete);
                        for w in alive.alive_workers() {
                            let wk = &mut workers[w.0 as usize];
                            wk.peers.register(&groups, &incomplete);
                            for g in &groups {
                                for &b in &g.members {
                                    let count = wk.peers.effective_count(b);
                                    wk.store.policy_event(PolicyEvent::EffectiveCount {
                                        block: b,
                                        count,
                                    });
                                }
                            }
                        }
                        if keep_groups {
                            registered_groups.extend(groups);
                        }
                    }
                    spec_tasks.extend(tasks);
                }
                for t in &spec_tasks {
                    trace.emit(0, Some(now), || TraceEvent::TaskAdmitted {
                        job: t.job,
                        task: t.id,
                    });
                }
                lineage.add_tasks(&spec_tasks, all_tasks.len());
                for t in &spec_tasks {
                    task_index.insert(t.id, t.clone());
                }
                let changed = refcounts.add_tasks(&spec_tasks);
                if dag_aware {
                    let mut seed = changed;
                    let seeded: FxHashSet<BlockId> = seed.iter().map(|(b, _)| *b).collect();
                    for t in &spec_tasks {
                        if !seeded.contains(&t.output) {
                            seed.push((t.output, refcounts.get(t.output)));
                        }
                    }
                    for w in alive.alive_workers() {
                        for &(b, count) in &seed {
                            workers[w.0 as usize]
                                .store
                                .policy_event(PolicyEvent::RefCount { block: b, count });
                        }
                    }
                    msgs.refcount_updates += alive.alive_count() as u64;
                }
                for d in &spec.workload.dags {
                    for ds in d.inputs() {
                        ingest_datasets.insert(ds.id.0);
                        for b in ds.blocks() {
                            block_len_of.insert(b, ds.block_len);
                        }
                    }
                }
                let pinned_set: Option<FxHashSet<BlockId>> =
                    spec.workload.pinned_cache.as_ref().map(|v| v.iter().copied().collect());
                for &b in &spec.workload.ingest_order {
                    if ingest_owner.contains_key(&b) {
                        continue;
                    }
                    ingest_owner.insert(b, si);
                    let w = alive.home_of(b).0 as usize;
                    let (cache, pin) = match &pinned_set {
                        Some(set) => (set.contains(&b), set.contains(&b)),
                        None => (true, false),
                    };
                    workers[w]
                        .queue
                        .push_back(SimOp::Ingest(b, block_len_of[&b], cache, pin));
                    spec_pending[si] += 1;
                    pending_total += 1;
                    try_start!(w);
                }
                if !ecfg.overlap_ingest && spec_pending[si] > 0 {
                    spec_gated[si] = true;
                    for dag in &spec.workload.dags {
                        tracker.gate_job(dag.job);
                    }
                }
                all_tasks.extend(spec_tasks.iter().cloned());
                tracker.add_tasks(spec_tasks);
            }};
        }

        // Handle evictions caused by an insert on worker `wi` at time `t`.
        macro_rules! handle_evictions {
            ($wi:expr, $evicted:expr, $t:expr) => {{
                if peer_aware {
                    for &b in $evicted.iter() {
                        if workers[$wi].peers.should_report_eviction(b) {
                            msgs.eviction_reports += 1;
                            core.schedule_at(
                                $t + lat.as_nanos() as u64,
                                SimEvent::ReportArrival(b),
                            );
                        }
                    }
                }
            }};
        }

        // Queue an invalidation broadcast to every alive worker.
        macro_rules! broadcast_to_alive {
            ($block:expr) => {{
                trace.emit(0, Some(now), || TraceEvent::InvalidationBroadcast {
                    block: $block,
                });
                msgs.invalidation_broadcasts += 1;
                msgs.broadcast_deliveries += alive.alive_count() as u64;
                for w in alive.alive_workers() {
                    core.schedule_at(
                        now + lat.as_nanos() as u64,
                        SimEvent::BroadcastArrival($block, w.0),
                    );
                }
            }};
        }

        // Register a recompute closure's peer groups at every alive
        // replica — one protocol sequence shared by the kill path and the
        // spill drop path, so the incomplete-group rule cannot drift
        // between them. Members that are materialized but neither cached
        // nor restorably spilled make their group broken from birth:
        // registering it complete would inflate effective counts.
        macro_rules! register_recompute_groups {
            ($recompute:expr) => {{
                let groups = peer_groups($recompute);
                let incomplete: Vec<GroupId> = groups
                    .iter()
                    .filter(|g| {
                        g.members.iter().any(|m| {
                            crate::spill::member_breaks_group(
                                &workers[alive.home_of(*m).0 as usize].store,
                                tracker.is_materialized(*m),
                                *m,
                            )
                        })
                    })
                    .map(|g| g.id)
                    .collect();
                master.register(&groups);
                master.mark_incomplete(&incomplete);
                for w in alive.alive_workers() {
                    let wk = &mut workers[w.0 as usize];
                    wk.peers.register(&groups, &incomplete);
                    for g in &groups {
                        for &b in &g.members {
                            let count = wk.peers.effective_count(b);
                            wk.store.policy_event(PolicyEvent::EffectiveCount {
                                block: b,
                                count,
                            });
                        }
                    }
                }
                if keep_groups {
                    registered_groups.extend(groups);
                }
            }};
        }

        // A transform block's bytes left both tiers (demotion refused, or
        // reclaimed from the spill area): re-plan the still-needed ones
        // through lineage — the same registration steps as a kill's
        // recompute closure.
        macro_rules! handle_tier_drops {
            ($dropped:expr) => {{
                let dropped: Vec<BlockId> = $dropped;
                let plan = plan_dropped_blocks(
                    &dropped,
                    &lineage,
                    &all_tasks,
                    &mut tracker,
                    &mut refcounts,
                    &mut next_task_id,
                );
                spill_recomputed.extend(plan.lost_durable.iter().copied());
                if !plan.recompute.is_empty() {
                    recompute_set.plan(&plan.recompute);
                    for t in &plan.recompute {
                        trace.emit(0, Some(now), || TraceEvent::RecomputePlanned {
                            block: t.output,
                            task: t.id,
                        });
                    }
                    tier_global.spill_recompute_tasks += plan.recompute.len() as u64;
                    if dag_aware {
                        for w in alive.alive_workers() {
                            for &(b, count) in &plan.refcount_changes {
                                workers[w.0 as usize]
                                    .store
                                    .policy_event(PolicyEvent::RefCount { block: b, count });
                            }
                        }
                        msgs.refcount_updates += alive.alive_count() as u64;
                    }
                    if track_groups {
                        register_recompute_groups!(&plan.recompute);
                    }
                    for t in &plan.recompute {
                        task_index.insert(t.id, t.clone());
                        *recompute_per_job.entry(t.job.0).or_default() += 1;
                    }
                    tracker.add_tasks(plan.recompute);
                }
            }};
        }

        // Insert a block at worker `wi`, demoting this insert's victims to
        // the spill tier instead of dropping the bytes (DESIGN.md §5).
        // Spill off = exactly the old insert + eviction-report path.
        macro_rules! insert_demote {
            ($wi:expr, $b:expr, $data:expr) => {{
                let wi: usize = $wi;
                trace.emit(wi + 1, Some(now), || TraceEvent::BlockInserted {
                    block: $b,
                    worker: WorkerId(wi as u32),
                });
                if !spill_on {
                    let outcome = workers[wi].store.insert($b, $data);
                    for ev in &outcome.evicted {
                        trace.emit(wi + 1, Some(now), || TraceEvent::BlockEvicted {
                            block: *ev,
                            worker: WorkerId(wi as u32),
                        });
                    }
                    handle_evictions!(wi, outcome.evicted, now);
                } else {
                    let (outcome, payloads) = workers[wi].store.insert_retaining($b, $data);
                    if !outcome.evicted.is_empty() {
                        for ev in &outcome.evicted {
                            trace.emit(wi + 1, Some(now), || TraceEvent::BlockEvicted {
                                block: *ev,
                                worker: WorkerId(wi as u32),
                            });
                        }
                        let evicted: Vec<(BlockId, BlockData)> =
                            outcome.evicted.iter().copied().zip(payloads).collect();
                        let plan = {
                            let wk = &mut workers[wi];
                            demote_evicted(
                                &wk.store,
                                &wk.peers,
                                wk.spill.as_mut().expect("spill on"),
                                |bb: BlockId| !ingest_datasets.contains(&bb.dataset.0),
                                evicted,
                            )
                        };
                        {
                            let wk = &mut workers[wi];
                            // The sim "persists" instantly; mark the
                            // spilled blocks now (the threaded engine
                            // marks after the real file writes).
                            for (bb, _) in &plan.spilled {
                                wk.store.set_tier(*bb, BlockTier::SpilledLocal);
                                trace.emit(wi + 1, Some(now), || TraceEvent::BlockDemoted {
                                    block: *bb,
                                    worker: WorkerId(wi as u32),
                                });
                            }
                            wk.tier.spilled_blocks += plan.spilled.len() as u64;
                            wk.tier.spilled_bytes += plan.bytes_spilled;
                            wk.tier.groups_demoted += plan.groups_demoted;
                            wk.tier.demotions_refused += plan.dropped.len() as u64;
                            wk.tier.spill_evictions += plan.spill_evicted.len() as u64;
                            for (bb, _) in &plan.spilled {
                                wk.tier.spilled_log.push(block_key(*bb));
                            }
                            // Demote writes: a flat-mode debt charge on the
                            // worker's next op, or a background disk flow
                            // contending fair-share with reads.
                            match net.as_mut() {
                                Some(n) => {
                                    if !ecfg.disk.unthrottled && plan.bytes_spilled > 0 {
                                        n.start(
                                            now,
                                            plan.bytes_spilled,
                                            Route::Disk { home: wi as u32 },
                                            ecfg.disk.bandwidth_bytes_per_sec,
                                            ecfg.disk.seek_latency,
                                            FlowTag::Background,
                                        );
                                    }
                                }
                                None => {
                                    wk.tier_debt +=
                                        tiered::spill_write_cost(ecfg, plan.bytes_spilled)
                                            .as_nanos() as u64;
                                }
                            }
                        }
                        net_wake!();
                        if let Some(rst) = restorer.as_mut() {
                            for (bb, _) in &plan.spilled {
                                rst.note_spilled(*bb);
                            }
                            for bb in plan.dropped.iter().chain(plan.spill_evicted.iter()) {
                                rst.note_dropped(*bb);
                            }
                        }
                        let report: Vec<BlockId> = plan.all_dropped().collect();
                        for dropped in &report {
                            trace.emit(wi + 1, Some(now), || TraceEvent::BlockDropped {
                                block: *dropped,
                                worker: WorkerId(wi as u32),
                            });
                        }
                        handle_evictions!(wi, report, now);
                        let to_plan: Vec<BlockId> = plan
                            .dropped
                            .iter()
                            .chain(plan.spill_evicted.iter())
                            .copied()
                            .filter(|bb| !spill_recomputed.contains(bb))
                            .collect();
                        if !to_plan.is_empty() {
                            handle_tier_drops!(to_plan);
                        }
                    }
                }
            }};
        }

        // Promote one spilled block back to memory at its home — the sim
        // half of the pre-dispatch group restore (the threaded engine
        // does a real read + pin in driver/worker.rs). The restored
        // block is pinned until its task retires, so the promotion's own
        // eviction cascade can never undo it.
        macro_rules! restore_block {
            ($home:expr, $b:expr, $tid:expr) => {{
                let home: usize = $home;
                let bb: BlockId = $b;
                let t: TaskId = $tid;
                let released = workers[home].spill.as_mut().and_then(|m| m.release(bb));
                if let Some(bytes) = released {
                    // Restore reads: flat-mode debt on the home worker's
                    // next op, or a disk flow the dispatched task waits on.
                    match net.as_mut() {
                        Some(n) => {
                            if !ecfg.disk.unthrottled {
                                n.start(
                                    now,
                                    bytes,
                                    Route::Disk { home: home as u32 },
                                    ecfg.disk.bandwidth_bytes_per_sec,
                                    ecfg.disk.seek_latency,
                                    FlowTag::Restore { task: t.0 },
                                );
                                *restores_inflight.entry(t).or_insert(0) += 1;
                                net_wake!();
                            }
                        }
                        None => {
                            workers[home].tier_debt +=
                                tiered::read_cost(ecfg, TierSource::SpilledLocal, bytes)
                                    .as_nanos() as u64;
                        }
                    }
                    workers[home].store.pin(bb);
                    let data = payload((bytes / 4) as usize);
                    insert_demote!(home, bb, data);
                    workers[home].store.set_tier(bb, BlockTier::Memory);
                    trace.emit(home + 1, Some(now), || TraceEvent::BlockRestored {
                        block: bb,
                        worker: WorkerId(home as u32),
                    });
                    workers[home].tier.restored_blocks += 1;
                    workers[home].tier.restored_bytes += bytes;
                    workers[home].tier.restored_log.push(block_key(bb));
                    restore_pins.entry(t).or_default().push(bb);
                }
            }};
        }

        // Admit due/overdue jobs and dispatch, held at the next failure
        // or arrival boundary — the same deterministic admission points
        // as the threaded engine's `admit_and_dispatch!`.
        macro_rules! admit_and_dispatch {
            () => {{
                loop {
                    let mut admitted_any = false;
                    while next_spec < order.len()
                        && queue.jobs[order[next_spec]].arrival <= dispatched
                    {
                        admit!(order[next_spec]);
                        next_spec += 1;
                        admitted_any = true;
                    }
                    // Stall clamp: quiescent with jobs left whose arrival
                    // index can never be reached — pull the next one in.
                    if !admitted_any
                        && next_spec < order.len()
                        && pending_total == 0
                        && tracker.ready_len() == 0
                        && workers.iter().all(|w| !w.busy && w.queue.is_empty())
                    {
                        admit!(order[next_spec]);
                        next_spec += 1;
                    }
                    let fail_limit = actions.first().map(|&(t, _)| t);
                    let auto_limit = auto_cfg.as_ref().map(|_| next_check);
                    let arr_limit = if next_spec < order.len() {
                        Some(queue.jobs[order[next_spec]].arrival)
                    } else {
                        None
                    };
                    let limit = [fail_limit, auto_limit, arr_limit]
                        .into_iter()
                        .flatten()
                        .min();
                    loop {
                        for rid in tracker.take_newly_ready() {
                            ready_ts.insert(rid, now);
                            trace.emit(0, Some(now), || TraceEvent::TaskReady { task: rid });
                        }
                        if let Some(t) = limit {
                            if dispatched >= t {
                                break;
                            }
                        }
                        let Some(tid) = tracker.pop_ready() else {
                            break;
                        };
                        // Pre-dispatch group restore: promote the task's
                        // spilled input members back to memory as a whole
                        // before it runs (DESIGN.md §5).
                        if let Some(rst) = restorer.as_mut() {
                            let inputs = task_index[&tid].inputs.clone();
                            let set = rst.plan_restore(&inputs);
                            if !set.is_empty() {
                                tier_global.groups_restored += 1;
                                for bb in set {
                                    let h = alive.home_of(bb).0 as usize;
                                    restore_block!(h, bb, tid);
                                }
                            }
                        }
                        let task_job = task_index[&tid].job;
                        *tasks_run_per_job.entry(task_job.0).or_default() += 1;
                        let home = alive.home_of(task_index[&tid].output).0 as usize;
                        if let Some(r) = ready_ts.remove(&tid) {
                            wait_per_job
                                .entry(task_job.0)
                                .or_default()
                                .record(now.saturating_sub(r));
                        }
                        disp_ts.insert(tid, now);
                        trace.emit(0, Some(now), || TraceEvent::TaskDispatched {
                            task: tid,
                            worker: WorkerId(home as u32),
                        });
                        workers[home].queue.push_back(SimOp::Run(tid));
                        dispatched += 1;
                        if tl_every != 0 && dispatched % tl_every == 0 {
                            tl_sample!();
                        }
                        try_start!(home);
                    }
                    if next_spec < order.len()
                        && (queue.jobs[order[next_spec]].arrival <= dispatched
                            || (pending_total == 0
                                && tracker.ready_len() == 0
                                && workers.iter().all(|w| !w.busy && w.queue.is_empty())))
                    {
                        continue;
                    }
                    break;
                }
            }};
        }

        // Apply due failure-plan steps at quiescent points (identical
        // semantics to the threaded driver: dispatch is held at the
        // trigger, the kill lands once every worker is idle and drained),
        // then dispatch ready tasks up to the next trigger.
        macro_rules! pump {
            () => {{
                // Quiescent drain: the sim is single-threaded, so every
                // pump boundary is a safe point to move ring contents
                // into the collected log before they can overflow.
                if let Some(rec) = trace.recorder() {
                    rec.drain();
                }
                loop {
                    let due = match actions.first() {
                        Some(&(t, _)) => dispatched >= t,
                        None => false,
                    };
                    let auto_due = auto_cfg.is_some() && dispatched >= next_check;
                    if !due && !auto_due {
                        break;
                    }
                    let busy_any = workers.iter().any(|w| w.busy || !w.queue.is_empty());
                    if busy_any || pending_total > 0 {
                        break;
                    }
                    if !due {
                        // Autoscale checkpoint. Dispatch was held at
                        // `next_check`, so the ready queue depth is the
                        // genuine backlog; decisions become Join / Kill
                        // actions consumed by the arms below.
                        let a = auto_cfg.as_ref().expect("autoscale gate");
                        while next_check <= dispatched {
                            next_check += a.check_every;
                        }
                        let ready = tracker.ready_len() as u64;
                        let alive_n = alive.alive_count();
                        let mut used = 0u64;
                        for wid in alive.alive_workers() {
                            used += workers[wid.0 as usize].store.used();
                        }
                        let cap = alive_n as u64 * ecfg.cache_capacity_per_worker;
                        let mem_frac = if cap == 0 { 0.0 } else { used as f64 / cap as f64 };
                        let want_up = (ready >= a.scale_up_ready as u64
                            || mem_frac >= a.mem_high)
                            && alive_n < a.max_workers.min(w_count as u32);
                        let want_down = !want_up
                            && ready <= a.scale_down_ready as u64
                            && mem_frac <= a.mem_low
                            && alive_n > a.min_workers;
                        if want_up {
                            // Lowest-indexed pending slot comes online.
                            let joiner = (0..w_count as u32)
                                .map(WorkerId)
                                .find(|w| !alive.is_alive(*w));
                            if let Some(j) = joiner {
                                trace.emit(0, Some(now), || TraceEvent::ScaleDecision {
                                    action: "up",
                                    worker: j,
                                    ready,
                                    mem_used: used,
                                });
                                actions.insert(
                                    0,
                                    (dispatched, RepairAction::Join { worker: j }),
                                );
                            }
                        } else if want_down {
                            // Highest-indexed alive worker retires; its
                            // state tears down through the shared Kill
                            // arm (no restart scheduled).
                            if let Some(v) = alive.alive_workers().last() {
                                trace.emit(0, Some(now), || TraceEvent::ScaleDecision {
                                    action: "down",
                                    worker: v,
                                    ready,
                                    mem_used: used,
                                });
                                scale.workers_retired += 1;
                                actions.insert(
                                    0,
                                    (
                                        dispatched,
                                        RepairAction::Kill {
                                            worker: v,
                                            restart_after: None,
                                        },
                                    ),
                                );
                            }
                        }
                        continue;
                    }
                    let (_, action) = actions.remove(0);
                    match action {
                        RepairAction::Kill {
                            worker,
                            restart_after,
                        } => {
                            trace.emit(0, Some(now), || TraceEvent::WorkerKilled { worker });
                            let wi = worker.0 as usize;
                            let lost_cached = workers[wi].store.clear();
                            // Crash semantics: the local spill area dies
                            // with its worker, so recovery's minimal-
                            // closure math never counts on spilled bytes.
                            let lost_spilled: Vec<BlockId> =
                                workers[wi].spill.as_mut().map(|m| m.clear()).unwrap_or_default();
                            workers[wi].tier_debt = 0;
                            if let Some(rst) = restorer.as_mut() {
                                for b in lost_cached.iter().chain(lost_spilled.iter()) {
                                    rst.forget(*b);
                                }
                            }
                            workers[wi].peers = WorkerPeerTracker::default();
                            let plan = plan_worker_loss(
                                worker,
                                &alive,
                                &lineage,
                                &all_tasks,
                                &mut tracker,
                                &mut refcounts,
                                &mut next_task_id,
                            );
                            alive.kill(worker);
                            if alive.alive_count() == 0 {
                                return Err(crate::common::error::EngineError::Invariant(
                                    "failure plan killed every worker; nothing can run the job"
                                        .into(),
                                ));
                            }
                            if peer_aware {
                                // Spilled blocks kept their groups whole;
                                // losing the spill area breaks them like
                                // any other mass eviction.
                                for &b in lost_cached.iter().chain(lost_spilled.iter()) {
                                    if master.fail_member(b).is_some() {
                                        broadcast_to_alive!(b);
                                    }
                                }
                            }
                            recovery.workers_killed += 1;
                            recovery.blocks_lost_cached += lost_cached.len() as u64;
                            recovery.blocks_lost_spilled += lost_spilled.len() as u64;
                            recovery.blocks_lost_durable += plan.lost_durable.len() as u64;
                            recovery.recompute_tasks += plan.recompute.len() as u64;
                            recovery.recompute_bytes += plan.recompute_bytes();
                            if !plan.recompute.is_empty() {
                                if dag_aware {
                                    for w in alive.alive_workers() {
                                        for &(b, count) in &plan.refcount_changes {
                                            workers[w.0 as usize].store.policy_event(
                                                PolicyEvent::RefCount { block: b, count },
                                            );
                                        }
                                    }
                                    msgs.refcount_updates += alive.alive_count() as u64;
                                }
                                if track_groups {
                                    register_recompute_groups!(&plan.recompute);
                                }
                                recompute_set.plan(&plan.recompute);
                                for t in &plan.recompute {
                                    recompute_pending.insert(t.id);
                                    task_index.insert(t.id, t.clone());
                                    *recompute_per_job.entry(t.job.0).or_default() += 1;
                                    trace.emit(0, Some(now), || TraceEvent::RecomputePlanned {
                                        block: t.output,
                                        task: t.id,
                                    });
                                }
                                tracker.add_tasks(plan.recompute);
                                if recovery_started.is_none() {
                                    recovery_started = Some(now);
                                }
                            }
                            if let Some(after) = restart_after {
                                let trigger = dispatched + after;
                                let pos = actions.partition_point(|(t, _)| *t <= trigger);
                                actions.insert(pos, (trigger, RepairAction::Revive { worker }));
                            }
                        }
                        RepairAction::Revive { worker } => {
                            trace.emit(0, Some(now), || TraceEvent::WorkerRevived { worker });
                            alive.revive(worker);
                            // Purge blocks whose home reverts to the
                            // revived worker (unreachable at their
                            // kill-era probe homes) and break their groups.
                            for v in alive.alive_workers() {
                                if v == worker {
                                    continue;
                                }
                                let vi = v.0 as usize;
                                for b in workers[vi].store.cached_blocks() {
                                    if alive.home_of(b) != v
                                        && workers[vi].store.remove(b).is_some()
                                    {
                                        // A purged restored resident must
                                        // not leave its Memory tier record.
                                        workers[vi].store.clear_tier(b);
                                        if let Some(rst) = restorer.as_mut() {
                                            rst.forget(b);
                                        }
                                        if peer_aware && master.fail_member(b).is_some() {
                                            broadcast_to_alive!(b);
                                        }
                                    }
                                }
                                // Spill copies whose home reverts to the
                                // revived worker are unreachable under the
                                // restored mapping: purge them (readers
                                // fall back to the durable copies, like
                                // the purged memory blocks above).
                                if spill_on {
                                    let stale: Vec<BlockId> = workers[vi]
                                        .spill
                                        .as_ref()
                                        .map(|m| {
                                            m.resident_blocks()
                                                .into_iter()
                                                .filter(|b| alive.home_of(*b) != v)
                                                .collect()
                                        })
                                        .unwrap_or_default();
                                    for b in stale {
                                        workers[vi].spill.as_mut().expect("spill on").release(b);
                                        workers[vi].store.clear_tier(b);
                                        if let Some(rst) = restorer.as_mut() {
                                            rst.forget(b);
                                        }
                                        if peer_aware && master.fail_member(b).is_some() {
                                            broadcast_to_alive!(b);
                                        }
                                    }
                                }
                            }
                            // Re-seed the cold replica's metadata.
                            let wi = worker.0 as usize;
                            if dag_aware {
                                let counts: Vec<(BlockId, u32)> =
                                    refcounts.iter().map(|(b, c)| (*b, *c)).collect();
                                for (b, count) in counts {
                                    workers[wi]
                                        .store
                                        .policy_event(PolicyEvent::RefCount { block: b, count });
                                }
                                msgs.refcount_updates += 1;
                            }
                            if track_groups {
                                let subset: Vec<PeerGroup> = registered_groups
                                    .iter()
                                    .filter(|g| master.task_retired(g.task) == Some(false))
                                    .cloned()
                                    .collect();
                                let incomplete: Vec<GroupId> = subset
                                    .iter()
                                    .filter(|g| master.group_complete(g.task) == Some(false))
                                    .map(|g| g.id)
                                    .collect();
                                let wk = &mut workers[wi];
                                wk.peers.register(&subset, &incomplete);
                                for g in &subset {
                                    for &b in &g.members {
                                        let count = wk.peers.effective_count(b);
                                        wk.store.policy_event(PolicyEvent::EffectiveCount {
                                            block: b,
                                            count,
                                        });
                                    }
                                }
                            }
                            recovery.workers_restarted += 1;
                        }
                        RepairAction::Join { worker } => {
                            trace.emit(0, Some(now), || TraceEvent::WorkerJoined {
                                worker,
                            });
                            alive.revive(worker);
                            let ji = worker.0 as usize;
                            // Re-seed the newcomer's metadata BEFORE any
                            // payload moves, so migration inserts land on
                            // live policy state (the Revive re-seed idiom).
                            if dag_aware {
                                let counts: Vec<(BlockId, u32)> =
                                    refcounts.iter().map(|(b, c)| (*b, *c)).collect();
                                for (b, count) in counts {
                                    workers[ji].store.policy_event(PolicyEvent::RefCount {
                                        block: b,
                                        count,
                                    });
                                }
                                msgs.refcount_updates += 1;
                            }
                            if track_groups {
                                let subset: Vec<PeerGroup> = registered_groups
                                    .iter()
                                    .filter(|g| master.task_retired(g.task) == Some(false))
                                    .cloned()
                                    .collect();
                                let incomplete: Vec<GroupId> = subset
                                    .iter()
                                    .filter(|g| {
                                        master.group_complete(g.task) == Some(false)
                                    })
                                    .map(|g| g.id)
                                    .collect();
                                let wk = &mut workers[ji];
                                wk.peers.register(&subset, &incomplete);
                                for g in &subset {
                                    for &b in &g.members {
                                        let count = wk.peers.effective_count(b);
                                        wk.store.policy_event(PolicyEvent::EffectiveCount {
                                            block: b,
                                            count,
                                        });
                                    }
                                }
                            }
                            // Incremental re-homing: ONLY blocks whose
                            // stable probe home is now the newcomer move
                            // (the placement analogue of a revive). Group
                            // fragments migrate as pinned batches — every
                            // member is pinned at the newcomer before the
                            // first insert, so no migration insert can
                            // evict a co-member mid-batch and a group is
                            // never split by its own warm-up.
                            let donors: Vec<WorkerId> =
                                alive.alive_workers().filter(|v| *v != worker).collect();
                            for v in donors {
                                let vi = v.0 as usize;
                                let moving: Vec<BlockId> = workers[vi]
                                    .store
                                    .cached_blocks()
                                    .into_iter()
                                    .filter(|b| alive.home_of(*b) == worker)
                                    .collect();
                                let mut batches: Vec<(GroupId, Vec<BlockId>)> = Vec::new();
                                let mut single: Vec<BlockId> = moving.clone();
                                if track_groups {
                                    let mset: FxHashSet<BlockId> =
                                        moving.iter().copied().collect();
                                    let mut batched: FxHashSet<BlockId> =
                                        FxHashSet::default();
                                    for g in registered_groups.iter().filter(|g| {
                                        master.task_retired(g.task) == Some(false)
                                    }) {
                                        let frag: Vec<BlockId> = g
                                            .members
                                            .iter()
                                            .copied()
                                            .filter(|m| {
                                                mset.contains(m) && !batched.contains(m)
                                            })
                                            .collect();
                                        if !frag.is_empty() {
                                            batched.extend(frag.iter().copied());
                                            batches.push((g.id, frag));
                                        }
                                    }
                                    single.retain(|b| !batched.contains(b));
                                    for b in single.iter() {
                                        batches.push((GroupId(u64::MAX), vec![*b]));
                                    }
                                } else {
                                    for b in single.iter() {
                                        batches.push((GroupId(u64::MAX), vec![*b]));
                                    }
                                }
                                for (gid, frag) in batches {
                                    let grouped = gid != GroupId(u64::MAX);
                                    if grouped {
                                        for &b in &frag {
                                            workers[ji].store.pin(b);
                                        }
                                    }
                                    let mut moved = 0u64;
                                    for &b in &frag {
                                        // A donor-pinned block stays put
                                        // (same rule as the revive purge).
                                        let Some(data) = workers[vi].store.remove(b)
                                        else {
                                            continue;
                                        };
                                        workers[vi].store.clear_tier(b);
                                        let bytes = (data.len() * 4) as u64;
                                        trace.emit(ji + 1, Some(now), || {
                                            TraceEvent::BlockInserted { block: b, worker }
                                        });
                                        // Plain insert (no demotion cascade):
                                        // a migration victim is dropped, not
                                        // spilled — both engines share this
                                        // simplification so their decision
                                        // streams stay identical.
                                        let outcome = workers[ji].store.insert(b, data);
                                        for ev in &outcome.evicted {
                                            trace.emit(ji + 1, Some(now), || {
                                                TraceEvent::BlockEvicted {
                                                    block: *ev,
                                                    worker,
                                                }
                                            });
                                            if spill_on {
                                                workers[ji].store.clear_tier(*ev);
                                            }
                                        }
                                        handle_evictions!(ji, outcome.evicted, now);
                                        scale.blocks_migrated += 1;
                                        scale.migration_bytes += bytes;
                                        moved += 1;
                                    }
                                    if grouped {
                                        for &b in &frag {
                                            workers[ji].store.unpin(b);
                                        }
                                        if moved > 0 {
                                            scale.groups_migrated += 1;
                                            trace.emit(0, Some(now), || {
                                                TraceEvent::GroupMigrated {
                                                    group: gid,
                                                    from: v,
                                                    to: worker,
                                                    blocks: moved,
                                                }
                                            });
                                        }
                                    }
                                }
                                // Spilled copies whose home probes to the
                                // newcomer move with their accounting:
                                // each group fragment is offered to the
                                // newcomer's spill area all-or-nothing —
                                // adopted whole, or purged whole
                                // (Revive-style; readers fall back to the
                                // durable copies). Never a partial move.
                                if spill_on {
                                    let moving_spill: Vec<BlockId> = workers[vi]
                                        .spill
                                        .as_ref()
                                        .map(|m| {
                                            m.resident_blocks()
                                                .into_iter()
                                                .filter(|b| alive.home_of(*b) == worker)
                                                .collect()
                                        })
                                        .unwrap_or_default();
                                    let mut sbatches: Vec<(Option<GroupId>, Vec<BlockId>)> =
                                        Vec::new();
                                    let mset: FxHashSet<BlockId> =
                                        moving_spill.iter().copied().collect();
                                    let mut batched: FxHashSet<BlockId> =
                                        FxHashSet::default();
                                    if track_groups {
                                        for g in registered_groups.iter().filter(|g| {
                                            master.task_retired(g.task) == Some(false)
                                        }) {
                                            let frag: Vec<BlockId> = g
                                                .members
                                                .iter()
                                                .copied()
                                                .filter(|m| {
                                                    mset.contains(m)
                                                        && !batched.contains(m)
                                                })
                                                .collect();
                                            if !frag.is_empty() {
                                                batched.extend(frag.iter().copied());
                                                sbatches.push((Some(g.id), frag));
                                            }
                                        }
                                    }
                                    for b in moving_spill
                                        .iter()
                                        .copied()
                                        .filter(|b| !batched.contains(b))
                                    {
                                        sbatches.push((None, vec![b]));
                                    }
                                    for (gid, frag) in sbatches {
                                        let set: Vec<(BlockId, u64)> = frag
                                            .iter()
                                            .filter_map(|&b| {
                                                workers[vi]
                                                    .spill
                                                    .as_mut()
                                                    .expect("spill on")
                                                    .release(b)
                                                    .map(|bytes| (b, bytes))
                                            })
                                            .collect();
                                        if set.is_empty() {
                                            continue;
                                        }
                                        // The `dead` predicate consults the
                                        // newcomer's freshly re-seeded peer
                                        // replica, mirroring demote_evicted.
                                        let dead_set: FxHashSet<BlockId> = workers[ji]
                                            .spill
                                            .as_ref()
                                            .map(|m| m.resident_blocks())
                                            .unwrap_or_default()
                                            .into_iter()
                                            .filter(|&b| !workers[ji].peers.unconsumed(b))
                                            .collect();
                                        let outcome = workers[ji]
                                            .spill
                                            .as_mut()
                                            .expect("spill on")
                                            .offer(&set, |bb| dead_set.contains(&bb));
                                        if outcome.admitted {
                                            for &(b, _) in &set {
                                                workers[vi].store.clear_tier(b);
                                                workers[ji]
                                                    .store
                                                    .set_tier(b, BlockTier::SpilledLocal);
                                            }
                                            if !outcome.evicted.is_empty() {
                                                workers[ji].tier.spill_evictions +=
                                                    outcome.evicted.len() as u64;
                                                for &ev in &outcome.evicted {
                                                    workers[ji].store.clear_tier(ev);
                                                    trace.emit(ji + 1, Some(now), || {
                                                        TraceEvent::BlockDropped {
                                                            block: ev,
                                                            worker,
                                                        }
                                                    });
                                                    if let Some(rst) = restorer.as_mut() {
                                                        rst.note_dropped(ev);
                                                    }
                                                }
                                                handle_evictions!(
                                                    ji,
                                                    outcome.evicted,
                                                    now
                                                );
                                                let to_plan: Vec<BlockId> = outcome
                                                    .evicted
                                                    .iter()
                                                    .copied()
                                                    .filter(|bb| {
                                                        !spill_recomputed.contains(bb)
                                                    })
                                                    .collect();
                                                if !to_plan.is_empty() {
                                                    handle_tier_drops!(to_plan);
                                                }
                                            }
                                            scale.blocks_migrated += set.len() as u64;
                                            scale.migration_bytes += set
                                                .iter()
                                                .map(|(_, by)| *by)
                                                .sum::<u64>();
                                            if let Some(g) = gid {
                                                scale.groups_migrated += 1;
                                                let blocks = set.len() as u64;
                                                trace.emit(0, Some(now), || {
                                                    TraceEvent::GroupMigrated {
                                                        group: g,
                                                        from: v,
                                                        to: worker,
                                                        blocks,
                                                    }
                                                });
                                            }
                                        } else {
                                            for &(b, _) in &set {
                                                workers[vi].store.clear_tier(b);
                                                if let Some(rst) = restorer.as_mut() {
                                                    rst.forget(b);
                                                }
                                                if peer_aware
                                                    && master.fail_member(b).is_some()
                                                {
                                                    broadcast_to_alive!(b);
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                            scale.workers_joined += 1;
                        }
                    }
                }
                admit_and_dispatch!();
            }};
        }

        // Jobs arriving at dispatch 0 (or pulled in by the stall clamp if
        // the first arrival is later) start the run; their ingest ops
        // seed the event queue.
        admit_and_dispatch!();

        'events: loop {
            let Some(ev) = core.pop() else {
                // Queue drained. Jobs may remain whose arrival index the
                // quiesced queue can never reach: schedule an admission
                // event (the stall clamp pulls the next one in).
                if next_spec < order.len() {
                    core.schedule_at(now, SimEvent::Admission);
                    continue 'events;
                }
                break 'events;
            };
            now = core.now();
            match ev {
                SimEvent::OpComplete(w) => {
                    let wi = w as usize;
                    let fin = workers[wi].finishing.take();
                    if let Some(Finish::Task(tid)) = &fin {
                        running_task.remove(tid);
                    }
                    if workers[wi].busy {
                        workers[wi].busy_nanos += now - workers[wi].op_start;
                    }
                    workers[wi].busy = false;
                    match fin {
                        Some(Finish::Ingest(b, len, cache, pin)) => {
                            if cache {
                                if pin {
                                    workers[wi].store.pin(b);
                                }
                                let data = payload(len);
                                insert_demote!(wi, b, data);
                            }
                            let si = *ingest_owner.get(&b).expect("owned ingest");
                            pending_total -= 1;
                            spec_pending[si] -= 1;
                            tracker.on_block_materialized(b);
                            // Per-job ingest barrier: the owning job's
                            // gate lifts when ITS ingest completes; other
                            // jobs keep computing throughout.
                            let barrier_done = spec_pending[si] == 0;
                            if barrier_done && spec_gated[si] {
                                spec_gated[si] = false;
                                for dag in &queue.jobs[si].workload.dags {
                                    tracker.ungate_job(dag.job);
                                }
                            }
                            if ecfg.overlap_ingest || barrier_done {
                                if barrier_done && compute_start.is_none() {
                                    compute_start = Some(now);
                                }
                                // Apply due repairs, dispatch whatever is
                                // ready (held at the next kill trigger).
                                pump!();
                                if barrier_done {
                                    for i in 0..w_count {
                                        try_start!(i);
                                    }
                                }
                            }
                        }
                        Some(Finish::Task(tid)) => {
                            let task = task_index[&tid].clone();
                            trace.emit(wi + 1, Some(now), || TraceEvent::TaskComputed {
                                task: tid,
                                worker: WorkerId(wi as u32),
                            });
                            // Materialize + cache the output.
                            let data = payload(task.output_len);
                            insert_demote!(wi, task.output, data);
                            if let Some(rst) = restorer.as_mut() {
                                rst.forget(task.output);
                            }
                            trace.emit(wi + 1, Some(now), || TraceEvent::TaskPublished {
                                task: tid,
                                worker: WorkerId(wi as u32),
                                block: task.output,
                            });
                            recompute_set.materialized(task.output);
                            if let Some(d) = disp_ts.remove(&tid) {
                                lat_per_job
                                    .entry(task.job.0)
                                    .or_default()
                                    .record(now.saturating_sub(d));
                            }
                            // Release the task's restore pins after its
                            // output lands — the threaded engine releases
                            // them on RetireTask, which likewise follows
                            // the output insert.
                            if let Some(pins) = restore_pins.remove(&tid) {
                                for bb in pins {
                                    workers[alive.home_of(bb).0 as usize].store.unpin(bb);
                                }
                            }
                            // Ref counts are always maintained (recovery's
                            // "still needed" test reads them); only
                            // DAG-aware policies are told.
                            let changed = refcounts.on_task_complete(&task);
                            if dag_aware {
                                for w in alive.alive_workers() {
                                    for &(b, count) in &changed {
                                        workers[w.0 as usize].store.policy_event(
                                            PolicyEvent::RefCount { block: b, count },
                                        );
                                    }
                                }
                                msgs.refcount_updates += alive.alive_count() as u64;
                            }
                            if track_groups {
                                master.retire_task(tid);
                                for w in workers.iter_mut() {
                                    let deltas = w.peers.retire_task(tid);
                                    for (b, count) in deltas {
                                        w.store.policy_event(PolicyEvent::EffectiveCount {
                                            block: b,
                                            count,
                                        });
                                    }
                                }
                            }
                            let (_ready, job_finished) = tracker.on_task_complete(tid)?;
                            if job_finished {
                                let base = compute_start.unwrap_or(0);
                                job_done_at
                                    .insert(task.job.0, Duration::from_nanos(now - base));
                                let si = spec_of_job[&task.job];
                                job_jct.insert(
                                    task.job.0,
                                    Duration::from_nanos(now - admitted_now[si]),
                                );
                            }
                            if recompute_pending.remove(&tid) && recompute_pending.is_empty() {
                                if let Some(started) = recovery_started.take() {
                                    recovery.recovery_nanos += now - started;
                                }
                            }
                            pump!();
                        }
                        None => {}
                    }
                    try_start!(wi);
                }
                SimEvent::ReadComplete(w) => {
                    // Fair-share only: the current op's fetch phase is
                    // over; compute + output-write finishes the op.
                    let wi = w as usize;
                    core.schedule_at(now + workers[wi].post_nanos, SimEvent::OpComplete(w));
                }
                SimEvent::RestoreComplete(raw) => {
                    // Fair-share only: one pre-dispatch restore read
                    // landed. If the task already started, it counts
                    // against the running op's outstanding flows;
                    // otherwise against the pre-start tally.
                    let tid = TaskId(raw);
                    if let Some(&rw) = running_task.get(&tid) {
                        let wk = &mut workers[rw as usize];
                        wk.wait_flows = wk.wait_flows.saturating_sub(1);
                        if wk.wait_flows == 0 {
                            let at = now.max(wk.fetch_floor);
                            core.schedule_at(at, SimEvent::ReadComplete(rw));
                        }
                    } else if let Some(c) = restores_inflight.get_mut(&tid) {
                        *c -= 1;
                        if *c == 0 {
                            restores_inflight.remove(&tid);
                        }
                    }
                }
                SimEvent::Admission => {
                    admit_and_dispatch!();
                }
                SimEvent::ReportArrival(block) => {
                    if let Some(b) = master.on_eviction_report(block) {
                        broadcast_to_alive!(b);
                    }
                }
                SimEvent::BroadcastArrival(block, w) => {
                    // Deliveries addressed to a worker that died while the
                    // message was in flight are dropped on the floor.
                    if !alive.is_alive(WorkerId(w)) {
                        continue;
                    }
                    let wi = w as usize;
                    let (deltas, broken) = workers[wi].peers.apply_eviction_broadcast(block);
                    for (b, count) in deltas {
                        workers[wi]
                            .store
                            .policy_event(PolicyEvent::EffectiveCount { block: b, count });
                    }
                    if !broken.is_empty() {
                        workers[wi]
                            .store
                            .policy_event(PolicyEvent::GroupBroken { members: &broken });
                    }
                }
                SimEvent::NetWake(epoch) => {
                    // Superseded wake-ups (a flow arrived/departed since
                    // this was scheduled) are no-ops.
                    if epoch != net_epoch {
                        continue 'events;
                    }
                    let tags = net.as_mut().map(|n| n.advance(now)).unwrap_or_default();
                    for tag in tags {
                        match tag {
                            FlowTag::TaskRead { worker } => {
                                let wk = &mut workers[worker as usize];
                                wk.wait_flows = wk.wait_flows.saturating_sub(1);
                                if wk.wait_flows == 0 {
                                    let at = now.max(wk.fetch_floor);
                                    core.schedule_at(at, SimEvent::ReadComplete(worker));
                                }
                            }
                            FlowTag::Restore { task } => {
                                core.schedule_at(now, SimEvent::RestoreComplete(task));
                            }
                            FlowTag::Background => {}
                        }
                    }
                    net_wake!();
                }
            }
        }

        if !tracker.all_done() {
            return Err(crate::common::error::EngineError::Invariant(format!(
                "simulation stalled: {}/{} tasks completed",
                tracker.completed_len(),
                tracker.total()
            )));
        }

        // Final teardown sample: the timeline always ends with the
        // run's last state, whatever the dispatch count modulo.
        if tl_every != 0 {
            tl_sample!();
        }

        // --- report ---------------------------------------------------------
        let mut access = AccessStats::default();
        let mut evictions = 0u64;
        let mut rejected = 0u64;
        let mut tier = tier_global;
        for w in &workers {
            access.merge(&w.access);
            tier.merge(&w.tier);
            let cache_stats = w.store.stats();
            evictions += cache_stats.evictions;
            rejected += cache_stats.rejected;
        }
        tier.finalize();
        msgs.profile_broadcasts = master.stats.profile_broadcasts;
        let net_stats = net.as_ref().map(|n| n.stats(now)).unwrap_or_default();

        let mut jobs: Vec<JobStats> = Vec::new();
        for (si, spec) in queue.jobs.iter().enumerate() {
            for dag in &spec.workload.dags {
                jobs.push(JobStats {
                    job: dag.job.0,
                    priority: spec.priority,
                    arrival: spec.arrival,
                    admitted_at_dispatch: admitted_at[si],
                    tasks_run: tasks_run_per_job.get(&dag.job.0).copied().unwrap_or(0),
                    recompute_tasks: recompute_per_job.get(&dag.job.0).copied().unwrap_or(0),
                    access: per_job_access.get(&dag.job).copied().unwrap_or_default(),
                    jct: job_jct.get(&dag.job.0).copied().unwrap_or_default(),
                    task_latency: lat_per_job.get(&dag.job.0).cloned().unwrap_or_default(),
                    queue_wait: wait_per_job.get(&dag.job.0).cloned().unwrap_or_default(),
                });
            }
        }

        Ok(FleetReport {
            aggregate: RunReport {
                policy: ecfg.policy.name().to_string(),
                makespan: Duration::from_nanos(now),
                compute_makespan: Duration::from_nanos(now - compute_start.unwrap_or(0)),
                job_times: job_done_at,
                access,
                messages: msgs,
                tasks_run: dispatched,
                evictions,
                rejected_inserts: rejected,
                cache_capacity: ecfg.total_cache(),
                recovery,
                scale,
                tier,
                net: net_stats,
                attribution,
                timeline,
            },
            jobs,
        })
    }
}

impl crate::engine::Engine for Simulator {
    fn run(&self, queue: &JobQueue) -> Result<FleetReport> {
        self.execute(queue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{LinkConfig, PolicyKind};
    use crate::engine::Engine;
    use crate::workload;

    fn cfg(policy: PolicyKind, cache_blocks: u64) -> SimConfig {
        SimConfig::new(EngineConfig {
            num_workers: 4,
            cache_capacity_per_worker: cache_blocks * 4096 * 4,
            block_len: 4096,
            policy,
            ..Default::default()
        })
    }

    #[test]
    fn sim_is_deterministic() {
        let w = workload::multi_tenant_zip(4, 10, 4096);
        let r1 = Simulator::new(cfg(PolicyKind::Lerc, 5)).run_workload(&w).unwrap();
        let r2 = Simulator::new(cfg(PolicyKind::Lerc, 5)).run_workload(&w).unwrap();
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.access.mem_hits, r2.access.mem_hits);
        assert_eq!(r1.access.effective_hits, r2.access.effective_hits);
        assert_eq!(r1.messages.eviction_reports, r2.messages.eviction_reports);
    }

    #[test]
    fn all_tasks_complete_for_every_policy() {
        let w = workload::multi_tenant_zip(4, 10, 4096);
        for p in PolicyKind::ALL {
            let r = Simulator::new(cfg(p, 3)).run_workload(&w).unwrap();
            assert_eq!(r.tasks_run, 40, "{}", p.name());
        }
    }

    #[test]
    fn big_cache_all_effective() {
        let w = workload::multi_tenant_zip(2, 8, 4096);
        let r = Simulator::new(cfg(PolicyKind::Lru, 1000)).run_workload(&w).unwrap();
        assert_eq!(r.hit_ratio(), 1.0);
        assert_eq!(r.effective_hit_ratio(), 1.0);
    }

    #[test]
    fn paper_ordering_under_pressure() {
        // Cache ~half the input: LERC >= LRC >= LRU on effective ratio,
        // and runtime ordered the other way.
        let w = workload::multi_tenant_zip(8, 12, 4096);
        let run = |p| Simulator::new(cfg(p, 6)).run_workload(&w).unwrap();
        let lru = run(PolicyKind::Lru);
        let lrc = run(PolicyKind::Lrc);
        let lerc = run(PolicyKind::Lerc);
        assert!(lerc.effective_hit_ratio() >= lrc.effective_hit_ratio());
        assert!(lrc.effective_hit_ratio() >= lru.effective_hit_ratio());
        assert!(lerc.makespan <= lrc.makespan);
        assert!(lrc.makespan <= lru.makespan);
    }

    #[test]
    fn lru_effective_ratio_near_zero_at_small_cache() {
        let w = workload::multi_tenant_zip(8, 12, 4096);
        let r = Simulator::new(cfg(PolicyKind::Lru, 4)).run_workload(&w).unwrap();
        assert!(
            r.effective_hit_ratio() < 0.05,
            "LRU effective ratio {} not near zero",
            r.effective_hit_ratio()
        );
    }

    #[test]
    fn job_queue_runs_online_and_admits_at_arrival_boundaries() {
        use crate::common::ids::JobId;
        let q = workload::multijob_zip_shared(2, 6, 4096, true, 3);
        let sim = Simulator::new(cfg(PolicyKind::Lerc, 5));
        let fleet = Engine::run(&sim, &q).unwrap();
        assert_eq!(fleet.aggregate.tasks_run, 12);
        assert_eq!(fleet.jobs.len(), 2);
        assert_eq!(fleet.job(JobId(0)).unwrap().admitted_at_dispatch, 0);
        assert_eq!(fleet.job(JobId(1)).unwrap().admitted_at_dispatch, 3);
        let per_job: u64 = fleet.jobs.iter().map(|j| j.access.accesses).sum();
        assert_eq!(per_job, fleet.aggregate.access.accesses);
    }

    #[test]
    fn two_stage_and_mixed_complete() {
        for w in [
            workload::two_stage_zip_agg(8, 4096),
            workload::mixed_tenants(6, 6, 4096),
            workload::cross_validation(5, 6, 4096),
            workload::shared_input(3, 6, 4096),
            workload::etl_pipeline(6, 4096),
        ] {
            for p in [PolicyKind::Lru, PolicyKind::Lrc, PolicyKind::Lerc] {
                let r = Simulator::new(cfg(p, 4)).run_workload(&w).unwrap();
                assert!(r.tasks_run > 0, "{} on {}", p.name(), w.name);
            }
        }
    }

    #[test]
    fn sharded_sim_still_completes_and_conserves() {
        let w = workload::multi_tenant_zip(4, 10, 4096);
        let mut c = cfg(PolicyKind::Lerc, 5);
        c.engine.cache_shards = 4;
        let r = Simulator::new(c).run_workload(&w).unwrap();
        assert_eq!(r.tasks_run, 40);
        assert_eq!(r.access.accesses, r.access.mem_hits + r.access.disk_reads);
    }

    #[test]
    fn fair_share_mode_completes_deterministically_and_reports_net_stats() {
        let w = workload::multi_tenant_zip(4, 10, 4096);
        let mut c = cfg(PolicyKind::Lerc, 5);
        c.engine.net_model = NetModel::FairShare(LinkConfig::default());
        let r1 = Simulator::new(c.clone()).run_workload(&w).unwrap();
        let r2 = Simulator::new(c).run_workload(&w).unwrap();
        assert_eq!(r1.tasks_run, 40);
        // Conservation holds regardless of the timing model.
        assert_eq!(r1.access.accesses, r1.access.mem_hits + r1.access.disk_reads);
        // Every remote hit and durable reload became a flow.
        assert!(r1.net.flows > 0, "no flows recorded: {:?}", r1.net);
        assert!(r1.net.max_link_utilization > 0.0);
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.net.flows, r2.net.flows);
        assert_eq!(r1.net.queueing_nanos, r2.net.queueing_nanos);
    }

    #[test]
    fn join_only_plan_completes_and_migrates_rehomed_blocks() {
        use crate::recovery::TopologyPlan;
        let w = workload::multi_tenant_zip(4, 10, 4096);
        // Big cache: every re-homed block is still resident at the join,
        // so the warm-up migration is observable and deterministic.
        let mut c = cfg(PolicyKind::Lerc, 1000);
        c.engine.topology = TopologyPlan::join_at(4, 10);
        let r1 = Simulator::new(c.clone()).run_workload(&w).unwrap();
        let r2 = Simulator::new(c).run_workload(&w).unwrap();
        assert_eq!(r1.tasks_run, 40);
        assert_eq!(r1.scale.workers_joined, 1);
        assert!(
            r1.scale.blocks_migrated >= 1,
            "slot-4 blocks should warm-migrate: {:?}",
            r1.scale
        );
        assert_eq!(r1.scale, r2.scale, "migration must be deterministic");
        assert_eq!(r1.makespan, r2.makespan);
    }

    #[test]
    fn autoscale_joins_under_backlog_up_to_max_workers() {
        use crate::recovery::{AutoscaleConfig, TopologyPlan};
        let w = workload::multi_tenant_zip(8, 12, 4096);
        let mut c = cfg(PolicyKind::Lerc, 1000);
        c.engine.topology = TopologyPlan::autoscale(AutoscaleConfig {
            min_workers: 4,
            max_workers: 6,
            check_every: 8,
            scale_up_ready: 1,
            scale_down_ready: 0,
            mem_high: 2.0, // queue depth drives this test, not memory
            mem_low: 0.0,
        });
        let r = Simulator::new(c).run_workload(&w).unwrap();
        assert_eq!(r.tasks_run, 96);
        assert_eq!(
            r.scale.workers_joined, 2,
            "backlog should pull both pending slots in: {:?}",
            r.scale
        );
        assert_eq!(r.scale.workers_retired, 0);
    }

    #[test]
    fn fair_share_preserves_structural_metrics() {
        // Contention shifts durations (and may reorder completions), but
        // the work itself — tasks dispatched, input accesses — is fixed
        // by the DAG, not the timing model.
        let w = workload::multi_tenant_zip(8, 12, 4096);
        for p in [PolicyKind::Lru, PolicyKind::Lrc, PolicyKind::Lerc] {
            let flat = Simulator::new(cfg(p, 6)).run_workload(&w).unwrap();
            let mut c = cfg(p, 6);
            c.engine.net_model = NetModel::FairShare(LinkConfig::default());
            let fair = Simulator::new(c).run_workload(&w).unwrap();
            assert_eq!(flat.tasks_run, fair.tasks_run, "{}", p.name());
            assert_eq!(flat.access.accesses, fair.access.accesses, "{}", p.name());
            assert!(fair.makespan > Duration::ZERO, "{}", p.name());
        }
    }
}
