//! The discrete-event core under the simulator (DESIGN.md §6).
//!
//! One binary-heap event queue keyed by `(time, seq)`: events at the
//! same timestamp pop in schedule order (FIFO), which is the entire
//! determinism story — two runs that schedule the same events in the
//! same order replay identically, with no clocks, threads, or hash
//! iteration anywhere on the event path (the dslab `SimulationState`
//! pattern, see SNIPPETS.md №1).
//!
//! [`EventCore`] is generic over the event type so unit tests and
//! future component simulations can reuse the queue; [`SimEvent`] is
//! the simulator's concrete taxonomy.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Duration;

use crate::common::ids::BlockId;

/// A monotonic discrete-event queue: `pop` advances the clock to the
/// popped event's timestamp, `schedule_at` clamps to the present so an
/// event can never be scheduled into the past.
#[derive(Debug)]
pub struct EventCore<E> {
    heap: BinaryHeap<Reverse<(u64, u64, E)>>,
    now: u64,
    seq: u64,
}

impl<E: Ord> EventCore<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
        }
    }

    /// Current simulated time in nanoseconds (the timestamp of the last
    /// popped event; 0 before the first pop).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Schedule `ev` at absolute time `at` (clamped to `now`). Events
    /// sharing a timestamp pop in schedule order.
    pub fn schedule_at(&mut self, at: u64, ev: E) {
        self.seq += 1;
        self.heap.push(Reverse((at.max(self.now), self.seq, ev)));
    }

    /// Schedule `ev` at `now + after`.
    pub fn schedule_after(&mut self, after: Duration, ev: E) {
        let at = self.now + after.as_nanos() as u64;
        self.schedule_at(at, ev);
    }

    /// Pop the earliest event and advance the clock to its timestamp.
    pub fn pop(&mut self) -> Option<E> {
        self.heap.pop().map(|Reverse((t, _, ev))| {
            self.now = t;
            ev
        })
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E: Ord> Default for EventCore<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// The simulator's typed event taxonomy (DESIGN.md §6). Dispatch,
/// admission-boundary holds, and failure triggers are *logical-clock*
/// driven (global dispatch index, applied synchronously at quiescent
/// points inside handlers); everything time-driven goes through these.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimEvent {
    /// A worker finished its in-flight op (ingest or task) — the
    /// dispatch point for its next queued op.
    OpComplete(u32),
    /// Every input fetch for the op running at this worker has landed
    /// (fair-share mode only: flat mode folds the fetch time into the
    /// op duration directly).
    ReadComplete(u32),
    /// A pre-dispatch group-restore disk read finished for the task
    /// with this raw [`crate::common::ids::TaskId`] (fair-share mode).
    RestoreComplete(u64),
    /// Re-check job admission: scheduled when the event queue drains
    /// with jobs still waiting on unreachable arrival indices.
    Admission,
    /// An eviction report arrives at the peer-tracker master.
    ReportArrival(BlockId),
    /// An invalidation broadcast arrives at a worker.
    BroadcastArrival(BlockId, u32),
    /// The contended network's earliest in-flight transfer completes;
    /// the payload is a generation stamp — stale wakes (superseded by a
    /// later flow arrival/departure) are skipped.
    NetWake(u64),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_and_advances_clock() {
        let mut core: EventCore<u32> = EventCore::new();
        core.schedule_at(30, 3);
        core.schedule_at(10, 1);
        core.schedule_at(20, 2);
        assert_eq!(core.peek_time(), Some(10));
        assert_eq!(core.pop(), Some(1));
        assert_eq!(core.now(), 10);
        assert_eq!(core.pop(), Some(2));
        assert_eq!(core.pop(), Some(3));
        assert_eq!(core.now(), 30);
        assert_eq!(core.pop(), None);
        assert!(core.is_empty());
    }

    #[test]
    fn same_time_events_pop_fifo() {
        let mut core: EventCore<u32> = EventCore::new();
        for v in 0..8 {
            core.schedule_at(5, v);
        }
        let order: Vec<u32> = std::iter::from_fn(|| core.pop()).collect();
        assert_eq!(order, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn schedule_in_the_past_clamps_to_now() {
        let mut core: EventCore<u32> = EventCore::new();
        core.schedule_at(100, 1);
        assert_eq!(core.pop(), Some(1));
        core.schedule_at(40, 2); // earlier than now=100
        assert_eq!(core.peek_time(), Some(100));
        assert_eq!(core.pop(), Some(2));
        assert_eq!(core.now(), 100);
    }

    #[test]
    fn schedule_after_is_relative_to_now() {
        let mut core: EventCore<u32> = EventCore::new();
        core.schedule_at(50, 1);
        core.pop();
        core.schedule_after(Duration::from_nanos(25), 2);
        assert_eq!(core.peek_time(), Some(75));
        assert_eq!(core.len(), 1);
    }
}
