//! First-In-First-Out: evict by insertion order, ignoring accesses.

use crate::cache::policy::{CachePolicy, PolicyEvent};
use crate::cache::score::ScoreIndex;
use crate::common::fxhash::FxHashSet;
use crate::common::ids::BlockId;

#[derive(Debug, Default)]
pub struct Fifo {
    idx: ScoreIndex<u64>,
}

impl CachePolicy for Fifo {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } => {
                self.idx.upsert(block, tick);
            }
            PolicyEvent::Remove { block } => {
                self.idx.remove(block);
            }
            _ => {} // accesses and hints do not reorder a FIFO
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn accesses_do_not_save_a_block() {
        let mut p = Fifo::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 2 });
        p.on_event(PolicyEvent::Access { block: b(1), tick: 99 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(1)));
    }
}
