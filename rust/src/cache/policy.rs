//! The policy trait and its event vocabulary.

use crate::common::config::PolicyKind;
use crate::common::fxhash::FxHashSet;
use crate::common::ids::BlockId;

/// Logical access clock (per worker). Strictly monotone; supplied by the
/// block manager so policies stay wall-clock free and deterministic.
pub type Tick = u64;

/// Everything a policy may learn about the world.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyEvent<'a> {
    /// Block entered the cache.
    Insert { block: BlockId, tick: Tick },
    /// Cached block was read by a task.
    Access { block: BlockId, tick: Tick },
    /// Block left the cache (evicted by us, or dropped externally).
    Remove { block: BlockId },
    /// DAG hint: `block` now has `count` unmaterialized dependents (LRC).
    RefCount { block: BlockId, count: u32 },
    /// Peer hint: `block` now has `count` effective references (LERC).
    EffectiveCount { block: BlockId, count: u32 },
    /// Peer hint: a peer-group containing these members broke (Sticky).
    GroupBroken { members: &'a [BlockId] },
}

/// A cache eviction policy: a deterministic decision structure.
///
/// Invariants required of implementations:
/// * `victim` returns a block that was inserted and not yet removed, and
///   never one in `pinned`.
/// * All operations are O(log n) or better in the number of cached blocks
///   (the eviction path is the engine's hot loop — see DESIGN.md §Perf).
pub trait CachePolicy: Send {
    fn name(&self) -> &'static str;

    fn on_event(&mut self, ev: PolicyEvent<'_>);

    /// Choose the next eviction victim, skipping pinned blocks.
    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId>;

    /// Apply a batch of deferred read touches in recorded order — the
    /// sharded store's Optimistic read path records accesses off-lock
    /// and replays them here under the shard lock (DESIGN.md §7). Ticks
    /// are pre-assigned by the caller's shard clock in the same order,
    /// so the default replay-as-individual-`Access` produces decision
    /// state identical to inline touches; a policy may override to
    /// exploit the batch shape (e.g. last-touch-wins dedup for pure
    /// recency), as long as it preserves that equivalence.
    fn on_touches(&mut self, touches: &[(BlockId, Tick)]) {
        for &(block, tick) in touches {
            self.on_event(PolicyEvent::Access { block, tick });
        }
    }

    /// Number of blocks currently tracked (== cached blocks).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Construct a policy instance by kind.
pub fn new_policy(kind: PolicyKind) -> Box<dyn CachePolicy> {
    match kind {
        PolicyKind::Lru => Box::new(super::lru::Lru::default()),
        PolicyKind::Lfu => Box::new(super::lfu::Lfu::default()),
        PolicyKind::Fifo => Box::new(super::fifo::Fifo::default()),
        PolicyKind::Lrfu => Box::new(super::lrfu::Lrfu::default()),
        PolicyKind::LruK => Box::new(super::lru_k::LruK::default()),
        PolicyKind::Lrc => Box::new(super::lrc::Lrc::default()),
        PolicyKind::Lerc => Box::new(super::lerc::Lerc::default()),
        PolicyKind::Sticky => Box::new(super::sticky::Sticky::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    /// Exhaustive conformance check run against every policy: victims are
    /// always cached, never pinned, and removal empties the policy.
    #[test]
    fn all_policies_conform() {
        for kind in PolicyKind::ALL {
            let mut p = new_policy(kind);
            assert_eq!(p.len(), 0, "{}", p.name());
            for i in 0..10 {
                p.on_event(PolicyEvent::Insert {
                    block: b(i),
                    tick: i as Tick,
                });
            }
            assert_eq!(p.len(), 10);

            let mut pinned = FxHashSet::default();
            pinned.insert(b(0));
            pinned.insert(b(1));

            let mut seen = FxHashSet::default();
            for _ in 0..8 {
                let v = p.victim(&pinned).expect("non-empty cache has a victim");
                assert!(!pinned.contains(&v), "{}: evicted pinned {v}", p.name());
                assert!(seen.insert(v), "{}: duplicate victim {v}", p.name());
                p.on_event(PolicyEvent::Remove { block: v });
            }
            assert_eq!(p.len(), 2, "{}", p.name());
            // Only pinned blocks remain; victim must be None.
            assert!(p.victim(&pinned).is_none(), "{}", p.name());
        }
    }

    #[test]
    fn victim_on_empty_is_none() {
        for kind in PolicyKind::ALL {
            let mut p = new_policy(kind);
            assert!(p.victim(&FxHashSet::default()).is_none());
        }
    }

    /// The batched-touch entry point must leave every policy in exactly
    /// the state inline `Access` events would have — same victims, in the
    /// same order, under eviction pressure.
    #[test]
    fn batched_touches_equal_inline_accesses() {
        for kind in PolicyKind::ALL {
            let mut inline = new_policy(kind);
            let mut batched = new_policy(kind);
            for i in 0..12 {
                let ev = PolicyEvent::Insert {
                    block: b(i),
                    tick: i as Tick,
                };
                inline.on_event(ev.clone());
                batched.on_event(ev);
            }
            // Interleave DAG/peer hints so the stateful policies diverge
            // if batching were to reorder anything.
            for (i, count) in [(2u32, 3u32), (5, 1), (7, 0)] {
                let rc = PolicyEvent::RefCount { block: b(i), count };
                let ec = PolicyEvent::EffectiveCount { block: b(i), count };
                inline.on_event(rc.clone());
                inline.on_event(ec.clone());
                batched.on_event(rc);
                batched.on_event(ec);
            }
            let touches: Vec<(BlockId, Tick)> =
                [(3u32, 20u64), (1, 21), (3, 22), (9, 23), (0, 24)]
                    .into_iter()
                    .map(|(i, t)| (b(i), t))
                    .collect();
            for &(block, tick) in &touches {
                inline.on_event(PolicyEvent::Access { block, tick });
            }
            batched.on_touches(&touches);

            let pinned = FxHashSet::default();
            for step in 0..12 {
                let vi = inline.victim(&pinned);
                let vb = batched.victim(&pinned);
                assert_eq!(vi, vb, "{}: diverged at eviction {step}", inline.name());
                let Some(v) = vi else { break };
                inline.on_event(PolicyEvent::Remove { block: v });
                batched.on_event(PolicyEvent::Remove { block: v });
            }
        }
    }
}
