//! LRFU (Lee et al., IEEE ToC 2001): a spectrum between LRU and LFU via an
//! exponentially-decayed *Combined Recency and Frequency* (CRF) score.
//!
//! `CRF(t) = 1 + CRF(t_last) * 2^(-lambda * (t - t_last))` on each access;
//! evict the smallest CRF. `lambda -> 0` degenerates to LFU,
//! `lambda -> 1` to LRU. Default `lambda = 0.05` (a mid-spectrum setting).
//!
//! The CRF of idle blocks decays identically (same exponent base), so
//! comparing values lazily-decayed *to each block's own last-access time*
//! is NOT order-correct in general; we therefore materialize scores at a
//! common reference tick on every victim query, amortized by only
//! re-normalizing blocks whose stored epoch is stale.

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::score::{f64_key, ScoreIndex};
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::BlockId;

#[derive(Debug)]
pub struct Lrfu {
    lambda: f64,
    /// CRF valued at each block's last access tick.
    crf: FxHashMap<BlockId, (f64, Tick)>,
    /// Ordered by CRF decayed to tick 0 (a fixed reference point):
    /// `crf_at_0 = crf(t_last) * 2^(-lambda * (0 - t_last))` is monotone in
    /// the block ordering at ANY query time because all scores decay by
    /// the same factor between two instants. We store
    /// `log2(crf) + lambda * t_last` which is order-equivalent and
    /// overflow-free.
    idx: ScoreIndex<u64>,
}

impl Default for Lrfu {
    fn default() -> Self {
        Self::new(0.05)
    }
}

impl Lrfu {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0 && lambda <= 1.0);
        Self {
            lambda,
            crf: FxHashMap::default(),
            idx: ScoreIndex::new(),
        }
    }

    /// Order key: log2(crf) + lambda * t_last (shifted to be >= 0).
    fn key(&self, crf: f64, t_last: Tick) -> u64 {
        // crf >= 1 always (every access adds 1), so log2(crf) >= 0.
        f64_key(crf.log2() + self.lambda * t_last as f64)
    }

    fn touch(&mut self, block: BlockId, tick: Tick) {
        let new_crf = match self.crf.get(&block) {
            Some((old, t_last)) => {
                1.0 + old * 2f64.powf(-self.lambda * (tick - t_last) as f64)
            }
            None => 1.0,
        };
        self.crf.insert(block, (new_crf, tick));
        let key = self.key(new_crf, tick);
        self.idx.upsert(block, key);
    }
}

impl CachePolicy for Lrfu {
    fn name(&self) -> &'static str {
        "LRFU"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } | PolicyEvent::Access { block, tick } => {
                self.touch(block, tick)
            }
            PolicyEvent::Remove { block } => {
                self.idx.remove(block);
                self.crf.remove(&block);
            }
            _ => {}
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn hot_block_survives_cold_block() {
        let mut p = Lrfu::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 0 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 1 });
        for t in 2..10 {
            p.on_event(PolicyEvent::Access { block: b(1), tick: t });
        }
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn high_lambda_behaves_like_lru() {
        let mut p = Lrfu::new(1.0);
        // b1 accessed many times long ago; b2 once, recently. With
        // lambda=1 the decay halves per tick, so recency dominates.
        for t in 0..20 {
            p.on_event(PolicyEvent::Access { block: b(1), tick: t });
        }
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 200 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(1)));
    }

    #[test]
    fn low_lambda_behaves_like_lfu() {
        let mut p = Lrfu::new(1e-6);
        for t in 0..20 {
            p.on_event(PolicyEvent::Access { block: b(1), tick: t });
        }
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 21 });
        // With negligible decay, frequency dominates: b2 (1 access) loses.
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }
}
