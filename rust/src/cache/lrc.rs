//! LRC — Least Reference Count (Yu et al., INFOCOM 2017), the paper's
//! DAG-aware baseline: evict the block with the fewest unmaterialized
//! dependents, breaking ties by recency (oldest first).

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::score::ScoreIndex;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::BlockId;

#[derive(Debug, Default)]
pub struct Lrc {
    idx: ScoreIndex<(u32, Tick)>, // (ref count, last tick)
    meta: FxHashMap<BlockId, (u32, Tick)>,
    /// Reference counts arriving before the block is cached are remembered
    /// so a later insert scores correctly.
    pending_refs: FxHashMap<BlockId, u32>,
}

impl Lrc {
    fn rescore(&mut self, block: BlockId) {
        if let Some(&(refs, tick)) = self.meta.get(&block) {
            self.idx.upsert(block, (refs, tick));
        }
    }

    /// Current reference count as known to the policy (cached or pending).
    pub fn ref_count(&self, block: BlockId) -> u32 {
        self.meta
            .get(&block)
            .map(|&(r, _)| r)
            .or_else(|| self.pending_refs.get(&block).copied())
            .unwrap_or(0)
    }
}

impl CachePolicy for Lrc {
    fn name(&self) -> &'static str {
        "LRC"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } => {
                let refs = self.pending_refs.get(&block).copied().unwrap_or(0);
                self.meta.insert(block, (refs, tick));
                self.rescore(block);
            }
            PolicyEvent::Access { block, tick } => {
                if let Some(m) = self.meta.get_mut(&block) {
                    m.1 = tick;
                    self.rescore(block);
                }
            }
            PolicyEvent::Remove { block } => {
                // Keep pending_refs: the DAG count survives eviction and
                // must apply if the block is reloaded.
                if let Some((refs, _)) = self.meta.remove(&block) {
                    self.pending_refs.insert(block, refs);
                }
                self.idx.remove(block);
            }
            PolicyEvent::RefCount { block, count } => {
                self.pending_refs.insert(block, count);
                if let Some(m) = self.meta.get_mut(&block) {
                    m.0 = count;
                    self.rescore(block);
                }
            }
            // LRC is peer-agnostic — this is exactly its §II-C inefficiency.
            PolicyEvent::EffectiveCount { .. } | PolicyEvent::GroupBroken { .. } => {}
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn evicts_least_referenced() {
        let mut p = Lrc::default();
        for i in 1..=3 {
            p.on_event(PolicyEvent::Insert { block: b(i), tick: i as u64 });
        }
        p.on_event(PolicyEvent::RefCount { block: b(1), count: 3 });
        p.on_event(PolicyEvent::RefCount { block: b(2), count: 1 });
        p.on_event(PolicyEvent::RefCount { block: b(3), count: 2 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn refcount_before_insert_is_remembered() {
        let mut p = Lrc::default();
        p.on_event(PolicyEvent::RefCount { block: b(1), count: 5 });
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 2 });
        p.on_event(PolicyEvent::RefCount { block: b(2), count: 1 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
        assert_eq!(p.ref_count(b(1)), 5);
    }

    #[test]
    fn ties_break_by_recency() {
        let mut p = Lrc::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 2 });
        p.on_event(PolicyEvent::RefCount { block: b(1), count: 1 });
        p.on_event(PolicyEvent::RefCount { block: b(2), count: 1 });
        p.on_event(PolicyEvent::Access { block: b(1), tick: 3 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn zero_ref_blocks_evicted_first_regardless_of_recency() {
        let mut p = Lrc::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::RefCount { block: b(1), count: 2 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 100 });
        p.on_event(PolicyEvent::RefCount { block: b(2), count: 0 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn refcount_survives_eviction_and_reload() {
        let mut p = Lrc::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::RefCount { block: b(1), count: 4 });
        p.on_event(PolicyEvent::Remove { block: b(1) });
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 9 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 10 });
        p.on_event(PolicyEvent::RefCount { block: b(2), count: 1 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }
}
