//! The §III-A "sticky eviction" strawman: peers stick together and are
//! evicted as a whole once any of them leaves memory.
//!
//! Implementation: blocks belonging to any broken group sort strictly
//! before intact blocks (key `(0, refs, tick)` vs `(1, refs, tick)`), so a
//! single member eviction drags the rest of the group out on subsequent
//! evictions. The paper shows why this is inefficient: a block shared by
//! several tasks is surrendered even when caching it still benefits
//! another task — exactly the ablation `benches/ablation_sticky.rs`
//! measures.

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::score::ScoreIndex;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::BlockId;

#[derive(Debug, Clone, Copy, Default)]
struct Meta {
    broken: bool,
    refs: u32,
    tick: Tick,
}

#[derive(Debug, Default)]
pub struct Sticky {
    idx: ScoreIndex<(u8, u32, Tick)>,
    meta: FxHashMap<BlockId, Meta>,
    /// Blocks marked broken (or ref counts) before they were cached.
    pending: FxHashMap<BlockId, (bool, u32)>,
}

impl Sticky {
    fn rescore(&mut self, block: BlockId) {
        if let Some(m) = self.meta.get(&block) {
            let intact = if m.broken { 0u8 } else { 1u8 };
            self.idx.upsert(block, (intact, m.refs, m.tick));
        }
    }
}

impl CachePolicy for Sticky {
    fn name(&self) -> &'static str {
        "Sticky"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } => {
                let (broken, refs) = self.pending.get(&block).copied().unwrap_or((false, 0));
                self.meta.insert(block, Meta { broken, refs, tick });
                self.rescore(block);
            }
            PolicyEvent::Access { block, tick } => {
                if let Some(m) = self.meta.get_mut(&block) {
                    m.tick = tick;
                    self.rescore(block);
                }
            }
            PolicyEvent::Remove { block } => {
                if let Some(m) = self.meta.remove(&block) {
                    self.pending.insert(block, (m.broken, m.refs));
                }
                self.idx.remove(block);
            }
            PolicyEvent::RefCount { block, count } => {
                self.pending.entry(block).or_default().1 = count;
                if let Some(m) = self.meta.get_mut(&block) {
                    m.refs = count;
                    self.rescore(block);
                }
            }
            PolicyEvent::GroupBroken { members } => {
                for &block in members {
                    self.pending.entry(block).or_default().0 = true;
                    if let Some(m) = self.meta.get_mut(&block) {
                        m.broken = true;
                        self.rescore(block);
                    }
                }
            }
            PolicyEvent::EffectiveCount { .. } => {}
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn broken_group_members_go_first() {
        let mut p = Sticky::default();
        for i in 1..=4 {
            p.on_event(PolicyEvent::Insert { block: b(i), tick: i as u64 });
            p.on_event(PolicyEvent::RefCount { block: b(i), count: 5 });
        }
        let members = [b(2), b(3)];
        p.on_event(PolicyEvent::GroupBroken { members: &members });
        let v1 = p.victim(&FxHashSet::default()).unwrap();
        p.on_event(PolicyEvent::Remove { block: v1 });
        let v2 = p.victim(&FxHashSet::default()).unwrap();
        let mut got = [v1, v2];
        got.sort();
        assert_eq!(got, members);
    }

    #[test]
    fn shared_block_is_surrendered_even_if_useful() {
        // The defining inefficiency: block 1 is in a broken group but also
        // shared with another intact task; sticky evicts it anyway.
        let mut p = Sticky::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::RefCount { block: b(1), count: 2 }); // shared
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 2 });
        p.on_event(PolicyEvent::RefCount { block: b(2), count: 0 });
        let members = [b(1)];
        p.on_event(PolicyEvent::GroupBroken { members: &members });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(1)));
    }
}
