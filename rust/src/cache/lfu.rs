//! Least-Frequently-Used: evict the block with the fewest accesses,
//! breaking ties by recency (oldest first).

use crate::cache::policy::{CachePolicy, PolicyEvent};
use crate::cache::score::ScoreIndex;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::BlockId;

#[derive(Debug, Default)]
pub struct Lfu {
    idx: ScoreIndex<(u64, u64)>, // (frequency, last tick)
    freq: FxHashMap<BlockId, u64>,
}

impl Lfu {
    fn bump(&mut self, block: BlockId, tick: u64) {
        let f = self.freq.entry(block).or_insert(0);
        *f += 1;
        self.idx.upsert(block, (*f, tick));
    }
}

impl CachePolicy for Lfu {
    fn name(&self) -> &'static str {
        "LFU"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } | PolicyEvent::Access { block, tick } => {
                self.bump(block, tick)
            }
            PolicyEvent::Remove { block } => {
                self.idx.remove(block);
                self.freq.remove(&block);
            }
            _ => {}
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn evicts_least_frequent() {
        let mut p = Lfu::default();
        for i in 1..=3 {
            p.on_event(PolicyEvent::Insert { block: b(i), tick: i as u64 });
        }
        p.on_event(PolicyEvent::Access { block: b(1), tick: 4 });
        p.on_event(PolicyEvent::Access { block: b(1), tick: 5 });
        p.on_event(PolicyEvent::Access { block: b(3), tick: 6 });
        // b2 has frequency 1 (insert only).
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn frequency_resets_on_reinsert_after_remove() {
        let mut p = Lfu::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::Access { block: b(1), tick: 2 });
        p.on_event(PolicyEvent::Remove { block: b(1) });
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 3 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 4 });
        p.on_event(PolicyEvent::Access { block: b(2), tick: 5 });
        // b1 was forgotten on removal: freq 1 < freq 2.
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(1)));
    }
}
