//! The in-memory block store: payload map with byte accounting.
//!
//! Stores payloads as `Arc<[f32]>` (all engine payloads are 4-byte
//! scalars; i32 partition ids are stored bit-cast — see `runtime`).

use crate::common::fxhash::FxHashMap;
use crate::common::ids::BlockId;
use std::sync::Arc;

/// A cached block payload. Cloning is O(1) (Arc), and the flat slice
/// layout means a hit dereferences one pointer, not two (`Arc<Vec<_>>`
/// paid an extra chase through the Vec header on every element access).
/// Build one with `Arc::from(vec)` / `vec.into()`.
pub type BlockData = Arc<[f32]>;

/// Storage-tier residency of a block that has passed through the spill
/// machinery (DESIGN.md §5). Blocks that never demoted carry no tier
/// record at all — `ShardedStore::tier_of` returns `None` for them, which
/// keeps the spill-disabled engine byte-identical to the pre-spill one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BlockTier {
    /// Back in the memory store via a spill restore (plain residents have
    /// no tier record; this variant marks *restored* residents so their
    /// reads are reported as restored hits, not memory hits).
    Memory,
    /// In the home worker's local spill area.
    SpilledLocal,
    /// The bytes left both tiers (demotion refused or spill-evicted); a
    /// still-needed block in this state must be re-planned through
    /// lineage recompute.
    Dropped,
}

#[derive(Debug, Default)]
pub struct MemoryStore {
    map: FxHashMap<BlockId, BlockData>,
    used: u64,
    capacity: u64,
}

impl MemoryStore {
    pub fn new(capacity: u64) -> Self {
        Self {
            map: FxHashMap::default(),
            used: 0,
            capacity,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used)
    }

    pub fn over_capacity(&self) -> bool {
        self.used > self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, b: BlockId) -> bool {
        self.map.contains_key(&b)
    }

    pub fn get(&self, b: BlockId) -> Option<BlockData> {
        self.map.get(&b).cloned()
    }

    pub fn bytes_of(data: &BlockData) -> u64 {
        (data.len() * std::mem::size_of::<f32>()) as u64
    }

    /// Insert (or replace) a payload. Returns the new `used` total. The
    /// store intentionally allows transient over-capacity; the block
    /// manager immediately evicts back under the limit.
    pub fn put(&mut self, b: BlockId, data: BlockData) -> u64 {
        let bytes = Self::bytes_of(&data);
        if let Some(old) = self.map.insert(b, data) {
            self.used -= Self::bytes_of(&old);
        }
        self.used += bytes;
        self.used
    }

    /// Remove a payload; returns it if present.
    pub fn remove(&mut self, b: BlockId) -> Option<BlockData> {
        let old = self.map.remove(&b)?;
        self.used -= Self::bytes_of(&old);
        Some(old)
    }

    pub fn blocks(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.map.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn payload(n: usize) -> BlockData {
        Arc::from(vec![0.5; n])
    }

    #[test]
    fn byte_accounting() {
        let mut s = MemoryStore::new(1024);
        s.put(b(1), payload(64)); // 256 bytes
        assert_eq!(s.used(), 256);
        assert_eq!(s.free(), 768);
        s.put(b(2), payload(128)); // 512 bytes
        assert_eq!(s.used(), 768);
        s.remove(b(1));
        assert_eq!(s.used(), 512);
        assert!(!s.over_capacity());
    }

    #[test]
    fn replace_does_not_double_count() {
        let mut s = MemoryStore::new(1024);
        s.put(b(1), payload(64));
        s.put(b(1), payload(32));
        assert_eq!(s.used(), 128);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn transient_over_capacity_is_visible() {
        let mut s = MemoryStore::new(100);
        s.put(b(1), payload(64));
        assert!(s.over_capacity());
    }

    #[test]
    fn get_is_shared_not_copied() {
        let mut s = MemoryStore::new(1024);
        let p = payload(8);
        s.put(b(1), p.clone());
        let got = s.get(b(1)).unwrap();
        assert!(Arc::ptr_eq(&p, &got));
        assert!(s.get(b(2)).is_none());
    }

    #[test]
    fn remove_missing_is_none() {
        let mut s = MemoryStore::new(16);
        assert!(s.remove(b(9)).is_none());
        assert_eq!(s.used(), 0);
    }
}
