//! LRU-K (O'Neil et al., SIGMOD 1993) with K = 2: evict the block whose
//! K-th most recent access is oldest; blocks with fewer than K accesses
//! are preferred victims (ordered among themselves by last access).

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::score::ScoreIndex;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::BlockId;

pub const K: usize = 2;

#[derive(Debug, Default)]
pub struct LruK {
    /// Last up-to-K access ticks, most recent first.
    history: FxHashMap<BlockId, [Option<Tick>; K]>,
    /// Key: (has K accesses?, K-th recent tick or last tick).
    /// Blocks lacking K accesses sort first (0, last_tick).
    idx: ScoreIndex<(u8, Tick)>,
}

impl LruK {
    fn touch(&mut self, block: BlockId, tick: Tick) {
        let h = self.history.entry(block).or_insert([None; K]);
        // Shift history: newest at h[0].
        for i in (1..K).rev() {
            h[i] = h[i - 1];
        }
        h[0] = Some(tick);
        let key = match h[K - 1] {
            Some(kth) => (1u8, kth),
            None => (0u8, tick),
        };
        self.idx.upsert(block, key);
    }
}

impl CachePolicy for LruK {
    fn name(&self) -> &'static str {
        "LRU-2"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } | PolicyEvent::Access { block, tick } => {
                self.touch(block, tick)
            }
            PolicyEvent::Remove { block } => {
                self.idx.remove(block);
                self.history.remove(&block);
            }
            _ => {}
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn single_access_blocks_evicted_before_double_access() {
        let mut p = LruK::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 0 });
        p.on_event(PolicyEvent::Access { block: b(1), tick: 1 }); // 2 accesses
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 2 }); // 1 access
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn among_k_accessed_evicts_oldest_kth() {
        let mut p = LruK::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 0 });
        p.on_event(PolicyEvent::Access { block: b(1), tick: 10 }); // kth = 0
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 5 });
        p.on_event(PolicyEvent::Access { block: b(2), tick: 6 }); // kth = 5
        // b1's 2nd-most-recent access (0) is older than b2's (5).
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(1)));
    }
}
