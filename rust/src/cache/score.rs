//! Ordered score index shared by every policy implementation.
//!
//! A `ScoreIndex<K>` keeps cached blocks ordered by a policy-defined key
//! `K` (smallest = evict first) with O(log n) insert/update/remove and an
//! O(p log n) minimum query (p = pinned blocks skipped). This is the
//! engine's eviction hot path; see `benches/policy_micro.rs`.

use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::BlockId;
use std::collections::BTreeSet;

#[derive(Debug, Clone, Default)]
pub struct ScoreIndex<K: Ord + Copy> {
    ordered: BTreeSet<(K, BlockId)>,
    keys: FxHashMap<BlockId, K>,
}

impl<K: Ord + Copy> ScoreIndex<K> {
    pub fn new() -> Self {
        Self {
            ordered: BTreeSet::new(),
            keys: FxHashMap::default(),
        }
    }

    /// Insert or re-score a block.
    pub fn upsert(&mut self, block: BlockId, key: K) {
        if let Some(old) = self.keys.insert(block, key) {
            self.ordered.remove(&(old, block));
        }
        self.ordered.insert((key, block));
    }

    pub fn remove(&mut self, block: BlockId) -> bool {
        match self.keys.remove(&block) {
            Some(old) => self.ordered.remove(&(old, block)),
            None => false,
        }
    }

    pub fn contains(&self, block: BlockId) -> bool {
        self.keys.contains_key(&block)
    }

    pub fn key_of(&self, block: BlockId) -> Option<K> {
        self.keys.get(&block).copied()
    }

    /// Smallest-keyed block not in `pinned`.
    pub fn min_excluding(&self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.ordered
            .iter()
            .map(|(_, b)| *b)
            .find(|b| !pinned.contains(b))
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter_ordered(&self) -> impl Iterator<Item = (K, BlockId)> + '_ {
        self.ordered.iter().copied()
    }
}

/// Order-preserving map from non-negative f64 to u64 (for LRFU's CRF
/// score, which is a float but must live in an `Ord` key).
pub fn f64_key(v: f64) -> u64 {
    debug_assert!(v >= 0.0 && v.is_finite());
    v.to_bits()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn min_respects_order_and_pins() {
        let mut idx = ScoreIndex::new();
        idx.upsert(b(1), 10u64);
        idx.upsert(b(2), 5);
        idx.upsert(b(3), 7);
        assert_eq!(idx.min_excluding(&FxHashSet::default()), Some(b(2)));
        let pinned: FxHashSet<_> = [b(2)].into_iter().collect();
        assert_eq!(idx.min_excluding(&pinned), Some(b(3)));
    }

    #[test]
    fn upsert_rescores() {
        let mut idx = ScoreIndex::new();
        idx.upsert(b(1), 1u64);
        idx.upsert(b(2), 2);
        idx.upsert(b(1), 99); // re-score
        assert_eq!(idx.min_excluding(&FxHashSet::default()), Some(b(2)));
        assert_eq!(idx.len(), 2);
        assert_eq!(idx.key_of(b(1)), Some(99));
    }

    #[test]
    fn remove_is_idempotent() {
        let mut idx = ScoreIndex::new();
        idx.upsert(b(1), 1u64);
        assert!(idx.remove(b(1)));
        assert!(!idx.remove(b(1)));
        assert!(idx.is_empty());
    }

    #[test]
    fn f64_key_preserves_order() {
        let vals = [0.0, 1e-9, 0.5, 1.0, 1.5, 1e9];
        for w in vals.windows(2) {
            assert!(f64_key(w[0]) < f64_key(w[1]));
        }
    }

    #[test]
    fn tuple_keys_order_lexicographically() {
        let mut idx = ScoreIndex::new();
        idx.upsert(b(1), (1u32, 50u64));
        idx.upsert(b(2), (0u32, 99u64));
        idx.upsert(b(3), (1u32, 10u64));
        // (0, _) first, then (1, 10), then (1, 50).
        let order: Vec<_> = idx.iter_ordered().map(|(_, b)| b.index).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }
}
