//! Least-Recently-Used — Spark's default policy and the paper's baseline.

use crate::cache::policy::{CachePolicy, PolicyEvent};
use crate::cache::score::ScoreIndex;
use crate::common::fxhash::FxHashSet;
use crate::common::ids::BlockId;

/// Evicts the block with the oldest last-access tick.
#[derive(Debug, Default)]
pub struct Lru {
    idx: ScoreIndex<u64>,
}

impl CachePolicy for Lru {
    fn name(&self) -> &'static str {
        "LRU"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } | PolicyEvent::Access { block, tick } => {
                self.idx.upsert(block, tick);
            }
            PolicyEvent::Remove { block } => {
                self.idx.remove(block);
            }
            // Recency-only: DAG and peer hints are ignored.
            PolicyEvent::RefCount { .. }
            | PolicyEvent::EffectiveCount { .. }
            | PolicyEvent::GroupBroken { .. } => {}
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut p = Lru::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 2 });
        p.on_event(PolicyEvent::Insert { block: b(3), tick: 3 });
        // Touch 1 -> 2 becomes oldest.
        p.on_event(PolicyEvent::Access { block: b(1), tick: 4 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn ignores_dag_hints() {
        let mut p = Lru::default();
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        p.on_event(PolicyEvent::Insert { block: b(2), tick: 2 });
        p.on_event(PolicyEvent::RefCount { block: b(2), count: 0 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(1)));
    }
}
