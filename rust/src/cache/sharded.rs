//! The sharded, lock-striped block store: the concurrent backbone of every
//! worker's cache.
//!
//! A [`ShardedStore`] splits one worker's cache into N independent shards
//! (N rounded up to a power of two), each holding its own byte-accounted
//! [`MemoryStore`], its own [`CachePolicy`] instance, its own pin table and
//! its own logical clock, all behind a per-shard mutex. Blocks are routed
//! to shards by the engine's fxhash of their [`BlockId`], so concurrent
//! readers and writers only contend when they touch the same shard —
//! remote block reads no longer serialize against the home worker's
//! entire cache.
//!
//! With `shards = 1` the store is bit-for-bit equivalent to the original
//! monolithic block manager: one policy instance, one global eviction
//! order, one tick stream. The paper-reproduction experiments run with a
//! single shard so eviction decisions stay exactly comparable; the
//! multi-worker throughput path (`benches/store_throughput.rs`) runs with
//! many.
//!
//! ## Group pinning (LERC's all-or-nothing sticky sets)
//!
//! LERC's correctness argument is per peer-group: caching half a group
//! buys nothing (paper §II-C). [`ShardedStore::pin_group`] therefore pins
//! a whole member set atomically — all members or none — even when the
//! members hash to different shards. Coordination goes through a small
//! cross-shard *intent table* instead of a global lock: members are
//! pinned one shard at a time (pins are rolled back if any member is
//! missing), and the group is recorded in the intent table only once every
//! member is pinned. Because pinned blocks are never evicted, the
//! observable invariant is simple: **every group in the intent table has
//! all of its members cached and pinned** at every instant. The threaded
//! stress test (`rust/tests/sharded_store_stress.rs`) hammers this.
//!
//! ## The optimistic read path (`StoreReadPath::Optimistic`)
//!
//! Under the default Locked path every `get` takes the shard mutex just
//! to bump recency state. The Optimistic path (DESIGN.md §7) decouples
//! payload lookup from policy bookkeeping:
//!
//! * Each shard keeps a **read-mostly index** of `(payload, tier)`
//!   snapshots guarded by a seqlock-style generation counter: readers
//!   load the generation, take a brief shared read-lock on the index
//!   (never the shard mutex), clone the `Arc`, drop the guard, and
//!   re-validate the generation. Writers bump the generation to odd,
//!   splice the affected entries under the shard mutex, and bump it back
//!   to even — so a validated snapshot observed payload **and** tier at
//!   one instant (the §5 spill invariant holds across optimistic reads).
//! * Read touches go into a per-shard **lock-free MPSC ring**
//!   (BP-Wrapper style). The ring is drained — in push order, with ticks
//!   assigned at drain — under the shard lock before every mutation
//!   (insert/remove/policy event/pin_group/clear). A full ring makes the
//!   reader drain inline under the lock, so no touch is ever lost.
//!
//! Exactness boundary: for any program-order (happens-before) history a
//! shard's policy hears the identical `(event, tick)` stream as Locked
//! mode, because a touch always drains before the next mutation of its
//! shard. Only truly concurrent read/write races can land a touch later
//! than a Locked mutex would have serialized it — orderings that were
//! already arrival-order nondeterministic under the mutex. The
//! `shards = 1` Locked configuration the paper experiments run is
//! untouched byte-for-byte.

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::store::{BlockData, BlockTier, MemoryStore};
use crate::common::config::{PolicyKind, StoreReadPath};
use crate::common::error::{EngineError, Result};
use crate::common::fxhash::{FxBuildHasher, FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, DatasetId, GroupId};
use std::hash::{BuildHasher, Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, RwLock};

/// Default per-shard deferred-touch ring capacity (entries).
pub const DEFAULT_TOUCH_BUFFER: usize = 1024;

fn encode_block(b: BlockId) -> u64 {
    ((b.dataset.0 as u64) << 32) | b.index as u64
}

fn decode_block(key: u64) -> BlockId {
    BlockId::new(DatasetId((key >> 32) as u32), key as u32)
}

/// One slot of the deferred-touch ring. `seq` is the Vyukov sequence
/// cursor that makes the slot hand-off safe without locks.
struct TouchSlot {
    seq: AtomicUsize,
    key: AtomicU64,
}

/// Bounded lock-free MPSC ring of read touches (Vyukov bounded-queue
/// slots). Producers are the optimistic readers; the single consumer is
/// whoever holds the shard mutex (drains only ever run under it, which
/// is what makes single-consumer safe).
struct TouchRing {
    slots: Box<[TouchSlot]>,
    mask: usize,
    head: AtomicUsize,
    tail: AtomicUsize,
}

impl TouchRing {
    fn new(capacity: usize) -> Self {
        let cap = capacity.max(1).next_power_of_two();
        let slots = (0..cap)
            .map(|i| TouchSlot {
                seq: AtomicUsize::new(i),
                key: AtomicU64::new(0),
            })
            .collect();
        Self {
            slots,
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Multi-producer push. Returns `false` when the ring is full — the
    /// caller then drains under the shard lock and applies its touch
    /// inline, so a full ring bounds lag, never loses an access.
    fn push(&self, key: u64) -> bool {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        slot.key.store(key, Ordering::Relaxed);
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return true;
                    }
                    Err(current) => pos = current,
                }
            } else if dif < 0 {
                return false;
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Single-consumer pop; the caller must hold the shard mutex.
    fn pop(&self) -> Option<u64> {
        let pos = self.tail.load(Ordering::Relaxed);
        let slot = &self.slots[pos & self.mask];
        let seq = slot.seq.load(Ordering::Acquire);
        if seq != pos.wrapping_add(1) {
            return None;
        }
        let key = slot.key.load(Ordering::Relaxed);
        slot.seq
            .store(pos.wrapping_add(self.mask).wrapping_add(1), Ordering::Release);
        self.tail.store(pos.wrapping_add(1), Ordering::Relaxed);
        Some(key)
    }
}

/// One coherent `(payload, tier)` snapshot in a shard's read index. The
/// two fields are always spliced together under one generation bump, so
/// an optimistic reader can never observe a resident payload paired with
/// a stale `SpilledLocal`/`Dropped` tier (DESIGN.md §5).
#[derive(Clone)]
struct ReadEntry {
    data: Option<BlockData>,
    tier: Option<BlockTier>,
}

/// The lock-free side of one shard: seqlock generation + read-mostly
/// index + deferred-touch ring + off-lock hit/miss counters. Present
/// only under [`StoreReadPath::Optimistic`].
struct ReadSide {
    /// Seqlock generation: even = stable, odd = a publisher is splicing.
    /// Publishers only ever run under the shard mutex, so generations
    /// move strictly forward.
    gen: AtomicU64,
    index: RwLock<FxHashMap<BlockId, ReadEntry>>,
    touches: TouchRing,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ReadSide {
    fn new(touch_capacity: usize) -> Self {
        Self {
            gen: AtomicU64::new(0),
            index: RwLock::new(FxHashMap::default()),
            touches: TouchRing::new(touch_capacity),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A coherent snapshot of `b`, or `None` if the generation moved
    /// under us twice (persistent write churn) — the caller then falls
    /// back to the locked path. `Some(entry)` with empty fields is a
    /// *validated miss*, not a failure.
    fn snapshot(&self, b: BlockId) -> Option<ReadEntry> {
        for _ in 0..2 {
            let before = self.gen.load(Ordering::Acquire);
            if before & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let entry = {
                let idx = self.index.read().expect("read index poisoned");
                idx.get(&b).cloned()
            };
            let after = self.gen.load(Ordering::Acquire);
            if before == after {
                return Some(entry.unwrap_or(ReadEntry {
                    data: None,
                    tier: None,
                }));
            }
        }
        None
    }
}

/// Per-store cache counters (aggregated over shards on read).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub inserts: u64,
    pub evictions: u64,
    /// Inserts evicted within the same insert call (admission refusals).
    pub rejected: u64,
    pub mem_hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn merge(&mut self, other: &CacheStats) {
        self.inserts += other.inserts;
        self.evictions += other.evictions;
        self.rejected += other.rejected;
        self.mem_hits += other.mem_hits;
        self.misses += other.misses;
    }
}

/// Result of an insert: which blocks were evicted to make room, and
/// whether the inserted block itself survived.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InsertOutcome {
    pub evicted: Vec<BlockId>,
    pub admitted: bool,
}

/// One lock-striped slice of the cache: store + policy + pins + clock.
struct Shard {
    store: MemoryStore,
    policy: Box<dyn CachePolicy>,
    /// Blocks exempt from eviction (the set handed to `CachePolicy::victim`).
    pinned: FxHashSet<BlockId>,
    /// Pin reference counts: a block pinned by both an ingest pin and a
    /// task group pin stays pinned until *both* release it.
    pin_counts: FxHashMap<BlockId, u32>,
    /// Tier residency of blocks that passed through the spill machinery
    /// (empty while the spill tier is disabled — see DESIGN.md §5).
    tier: FxHashMap<BlockId, BlockTier>,
    tick: Tick,
    stats: CacheStats,
    /// Reusable drain buffer for the deferred-touch ring (avoids a fresh
    /// allocation per drain; empty between drains).
    touch_scratch: Vec<(BlockId, Tick)>,
}

impl Shard {
    fn new(capacity: u64, kind: PolicyKind) -> Self {
        Self {
            store: MemoryStore::new(capacity),
            policy: crate::cache::policy::new_policy(kind),
            pinned: FxHashSet::default(),
            pin_counts: FxHashMap::default(),
            tier: FxHashMap::default(),
            tick: 0,
            stats: CacheStats::default(),
            touch_scratch: Vec::new(),
        }
    }

    fn next_tick(&mut self) -> Tick {
        self.tick += 1;
        self.tick
    }

    /// Drain the deferred-touch ring in push order, assigning ticks at
    /// drain time, and replay it through the policy's batched entry
    /// point. Touches for blocks no longer resident are skipped without
    /// consuming a tick (their block's `Remove` already retired them).
    /// Caller holds the shard mutex (the ring's single-consumer rule).
    fn apply_touches(&mut self, ring: &TouchRing) {
        debug_assert!(self.touch_scratch.is_empty());
        while let Some(key) = ring.pop() {
            let b = decode_block(key);
            if self.store.contains(b) {
                let tick = self.next_tick();
                self.touch_scratch.push((b, tick));
            }
        }
        if !self.touch_scratch.is_empty() {
            let batch = std::mem::take(&mut self.touch_scratch);
            self.policy.on_touches(&batch);
            self.touch_scratch = batch;
            self.touch_scratch.clear();
        }
    }

    fn get(&mut self, b: BlockId) -> Option<BlockData> {
        match self.store.get(b) {
            Some(data) => {
                let tick = self.next_tick();
                self.policy.on_event(PolicyEvent::Access { block: b, tick });
                self.stats.mem_hits += 1;
                Some(data)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert then evict back under the shard's capacity — the same
    /// admission-control loop the monolithic manager ran: the new block
    /// participates in victim selection, so a policy may refuse it by
    /// evicting it immediately (LERC's "give up on ineffective hits").
    /// Victim payloads ride along so a spill-enabled caller can demote
    /// the bytes instead of dropping them (same order as `evicted`).
    fn insert(&mut self, b: BlockId, data: BlockData) -> (InsertOutcome, Vec<BlockData>) {
        let bytes = MemoryStore::bytes_of(&data);
        if bytes > self.store.capacity() {
            self.stats.rejected += 1;
            return (
                InsertOutcome {
                    evicted: vec![],
                    admitted: false,
                },
                vec![],
            );
        }
        let tick = self.next_tick();
        self.store.put(b, data);
        // A (re-)materialized block is plain memory again, whatever tier
        // record an earlier demotion left behind.
        self.tier.remove(&b);
        self.policy.on_event(PolicyEvent::Insert { block: b, tick });
        self.stats.inserts += 1;

        let mut evicted = Vec::new();
        let mut payloads = Vec::new();
        while self.store.over_capacity() {
            let Some(victim) = self.policy.victim(&self.pinned) else {
                // Everything remaining is pinned; caller sized pins wrong.
                break;
            };
            if let Some(data) = self.store.remove(victim) {
                payloads.push(data);
            }
            self.policy.on_event(PolicyEvent::Remove { block: victim });
            self.stats.evictions += 1;
            if victim == b {
                self.stats.rejected += 1;
            }
            evicted.push(victim);
        }
        let admitted = !evicted.contains(&b);
        (InsertOutcome { evicted, admitted }, payloads)
    }

    fn remove(&mut self, b: BlockId) -> Option<BlockData> {
        let data = self.store.remove(b)?;
        self.policy.on_event(PolicyEvent::Remove { block: b });
        Some(data)
    }

    fn pin(&mut self, b: BlockId) {
        let count = self.pin_counts.entry(b).or_insert(0);
        *count += 1;
        self.pinned.insert(b);
    }

    fn unpin(&mut self, b: BlockId) {
        if let Some(count) = self.pin_counts.get_mut(&b) {
            *count -= 1;
            if *count == 0 {
                self.pin_counts.remove(&b);
                self.pinned.remove(&b);
            }
        }
    }

    fn check_invariants(&self, idx: usize) -> Result<()> {
        if self.store.len() != self.policy.len() {
            return Err(EngineError::Invariant(format!(
                "shard {idx}: store has {} blocks, policy tracks {}",
                self.store.len(),
                self.policy.len()
            )));
        }
        let recounted: u64 = self
            .store
            .blocks()
            .map(|b| MemoryStore::bytes_of(&self.store.get(b).expect("listed block present")))
            .sum();
        if recounted != self.store.used() {
            return Err(EngineError::Invariant(format!(
                "shard {idx}: byte accounting drifted ({} used vs {} recounted)",
                self.store.used(),
                recounted
            )));
        }
        for (b, t) in &self.tier {
            let resident = self.store.contains(*b);
            match t {
                BlockTier::Memory if !resident => {
                    return Err(EngineError::Invariant(format!(
                        "shard {idx}: {b} marked restored-Memory but not resident"
                    )));
                }
                BlockTier::SpilledLocal | BlockTier::Dropped if resident => {
                    return Err(EngineError::Invariant(format!(
                        "shard {idx}: {b} marked {t:?} but still resident in memory"
                    )));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// One lock stripe plus its optional lock-free read side.
struct ShardSlot {
    shard: Mutex<Shard>,
    /// `Some` only under [`StoreReadPath::Optimistic`].
    read: Option<ReadSide>,
}

/// A lock-striped, byte-accounted block cache shared across threads.
///
/// All methods take `&self`; synchronization is internal and per shard.
/// See the module docs for the sharding, group-pinning, and optimistic
/// read-path designs.
pub struct ShardedStore {
    shards: Vec<ShardSlot>,
    hasher: FxBuildHasher,
    capacity: u64,
    kind: PolicyKind,
    read_path: StoreReadPath,
    /// Cross-shard group-pin intent table: group → its pinned members.
    intents: Mutex<FxHashMap<GroupId, Vec<BlockId>>>,
}

impl ShardedStore {
    /// Build a store of `shards` stripes (rounded up to a power of two;
    /// 0 is treated as 1). Capacity is split evenly across shards, with
    /// the remainder bytes going to the lowest-indexed shards so the
    /// total is exact. Reads take the Locked path — byte-identical to
    /// the historical store; see [`Self::with_read_path`].
    pub fn new(capacity: u64, kind: PolicyKind, shards: usize) -> Self {
        Self::with_read_path(
            capacity,
            kind,
            shards,
            StoreReadPath::default(),
            DEFAULT_TOUCH_BUFFER,
        )
    }

    /// [`Self::new`] with an explicit read path. `touch_buffer` is the
    /// per-shard deferred-touch ring capacity in entries (rounded up to
    /// a power of two; only meaningful under Optimistic).
    pub fn with_read_path(
        capacity: u64,
        kind: PolicyKind,
        shards: usize,
        path: StoreReadPath,
        touch_buffer: usize,
    ) -> Self {
        let n = shards.max(1).next_power_of_two();
        let base = capacity / n as u64;
        let rem = capacity % n as u64;
        let shards = (0..n)
            .map(|i| {
                let extra = if (i as u64) < rem { 1 } else { 0 };
                ShardSlot {
                    shard: Mutex::new(Shard::new(base + extra, kind)),
                    read: match path {
                        StoreReadPath::Locked => None,
                        StoreReadPath::Optimistic => Some(ReadSide::new(touch_buffer)),
                    },
                }
            })
            .collect();
        Self {
            shards,
            hasher: FxBuildHasher::default(),
            capacity,
            kind,
            read_path: path,
            intents: Mutex::new(FxHashMap::default()),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn policy_name(&self) -> &'static str {
        self.kind.name()
    }

    pub fn read_path(&self) -> StoreReadPath {
        self.read_path
    }

    fn shard_idx_of(&self, b: BlockId) -> usize {
        let mut h = self.hasher.build_hasher();
        b.hash(&mut h);
        h.finish() as usize & (self.shards.len() - 1)
    }

    fn slot_of(&self, b: BlockId) -> &ShardSlot {
        &self.shards[self.shard_idx_of(b)]
    }

    fn lock_shard_of(&self, b: BlockId) -> MutexGuard<'_, Shard> {
        self.slot_of(b).shard.lock().expect("shard lock poisoned")
    }

    /// Lock `b`'s shard for a mutation: drains the deferred-touch ring
    /// first so every pending read touch is replayed — in push order,
    /// ticks assigned now — *before* the mutation's own policy events.
    /// This is what keeps program-order histories exact (module docs).
    fn lock_shard_draining(&self, b: BlockId) -> MutexGuard<'_, Shard> {
        let slot = self.slot_of(b);
        let mut shard = slot.shard.lock().expect("shard lock poisoned");
        if let Some(read) = &slot.read {
            shard.apply_touches(&read.touches);
        }
        shard
    }

    /// Re-publish the read-index entries for `affected` blocks from the
    /// shard's authoritative state, under one seqlock generation bump.
    /// Callers hold the shard mutex, so publishers never race each other.
    fn publish(read: &ReadSide, shard: &Shard, affected: impl IntoIterator<Item = BlockId>) {
        let before = read.gen.load(Ordering::Relaxed);
        read.gen.store(before.wrapping_add(1), Ordering::Release);
        {
            let mut idx = read.index.write().expect("read index poisoned");
            for b in affected {
                let data = shard.store.get(b);
                let tier = shard.tier.get(&b).copied();
                if data.is_none() && tier.is_none() {
                    idx.remove(&b);
                } else {
                    idx.insert(b, ReadEntry { data, tier });
                }
            }
        }
        read.gen.store(before.wrapping_add(2), Ordering::Release);
    }

    /// Record an optimistic hit's policy touch. The lock-free push is
    /// the happy path; a full ring drains inline under the shard lock
    /// (applying this touch too), so no access is ever lost.
    fn record_touch(&self, slot: &ShardSlot, read: &ReadSide, b: BlockId) {
        if read.touches.push(encode_block(b)) {
            return;
        }
        let mut shard = slot.shard.lock().expect("shard lock poisoned");
        shard.apply_touches(&read.touches);
        if shard.store.contains(b) {
            let tick = shard.next_tick();
            shard.policy.on_event(PolicyEvent::Access { block: b, tick });
        }
    }

    /// Drain every shard's deferred-touch ring now (e.g. before reading
    /// policy state at a quiescent point). No-op under Locked.
    pub fn flush_touches(&self) {
        for slot in &self.shards {
            if let Some(read) = &slot.read {
                let mut shard = slot.shard.lock().expect("shard lock poisoned");
                shard.apply_touches(&read.touches);
            }
        }
    }

    /// Quiescent-point settling hook for the flight recorder's drain rule
    /// (DESIGN.md §8): the driver calls this only when no task is in
    /// flight anywhere, so taking the shard locks here cannot contend
    /// with the optimistic read path. Today it just drains the deferred
    /// touches; keep any future quiescent-only maintenance behind it.
    pub fn quiesce(&self) {
        self.flush_touches();
    }

    /// Read a block, recording the access (hit or miss) in the shard's
    /// policy and stats. On the Optimistic path a resident block is
    /// served without the shard mutex: one seqlock-validated index read,
    /// one `Arc` bump, one lock-free touch push.
    pub fn get(&self, b: BlockId) -> Option<BlockData> {
        let slot = self.slot_of(b);
        if let Some(read) = &slot.read {
            if let Some(entry) = read.snapshot(b) {
                return match entry.data {
                    Some(data) => {
                        read.hits.fetch_add(1, Ordering::Relaxed);
                        self.record_touch(slot, read, b);
                        Some(data)
                    }
                    None => {
                        read.misses.fetch_add(1, Ordering::Relaxed);
                        None
                    }
                };
            }
            // Persistent generation churn: serialize with the writers.
            let mut shard = slot.shard.lock().expect("shard lock poisoned");
            shard.apply_touches(&read.touches);
            return shard.get(b);
        }
        self.lock_shard_of(b).get(b)
    }

    /// [`Self::get`] plus the block's tier record in one coherent
    /// snapshot — the spill-enabled hot read path classifies restored/
    /// spilled/dropped reads without a second round trip, and payload
    /// and tier are observed at the same instant (§5 invariant; on the
    /// Optimistic path the seqlock validation guarantees it).
    pub fn get_with_tier(&self, b: BlockId) -> (Option<BlockData>, Option<BlockTier>) {
        let slot = self.slot_of(b);
        if let Some(read) = &slot.read {
            if let Some(entry) = read.snapshot(b) {
                match entry.data {
                    Some(data) => {
                        read.hits.fetch_add(1, Ordering::Relaxed);
                        self.record_touch(slot, read, b);
                        return (Some(data), entry.tier);
                    }
                    None => {
                        read.misses.fetch_add(1, Ordering::Relaxed);
                        return (None, entry.tier);
                    }
                }
            }
            let mut shard = slot.shard.lock().expect("shard lock poisoned");
            shard.apply_touches(&read.touches);
            let data = shard.get(b);
            let tier = shard.tier.get(&b).copied();
            return (data, tier);
        }
        let mut shard = self.lock_shard_of(b);
        let data = shard.get(b);
        let tier = shard.tier.get(&b).copied();
        (data, tier)
    }

    /// Non-mutating presence check (no access recorded).
    pub fn contains(&self, b: BlockId) -> bool {
        let slot = self.slot_of(b);
        if let Some(read) = &slot.read {
            if let Some(entry) = read.snapshot(b) {
                return entry.data.is_some();
            }
        }
        self.lock_shard_of(b).store.contains(b)
    }

    /// Insert a block, evicting shard-local victims until under capacity.
    /// A block larger than its shard's capacity is rejected outright.
    pub fn insert(&self, b: BlockId, data: BlockData) -> InsertOutcome {
        self.insert_retaining(b, data).0
    }

    /// [`Self::insert`], additionally returning the victims' payloads
    /// (aligned with `InsertOutcome::evicted`) — the demote-instead-of-
    /// drop hook: a spill-enabled caller persists the bytes to the spill
    /// tier instead of letting them drop here.
    pub fn insert_retaining(&self, b: BlockId, data: BlockData) -> (InsertOutcome, Vec<BlockData>) {
        let slot = self.slot_of(b);
        let mut shard = slot.shard.lock().expect("shard lock poisoned");
        if let Some(read) = &slot.read {
            shard.apply_touches(&read.touches);
        }
        let (outcome, payloads) = shard.insert(b, data);
        if let Some(read) = &slot.read {
            Self::publish(
                read,
                &shard,
                std::iter::once(b).chain(outcome.evicted.iter().copied()),
            );
        }
        (outcome, payloads)
    }

    /// Drop a block without policy consultation (e.g. external uncache).
    /// Pinned blocks are refused (`None`) — an in-use block cannot be
    /// uncached, which is what keeps the group-pin invariant (“every
    /// intent-table member is resident”) unconditional.
    pub fn remove(&self, b: BlockId) -> Option<BlockData> {
        let slot = self.slot_of(b);
        let mut shard = slot.shard.lock().expect("shard lock poisoned");
        if shard.pinned.contains(&b) {
            return None;
        }
        if let Some(read) = &slot.read {
            shard.apply_touches(&read.touches);
        }
        let out = shard.remove(b);
        if let Some(read) = &slot.read {
            Self::publish(read, &shard, [b]);
        }
        out
    }

    /// Tier residency of `b`, if it ever passed through the spill
    /// machinery (`None` for plain residents and unknown blocks — the
    /// spill-disabled store never records tiers at all).
    pub fn tier_of(&self, b: BlockId) -> Option<BlockTier> {
        let slot = self.slot_of(b);
        if let Some(read) = &slot.read {
            if let Some(entry) = read.snapshot(b) {
                return entry.tier;
            }
        }
        self.lock_shard_of(b).tier.get(&b).copied()
    }

    /// Record a tier transition for `b` (demotion, drop, restore).
    pub fn set_tier(&self, b: BlockId, tier: BlockTier) {
        let slot = self.slot_of(b);
        let mut shard = slot.shard.lock().expect("shard lock poisoned");
        shard.tier.insert(b, tier);
        if let Some(read) = &slot.read {
            Self::publish(read, &shard, [b]);
        }
    }

    /// Forget `b`'s tier record (it re-materialized through the normal
    /// insert path, or its job is gone).
    pub fn clear_tier(&self, b: BlockId) {
        let slot = self.slot_of(b);
        let mut shard = slot.shard.lock().expect("shard lock poisoned");
        shard.tier.remove(&b);
        if let Some(read) = &slot.read {
            Self::publish(read, &shard, [b]);
        }
    }

    /// Resident size of `b` in bytes without recording an access (the
    /// demotion planner sizes candidate sets with this; a policy-visible
    /// `get` here would perturb recency state).
    pub fn peek_bytes(&self, b: BlockId) -> Option<u64> {
        let slot = self.slot_of(b);
        if let Some(read) = &slot.read {
            if let Some(entry) = read.snapshot(b) {
                return entry.data.map(|d| MemoryStore::bytes_of(&d));
            }
        }
        let shard = self.lock_shard_of(b);
        shard.store.get(b).map(|d| MemoryStore::bytes_of(&d))
    }

    /// Is `b` currently pinned? (Demotion never touches pinned blocks —
    /// a pin asserts residency for an in-flight task.)
    pub fn is_pinned(&self, b: BlockId) -> bool {
        self.lock_shard_of(b).pinned.contains(&b)
    }

    /// Pin a block: exempt from eviction until unpinned as many times as
    /// it was pinned. Pinning a not-yet-cached block is allowed (ingest
    /// pins land before the insert).
    pub fn pin(&self, b: BlockId) {
        self.lock_shard_of(b).pin(b);
    }

    pub fn unpin(&self, b: BlockId) {
        self.lock_shard_of(b).unpin(b);
    }

    /// Atomically pin every member of a group, or none (the LERC sticky
    /// set). Returns `false` — with no pins retained — if any member is
    /// not currently cached or the group id is already pinned. On success
    /// the group is recorded in the intent table until [`Self::unpin_group`].
    ///
    /// Members are pinned one shard-lock at a time; the intent is
    /// registered only after the last pin lands, so observers holding the
    /// intent table always see fully-pinned groups.
    pub fn pin_group(&self, group: GroupId, members: &[BlockId]) -> bool {
        if self
            .intents
            .lock()
            .expect("intent lock poisoned")
            .contains_key(&group)
        {
            return false;
        }
        let mut pinned: Vec<BlockId> = Vec::with_capacity(members.len());
        for &b in members {
            // Drain deferred touches at pin time: a group pin brackets a
            // task's reads, so pending accesses must reach the policy
            // before the pin window's eviction decisions.
            let mut shard = self.lock_shard_draining(b);
            if !shard.store.contains(b) {
                drop(shard);
                for &p in &pinned {
                    self.lock_shard_of(p).unpin(p);
                }
                return false;
            }
            shard.pin(b);
            pinned.push(b);
        }
        let mut intents = self.intents.lock().expect("intent lock poisoned");
        // Two racing pin_group calls for the same id can both pass the
        // early check; the loser rolls its pins back.
        if intents.contains_key(&group) {
            drop(intents);
            for &p in &pinned {
                self.lock_shard_of(p).unpin(p);
            }
            return false;
        }
        intents.insert(group, pinned);
        true
    }

    /// Release a group pinned by [`Self::pin_group`]. No-op for unknown ids.
    pub fn unpin_group(&self, group: GroupId) {
        let members = self
            .intents
            .lock()
            .expect("intent lock poisoned")
            .remove(&group);
        if let Some(members) = members {
            for b in members {
                self.lock_shard_of(b).unpin(b);
            }
        }
    }

    /// Number of groups currently holding pins.
    pub fn pinned_group_count(&self) -> usize {
        self.intents.lock().expect("intent lock poisoned").len()
    }

    /// Drop every cached block, pin and group intent — a worker failure,
    /// not an eviction: the per-shard policies are told `Remove` so their
    /// indices stay consistent, but no eviction is counted and no victim
    /// is consulted. Returns the blocks that were resident.
    pub fn clear(&self) -> Vec<BlockId> {
        self.intents.lock().expect("intent lock poisoned").clear();
        let mut dropped = Vec::new();
        for slot in &self.shards {
            let mut shard = slot.shard.lock().expect("shard lock poisoned");
            if let Some(read) = &slot.read {
                // Purge, don't apply: the worker died mid-flight, and a
                // pending touch replayed after a later re-insert would be
                // an access the Locked history never delivered.
                while read.touches.pop().is_some() {}
            }
            let blocks: Vec<BlockId> = shard.store.blocks().collect();
            for b in blocks {
                shard.store.remove(b);
                shard.policy.on_event(PolicyEvent::Remove { block: b });
                dropped.push(b);
            }
            shard.pinned.clear();
            shard.pin_counts.clear();
            shard.tier.clear();
            if let Some(read) = &slot.read {
                let before = read.gen.load(Ordering::Relaxed);
                read.gen.store(before.wrapping_add(1), Ordering::Release);
                read.index.write().expect("read index poisoned").clear();
                read.gen.store(before.wrapping_add(2), Ordering::Release);
            }
        }
        dropped
    }

    /// Forward a DAG/peer hint to the owning shard's policy. Group-wide
    /// events are split per shard so each policy instance only hears
    /// about blocks it can own.
    pub fn policy_event(&self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, .. }
            | PolicyEvent::Access { block, .. }
            | PolicyEvent::Remove { block }
            | PolicyEvent::RefCount { block, .. }
            | PolicyEvent::EffectiveCount { block, .. } => {
                // Drain first: an external hint must order after the read
                // touches that preceded it in program order.
                self.lock_shard_draining(block).policy.on_event(ev);
            }
            PolicyEvent::GroupBroken { members } => {
                let mut by_shard: FxHashMap<usize, Vec<BlockId>> = FxHashMap::default();
                for &b in members {
                    by_shard.entry(self.shard_idx_of(b)).or_default().push(b);
                }
                for (idx, subset) in by_shard {
                    let slot = &self.shards[idx];
                    let mut shard = slot.shard.lock().expect("shard lock poisoned");
                    if let Some(read) = &slot.read {
                        shard.apply_touches(&read.touches);
                    }
                    shard
                        .policy
                        .on_event(PolicyEvent::GroupBroken { members: &subset });
                }
            }
        }
    }

    pub fn used(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.shard.lock().expect("shard lock poisoned").store.used())
            .sum()
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.shard.lock().expect("shard lock poisoned").store.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn pinned_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.shard.lock().expect("shard lock poisoned").pinned.len())
            .sum()
    }

    pub fn cached_blocks(&self) -> Vec<BlockId> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.shard.lock().expect("shard lock poisoned").store.blocks());
        }
        out
    }

    /// Aggregate counters across shards, folding in the off-lock hit/
    /// miss counters the Optimistic read path records outside the shard
    /// stats.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.shards {
            total.merge(&s.shard.lock().expect("shard lock poisoned").stats);
            if let Some(read) = &s.read {
                total.mem_hits += read.hits.load(Ordering::Relaxed);
                total.misses += read.misses.load(Ordering::Relaxed);
            }
        }
        total
    }

    /// Invariants: per shard, store and policy agree on membership and the
    /// byte accounting re-sums exactly; cross-shard, every pinned group's
    /// members are cached and pinned; under Optimistic, the read index
    /// mirrors the authoritative store ∪ tier state entry-for-entry.
    /// Used by tests and the stress suite.
    pub fn check_invariants(&self) -> Result<()> {
        for (idx, slot) in self.shards.iter().enumerate() {
            let shard = slot.shard.lock().expect("shard lock poisoned");
            shard.check_invariants(idx)?;
            if let Some(read) = &slot.read {
                Self::check_read_index(idx, read, &shard)?;
            }
        }
        self.check_group_invariants()
    }

    /// The read index must be a bijective mirror of the shard: every
    /// entry matches the store/tier maps, and the entry counts equal the
    /// authoritative counts (so nothing is missing either).
    fn check_read_index(idx: usize, read: &ReadSide, shard: &Shard) -> Result<()> {
        let index = read.index.read().expect("read index poisoned");
        let mut with_data = 0usize;
        let mut with_tier = 0usize;
        for (b, entry) in index.iter() {
            if entry.data.is_none() && entry.tier.is_none() {
                return Err(EngineError::Invariant(format!(
                    "shard {idx}: read index holds an empty entry for {b}"
                )));
            }
            match (&entry.data, shard.store.get(*b)) {
                (Some(seen), Some(actual)) if std::sync::Arc::ptr_eq(seen, &actual) => {
                    with_data += 1;
                }
                (None, None) => {}
                _ => {
                    return Err(EngineError::Invariant(format!(
                        "shard {idx}: read index payload for {b} disagrees with the store"
                    )));
                }
            }
            if entry.tier != shard.tier.get(b).copied() {
                return Err(EngineError::Invariant(format!(
                    "shard {idx}: read index tier for {b} disagrees with the tier map"
                )));
            }
            if entry.tier.is_some() {
                with_tier += 1;
            }
        }
        if with_data != shard.store.len() {
            return Err(EngineError::Invariant(format!(
                "shard {idx}: read index mirrors {with_data} payloads, store holds {}",
                shard.store.len()
            )));
        }
        if with_tier != shard.tier.len() {
            return Err(EngineError::Invariant(format!(
                "shard {idx}: read index mirrors {with_tier} tier records, shard holds {}",
                shard.tier.len()
            )));
        }
        Ok(())
    }

    /// The group-pin invariant alone: every intent-table group is fully
    /// pinned and fully resident (all-or-nothing, no partial pins).
    pub fn check_group_invariants(&self) -> Result<()> {
        let intents = self.intents.lock().expect("intent lock poisoned");
        for (gid, members) in intents.iter() {
            for &b in members {
                let shard = self.lock_shard_of(b);
                if !shard.pinned.contains(&b) {
                    return Err(EngineError::Invariant(format!(
                        "group {gid} member {b} lost its pin"
                    )));
                }
                if !shard.store.contains(b) {
                    return Err(EngineError::Invariant(format!(
                        "group {gid} member {b} evicted while pinned"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;
    use std::sync::Arc;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn payload(words: usize) -> BlockData {
        Arc::from(vec![0.5f32; words])
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        assert_eq!(ShardedStore::new(1024, PolicyKind::Lru, 0).shard_count(), 1);
        assert_eq!(ShardedStore::new(1024, PolicyKind::Lru, 3).shard_count(), 4);
        assert_eq!(ShardedStore::new(1024, PolicyKind::Lru, 8).shard_count(), 8);
    }

    #[test]
    fn capacity_split_is_exact() {
        for shards in [1usize, 2, 4, 8, 16] {
            let s = ShardedStore::new(1000, PolicyKind::Lru, shards);
            let per_shard: u64 = s
                .shards
                .iter()
                .map(|sh| sh.shard.lock().unwrap().store.capacity())
                .sum();
            assert_eq!(per_shard, 1000, "shards={shards}");
        }
    }

    #[test]
    fn single_shard_matches_monolithic_eviction_order() {
        // LRU over one shard must evict in global recency order — the
        // exact behavior the paper experiments rely on.
        let s = ShardedStore::new(100 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(50));
        s.insert(b(2), payload(50));
        let out = s.insert(b(3), payload(50));
        assert_eq!(out.evicted, vec![b(1)]);
        assert!(out.admitted);
        assert!(s.contains(b(2)) && s.contains(b(3)));
        s.check_invariants().unwrap();
    }

    #[test]
    fn blocks_distribute_across_shards() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 8);
        for i in 0..256 {
            s.insert(b(i), payload(4));
        }
        let occupied = s
            .shards
            .iter()
            .filter(|sh| sh.shard.lock().unwrap().store.len() > 0)
            .count();
        assert!(occupied >= 6, "only {occupied}/8 shards used");
        assert_eq!(s.len(), 256);
        s.check_invariants().unwrap();
    }

    #[test]
    fn pin_group_is_all_or_nothing() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 4);
        s.insert(b(1), payload(4));
        s.insert(b(2), payload(4));
        // Member 3 missing: nothing may stay pinned.
        assert!(!s.pin_group(GroupId(7), &[b(1), b(2), b(3)]));
        assert_eq!(s.pinned_count(), 0);
        assert_eq!(s.pinned_group_count(), 0);

        s.insert(b(3), payload(4));
        assert!(s.pin_group(GroupId(7), &[b(1), b(2), b(3)]));
        assert_eq!(s.pinned_count(), 3);
        assert_eq!(s.pinned_group_count(), 1);
        // Same id cannot double-pin.
        assert!(!s.pin_group(GroupId(7), &[b(1)]));
        s.check_invariants().unwrap();

        s.unpin_group(GroupId(7));
        assert_eq!(s.pinned_count(), 0);
        assert_eq!(s.pinned_group_count(), 0);
    }

    #[test]
    fn group_pinned_blocks_survive_eviction_pressure() {
        // Capacity for ~4 payload(8) blocks per shard; flood with inserts.
        let s = ShardedStore::new(4 * 8 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(8));
        s.insert(b(2), payload(8));
        assert!(s.pin_group(GroupId(1), &[b(1), b(2)]));
        for i in 10..40 {
            s.insert(b(i), payload(8));
        }
        assert!(s.contains(b(1)) && s.contains(b(2)));
        s.check_group_invariants().unwrap();
        s.unpin_group(GroupId(1));
        for i in 40..50 {
            s.insert(b(i), payload(8));
        }
        assert!(!s.contains(b(1)) || !s.contains(b(2)), "unpinned pair should churn out");
    }

    #[test]
    fn remove_refuses_pinned_blocks() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        s.insert(b(1), payload(4));
        assert!(s.pin_group(GroupId(3), &[b(1)]));
        assert!(s.remove(b(1)).is_none());
        assert!(s.contains(b(1)));
        s.check_group_invariants().unwrap();
        s.unpin_group(GroupId(3));
        assert!(s.remove(b(1)).is_some());
        assert!(!s.contains(b(1)));
    }

    #[test]
    fn overlapping_pins_are_counted() {
        let s = ShardedStore::new(2 * 8 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(8));
        s.pin(b(1)); // ingest-style pin
        assert!(s.pin_group(GroupId(0), &[b(1)])); // task group pin on top
        s.unpin_group(GroupId(0));
        // The ingest pin must still hold.
        for i in 10..20 {
            s.insert(b(i), payload(8));
        }
        assert!(s.contains(b(1)));
        s.unpin(b(1));
        s.insert(b(99), payload(8));
        s.insert(b(98), payload(8));
        assert!(!s.contains(b(1)));
    }

    #[test]
    fn clear_drops_everything_including_pins() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 4);
        for i in 0..12 {
            s.insert(b(i), payload(4));
        }
        s.pin(b(0));
        assert!(s.pin_group(GroupId(1), &[b(1), b(2)]));
        let mut dropped = s.clear();
        dropped.sort();
        assert_eq!(dropped, (0..12).map(b).collect::<Vec<_>>());
        assert!(s.is_empty());
        assert_eq!(s.used(), 0);
        assert_eq!(s.pinned_count(), 0);
        assert_eq!(s.pinned_group_count(), 0);
        assert_eq!(s.stats().evictions, 0, "a failure is not an eviction");
        s.check_invariants().unwrap();
        // The store is fully usable afterwards (a restarted worker).
        s.insert(b(99), payload(4));
        assert!(s.contains(b(99)));
    }

    #[test]
    fn stats_aggregate_over_shards() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 4);
        for i in 0..16 {
            s.insert(b(i), payload(4));
        }
        for i in 0..16 {
            assert!(s.get(b(i)).is_some());
        }
        assert!(s.get(b(999)).is_none());
        let st = s.stats();
        assert_eq!(st.inserts, 16);
        assert_eq!(st.mem_hits, 16);
        assert_eq!(st.misses, 1);
        assert_eq!(st.evictions, 0);
    }

    #[test]
    fn insert_retaining_returns_victim_payloads_in_order() {
        let s = ShardedStore::new(100 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(50));
        s.insert(b(2), payload(50));
        let (out, payloads) = s.insert_retaining(b(3), payload(50));
        assert_eq!(out.evicted, vec![b(1)]);
        assert_eq!(payloads.len(), 1);
        assert_eq!(payloads[0].len(), 50);
        s.check_invariants().unwrap();
    }

    #[test]
    fn tier_records_survive_until_rematerialization() {
        use crate::cache::store::BlockTier;
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        assert_eq!(s.tier_of(b(1)), None);
        s.insert(b(1), payload(4));
        let _ = s.remove(b(1));
        s.set_tier(b(1), BlockTier::SpilledLocal);
        assert_eq!(s.tier_of(b(1)), Some(BlockTier::SpilledLocal));
        s.check_invariants().unwrap();
        s.set_tier(b(1), BlockTier::Dropped);
        assert_eq!(s.tier_of(b(1)), Some(BlockTier::Dropped));
        // Re-materializing through the normal insert path clears the
        // record: the block is plain memory again.
        s.insert(b(1), payload(4));
        assert_eq!(s.tier_of(b(1)), None);
        // A restore marks the resident as restored-Memory.
        s.set_tier(b(1), BlockTier::Memory);
        assert_eq!(s.tier_of(b(1)), Some(BlockTier::Memory));
        s.check_invariants().unwrap();
        s.clear_tier(b(1));
        assert_eq!(s.tier_of(b(1)), None);
        // clear() wipes tier records with everything else.
        s.set_tier(b(1), BlockTier::Memory);
        s.clear();
        assert_eq!(s.tier_of(b(1)), None);
    }

    #[test]
    fn tier_invariants_catch_inconsistent_records() {
        use crate::cache::store::BlockTier;
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 1);
        s.insert(b(1), payload(4));
        s.set_tier(b(1), BlockTier::SpilledLocal); // resident yet "spilled"
        assert!(s.check_invariants().is_err());
    }

    #[test]
    fn get_with_tier_is_one_coherent_snapshot() {
        use crate::cache::store::BlockTier;
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        assert_eq!(s.get_with_tier(b(1)), (None, None));
        s.insert(b(1), payload(4));
        let (data, tier) = s.get_with_tier(b(1));
        assert!(data.is_some());
        assert_eq!(tier, None);
        s.set_tier(b(1), BlockTier::Memory);
        let (data, tier) = s.get_with_tier(b(1));
        assert!(data.is_some());
        assert_eq!(tier, Some(BlockTier::Memory));
        // Accesses are recorded exactly like `get`.
        assert_eq!(s.stats().mem_hits, 2);
        assert_eq!(s.stats().misses, 1);
    }

    #[test]
    fn peek_bytes_and_is_pinned_do_not_record_accesses() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        s.insert(b(1), payload(8));
        assert_eq!(s.peek_bytes(b(1)), Some(32));
        assert_eq!(s.peek_bytes(b(9)), None);
        assert!(!s.is_pinned(b(1)));
        s.pin(b(1));
        assert!(s.is_pinned(b(1)));
        s.unpin(b(1));
        let st = s.stats();
        assert_eq!(st.mem_hits, 0, "peek must not count as a hit");
        assert_eq!(st.misses, 0, "peek must not count as a miss");
    }

    #[test]
    fn group_broken_routes_to_owning_shards() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Sticky, 4);
        for i in 0..8 {
            s.policy_event(PolicyEvent::RefCount { block: b(i), count: 5 });
            s.insert(b(i), payload(4));
        }
        let members: Vec<BlockId> = (0..4).map(b).collect();
        s.policy_event(PolicyEvent::GroupBroken { members: &members });
        // Sticky must now prefer the broken members as victims, across
        // whichever shards they landed in.
        let mut evicted = Vec::new();
        for sh in &s.shards {
            let mut sh = sh.shard.lock().unwrap();
            while let Some(v) = sh.policy.victim(&FxHashSet::default()) {
                if !members.contains(&v) {
                    break;
                }
                sh.store.remove(v);
                sh.policy.on_event(PolicyEvent::Remove { block: v });
                evicted.push(v);
            }
        }
        evicted.sort();
        assert_eq!(evicted, members);
    }

    fn optimistic(capacity: u64, kind: PolicyKind, shards: usize) -> ShardedStore {
        ShardedStore::with_read_path(
            capacity,
            kind,
            shards,
            StoreReadPath::Optimistic,
            DEFAULT_TOUCH_BUFFER,
        )
    }

    #[test]
    fn touch_ring_push_pop_is_fifo_and_bounded() {
        let ring = TouchRing::new(4);
        assert!(ring.pop().is_none());
        for i in 0..4u64 {
            assert!(ring.push(i));
        }
        assert!(!ring.push(99), "a full ring must refuse, not overwrite");
        for i in 0..4u64 {
            assert_eq!(ring.pop(), Some(i));
        }
        assert!(ring.pop().is_none());
        // Wrap-around after a full drain cycle.
        assert!(ring.push(7));
        assert_eq!(ring.pop(), Some(7));
    }

    #[test]
    fn block_key_encoding_roundtrips() {
        for b in [
            BlockId::new(DatasetId(0), 0),
            BlockId::new(DatasetId(3), 17),
            BlockId::new(DatasetId(u32::MAX), u32::MAX),
        ] {
            assert_eq!(decode_block(encode_block(b)), b);
        }
    }

    /// The exactness pin in miniature: a scripted single-threaded history
    /// must produce identical eviction outcomes, final contents, and
    /// stats on both read paths (the full randomized version lives in
    /// `tests/sharded_store_stress.rs`).
    #[test]
    fn optimistic_single_thread_matches_locked() {
        for kind in PolicyKind::ALL {
            let locked = ShardedStore::new(4 * 8 * 4, kind, 1);
            let opt = optimistic(4 * 8 * 4, kind, 1);
            for s in [&locked, &opt] {
                for i in 0..4 {
                    s.insert(b(i), payload(8));
                }
                s.get(b(0));
                s.get(b(2));
                s.get(b(0));
                s.policy_event(PolicyEvent::RefCount { block: b(1), count: 4 });
                s.policy_event(PolicyEvent::EffectiveCount { block: b(1), count: 4 });
            }
            for i in 10..16 {
                let lo = locked.insert(b(i), payload(8));
                let oo = opt.insert(b(i), payload(8));
                assert_eq!(lo, oo, "{}: insert {i} diverged", kind.name());
            }
            let mut lb = locked.cached_blocks();
            let mut ob = opt.cached_blocks();
            lb.sort();
            ob.sort();
            assert_eq!(lb, ob, "{}", kind.name());
            let (ls, os) = (locked.stats(), opt.stats());
            assert_eq!(ls.mem_hits, os.mem_hits, "{}", kind.name());
            assert_eq!(ls.misses, os.misses, "{}", kind.name());
            assert_eq!(ls.evictions, os.evictions, "{}", kind.name());
            opt.check_invariants().unwrap();
        }
    }

    #[test]
    fn optimistic_serves_hits_and_counts_stats_off_lock() {
        let s = optimistic(u64::MAX / 2, PolicyKind::Lru, 4);
        assert_eq!(s.read_path(), StoreReadPath::Optimistic);
        s.insert(b(1), payload(8));
        let p = s.get(b(1)).expect("resident");
        assert_eq!(p.len(), 8);
        assert!(s.get(b(9)).is_none());
        assert!(s.contains(b(1)));
        assert!(!s.contains(b(9)));
        assert_eq!(s.peek_bytes(b(1)), Some(32));
        let st = s.stats();
        assert_eq!(st.mem_hits, 1);
        assert_eq!(st.misses, 1);
        s.check_invariants().unwrap();
    }

    /// A ring smaller than the touch stream must drain inline rather
    /// than drop accesses: recency state ends up exactly as Locked.
    #[test]
    fn full_touch_ring_loses_no_accesses() {
        let locked = ShardedStore::new(3 * 8 * 4, PolicyKind::Lru, 1);
        let tiny = ShardedStore::with_read_path(
            3 * 8 * 4,
            PolicyKind::Lru,
            1,
            StoreReadPath::Optimistic,
            2,
        );
        for s in [&locked, &tiny] {
            for i in 0..3 {
                s.insert(b(i), payload(8));
            }
            // Far more touches than the tiny ring holds.
            for _ in 0..64 {
                s.get(b(0));
            }
            s.get(b(1));
        }
        // LRU order is now 2 < 0 < 1 on both paths.
        assert_eq!(locked.insert(b(7), payload(8)).evicted, vec![b(2)]);
        assert_eq!(tiny.insert(b(7), payload(8)).evicted, vec![b(2)]);
        assert_eq!(locked.insert(b(8), payload(8)).evicted, vec![b(0)]);
        assert_eq!(tiny.insert(b(8), payload(8)).evicted, vec![b(0)]);
        assert_eq!(locked.stats().mem_hits, tiny.stats().mem_hits);
        tiny.check_invariants().unwrap();
    }

    #[test]
    fn optimistic_tier_snapshots_are_coherent() {
        use crate::cache::store::BlockTier;
        let s = optimistic(u64::MAX / 2, PolicyKind::Lru, 2);
        s.insert(b(1), payload(4));
        assert_eq!(s.get_with_tier(b(1)).1, None);
        let _ = s.remove(b(1));
        s.set_tier(b(1), BlockTier::SpilledLocal);
        assert_eq!(s.get_with_tier(b(1)), (None, Some(BlockTier::SpilledLocal)));
        assert_eq!(s.tier_of(b(1)), Some(BlockTier::SpilledLocal));
        s.insert(b(1), payload(4));
        // Re-materialization clears the tier in the same publish as the
        // payload: a snapshot can never pair Some(data) with SpilledLocal.
        let (data, tier) = s.get_with_tier(b(1));
        assert!(data.is_some());
        assert_eq!(tier, None);
        s.set_tier(b(1), BlockTier::Memory);
        assert_eq!(s.get_with_tier(b(1)).1, Some(BlockTier::Memory));
        s.check_invariants().unwrap();
        s.clear_tier(b(1));
        assert_eq!(s.tier_of(b(1)), None);
        s.check_invariants().unwrap();
    }

    #[test]
    fn optimistic_clear_resets_index_and_pending_touches() {
        let s = optimistic(u64::MAX / 2, PolicyKind::Lerc, 4);
        for i in 0..12 {
            s.insert(b(i), payload(4));
            s.get(b(i));
        }
        let mut dropped = s.clear();
        dropped.sort();
        assert_eq!(dropped, (0..12).map(b).collect::<Vec<_>>());
        assert!(s.is_empty());
        assert!(s.get(b(0)).is_none(), "index must forget cleared blocks");
        s.check_invariants().unwrap();
        s.insert(b(99), payload(4));
        assert!(s.get(b(99)).is_some());
        s.check_invariants().unwrap();
    }

    #[test]
    fn optimistic_group_pins_and_flush() {
        let s = optimistic(u64::MAX / 2, PolicyKind::Lru, 4);
        s.insert(b(1), payload(4));
        s.insert(b(2), payload(4));
        s.get(b(1));
        assert!(s.pin_group(GroupId(7), &[b(1), b(2)]));
        assert!(s.remove(b(1)).is_none(), "pinned blocks cannot be removed");
        s.flush_touches();
        s.check_invariants().unwrap();
        s.unpin_group(GroupId(7));
        assert!(s.remove(b(1)).is_some());
        s.check_invariants().unwrap();
    }
}
