//! The sharded, lock-striped block store: the concurrent backbone of every
//! worker's cache.
//!
//! A [`ShardedStore`] splits one worker's cache into N independent shards
//! (N rounded up to a power of two), each holding its own byte-accounted
//! [`MemoryStore`], its own [`CachePolicy`] instance, its own pin table and
//! its own logical clock, all behind a per-shard mutex. Blocks are routed
//! to shards by the engine's fxhash of their [`BlockId`], so concurrent
//! readers and writers only contend when they touch the same shard —
//! remote block reads no longer serialize against the home worker's
//! entire cache.
//!
//! With `shards = 1` the store is bit-for-bit equivalent to the original
//! monolithic block manager: one policy instance, one global eviction
//! order, one tick stream. The paper-reproduction experiments run with a
//! single shard so eviction decisions stay exactly comparable; the
//! multi-worker throughput path (`benches/store_throughput.rs`) runs with
//! many.
//!
//! ## Group pinning (LERC's all-or-nothing sticky sets)
//!
//! LERC's correctness argument is per peer-group: caching half a group
//! buys nothing (paper §II-C). [`ShardedStore::pin_group`] therefore pins
//! a whole member set atomically — all members or none — even when the
//! members hash to different shards. Coordination goes through a small
//! cross-shard *intent table* instead of a global lock: members are
//! pinned one shard at a time (pins are rolled back if any member is
//! missing), and the group is recorded in the intent table only once every
//! member is pinned. Because pinned blocks are never evicted, the
//! observable invariant is simple: **every group in the intent table has
//! all of its members cached and pinned** at every instant. The threaded
//! stress test (`rust/tests/sharded_store_stress.rs`) hammers this.

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::store::{BlockData, BlockTier, MemoryStore};
use crate::common::config::PolicyKind;
use crate::common::error::{EngineError, Result};
use crate::common::fxhash::{FxBuildHasher, FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, GroupId};
use std::hash::{BuildHasher, Hash, Hasher};
use std::sync::Mutex;

/// Per-store cache counters (aggregated over shards on read).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub inserts: u64,
    pub evictions: u64,
    /// Inserts evicted within the same insert call (admission refusals).
    pub rejected: u64,
    pub mem_hits: u64,
    pub misses: u64,
}

impl CacheStats {
    pub fn merge(&mut self, other: &CacheStats) {
        self.inserts += other.inserts;
        self.evictions += other.evictions;
        self.rejected += other.rejected;
        self.mem_hits += other.mem_hits;
        self.misses += other.misses;
    }
}

/// Result of an insert: which blocks were evicted to make room, and
/// whether the inserted block itself survived.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InsertOutcome {
    pub evicted: Vec<BlockId>,
    pub admitted: bool,
}

/// One lock-striped slice of the cache: store + policy + pins + clock.
struct Shard {
    store: MemoryStore,
    policy: Box<dyn CachePolicy>,
    /// Blocks exempt from eviction (the set handed to `CachePolicy::victim`).
    pinned: FxHashSet<BlockId>,
    /// Pin reference counts: a block pinned by both an ingest pin and a
    /// task group pin stays pinned until *both* release it.
    pin_counts: FxHashMap<BlockId, u32>,
    /// Tier residency of blocks that passed through the spill machinery
    /// (empty while the spill tier is disabled — see DESIGN.md §5).
    tier: FxHashMap<BlockId, BlockTier>,
    tick: Tick,
    stats: CacheStats,
}

impl Shard {
    fn new(capacity: u64, kind: PolicyKind) -> Self {
        Self {
            store: MemoryStore::new(capacity),
            policy: crate::cache::policy::new_policy(kind),
            pinned: FxHashSet::default(),
            pin_counts: FxHashMap::default(),
            tier: FxHashMap::default(),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    fn next_tick(&mut self) -> Tick {
        self.tick += 1;
        self.tick
    }

    fn get(&mut self, b: BlockId) -> Option<BlockData> {
        match self.store.get(b) {
            Some(data) => {
                let tick = self.next_tick();
                self.policy.on_event(PolicyEvent::Access { block: b, tick });
                self.stats.mem_hits += 1;
                Some(data)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert then evict back under the shard's capacity — the same
    /// admission-control loop the monolithic manager ran: the new block
    /// participates in victim selection, so a policy may refuse it by
    /// evicting it immediately (LERC's "give up on ineffective hits").
    /// Victim payloads ride along so a spill-enabled caller can demote
    /// the bytes instead of dropping them (same order as `evicted`).
    fn insert(&mut self, b: BlockId, data: BlockData) -> (InsertOutcome, Vec<BlockData>) {
        let bytes = MemoryStore::bytes_of(&data);
        if bytes > self.store.capacity() {
            self.stats.rejected += 1;
            return (
                InsertOutcome {
                    evicted: vec![],
                    admitted: false,
                },
                vec![],
            );
        }
        let tick = self.next_tick();
        self.store.put(b, data);
        // A (re-)materialized block is plain memory again, whatever tier
        // record an earlier demotion left behind.
        self.tier.remove(&b);
        self.policy.on_event(PolicyEvent::Insert { block: b, tick });
        self.stats.inserts += 1;

        let mut evicted = Vec::new();
        let mut payloads = Vec::new();
        while self.store.over_capacity() {
            let Some(victim) = self.policy.victim(&self.pinned) else {
                // Everything remaining is pinned; caller sized pins wrong.
                break;
            };
            if let Some(data) = self.store.remove(victim) {
                payloads.push(data);
            }
            self.policy.on_event(PolicyEvent::Remove { block: victim });
            self.stats.evictions += 1;
            if victim == b {
                self.stats.rejected += 1;
            }
            evicted.push(victim);
        }
        let admitted = !evicted.contains(&b);
        (InsertOutcome { evicted, admitted }, payloads)
    }

    fn remove(&mut self, b: BlockId) -> Option<BlockData> {
        let data = self.store.remove(b)?;
        self.policy.on_event(PolicyEvent::Remove { block: b });
        Some(data)
    }

    fn pin(&mut self, b: BlockId) {
        let count = self.pin_counts.entry(b).or_insert(0);
        *count += 1;
        self.pinned.insert(b);
    }

    fn unpin(&mut self, b: BlockId) {
        if let Some(count) = self.pin_counts.get_mut(&b) {
            *count -= 1;
            if *count == 0 {
                self.pin_counts.remove(&b);
                self.pinned.remove(&b);
            }
        }
    }

    fn check_invariants(&self, idx: usize) -> Result<()> {
        if self.store.len() != self.policy.len() {
            return Err(EngineError::Invariant(format!(
                "shard {idx}: store has {} blocks, policy tracks {}",
                self.store.len(),
                self.policy.len()
            )));
        }
        let recounted: u64 = self
            .store
            .blocks()
            .map(|b| MemoryStore::bytes_of(&self.store.get(b).expect("listed block present")))
            .sum();
        if recounted != self.store.used() {
            return Err(EngineError::Invariant(format!(
                "shard {idx}: byte accounting drifted ({} used vs {} recounted)",
                self.store.used(),
                recounted
            )));
        }
        for (b, t) in &self.tier {
            let resident = self.store.contains(*b);
            match t {
                BlockTier::Memory if !resident => {
                    return Err(EngineError::Invariant(format!(
                        "shard {idx}: {b} marked restored-Memory but not resident"
                    )));
                }
                BlockTier::SpilledLocal | BlockTier::Dropped if resident => {
                    return Err(EngineError::Invariant(format!(
                        "shard {idx}: {b} marked {t:?} but still resident in memory"
                    )));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

/// A lock-striped, byte-accounted block cache shared across threads.
///
/// All methods take `&self`; synchronization is internal and per shard.
/// See the module docs for the sharding and group-pinning design.
pub struct ShardedStore {
    shards: Vec<Mutex<Shard>>,
    hasher: FxBuildHasher,
    capacity: u64,
    kind: PolicyKind,
    /// Cross-shard group-pin intent table: group → its pinned members.
    intents: Mutex<FxHashMap<GroupId, Vec<BlockId>>>,
}

impl ShardedStore {
    /// Build a store of `shards` stripes (rounded up to a power of two;
    /// 0 is treated as 1). Capacity is split evenly across shards, with
    /// the remainder bytes going to the lowest-indexed shards so the
    /// total is exact.
    pub fn new(capacity: u64, kind: PolicyKind, shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        let base = capacity / n as u64;
        let rem = capacity % n as u64;
        let shards = (0..n)
            .map(|i| {
                let extra = if (i as u64) < rem { 1 } else { 0 };
                Mutex::new(Shard::new(base + extra, kind))
            })
            .collect();
        Self {
            shards,
            hasher: FxBuildHasher::default(),
            capacity,
            kind,
            intents: Mutex::new(FxHashMap::default()),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn policy_name(&self) -> &'static str {
        self.kind.name()
    }

    fn shard_idx_of(&self, b: BlockId) -> usize {
        let mut h = self.hasher.build_hasher();
        b.hash(&mut h);
        h.finish() as usize & (self.shards.len() - 1)
    }

    fn lock_shard_of(&self, b: BlockId) -> std::sync::MutexGuard<'_, Shard> {
        self.shards[self.shard_idx_of(b)]
            .lock()
            .expect("shard lock poisoned")
    }

    /// Read a block, recording the access (hit or miss) in the shard's
    /// policy and stats.
    pub fn get(&self, b: BlockId) -> Option<BlockData> {
        self.lock_shard_of(b).get(b)
    }

    /// [`Self::get`] plus the block's tier record, under one shard lock —
    /// the spill-enabled hot read path classifies restored/spilled/
    /// dropped reads without a second lock round trip, and the snapshot
    /// is coherent (payload and tier observed at the same instant).
    pub fn get_with_tier(&self, b: BlockId) -> (Option<BlockData>, Option<BlockTier>) {
        let mut shard = self.lock_shard_of(b);
        let data = shard.get(b);
        let tier = shard.tier.get(&b).copied();
        (data, tier)
    }

    /// Non-mutating presence check (no access recorded).
    pub fn contains(&self, b: BlockId) -> bool {
        self.lock_shard_of(b).store.contains(b)
    }

    /// Insert a block, evicting shard-local victims until under capacity.
    /// A block larger than its shard's capacity is rejected outright.
    pub fn insert(&self, b: BlockId, data: BlockData) -> InsertOutcome {
        self.lock_shard_of(b).insert(b, data).0
    }

    /// [`Self::insert`], additionally returning the victims' payloads
    /// (aligned with `InsertOutcome::evicted`) — the demote-instead-of-
    /// drop hook: a spill-enabled caller persists the bytes to the spill
    /// tier instead of letting them drop here.
    pub fn insert_retaining(&self, b: BlockId, data: BlockData) -> (InsertOutcome, Vec<BlockData>) {
        self.lock_shard_of(b).insert(b, data)
    }

    /// Drop a block without policy consultation (e.g. external uncache).
    /// Pinned blocks are refused (`None`) — an in-use block cannot be
    /// uncached, which is what keeps the group-pin invariant (“every
    /// intent-table member is resident”) unconditional.
    pub fn remove(&self, b: BlockId) -> Option<BlockData> {
        let mut shard = self.lock_shard_of(b);
        if shard.pinned.contains(&b) {
            return None;
        }
        shard.remove(b)
    }

    /// Tier residency of `b`, if it ever passed through the spill
    /// machinery (`None` for plain residents and unknown blocks — the
    /// spill-disabled store never records tiers at all).
    pub fn tier_of(&self, b: BlockId) -> Option<BlockTier> {
        self.lock_shard_of(b).tier.get(&b).copied()
    }

    /// Record a tier transition for `b` (demotion, drop, restore).
    pub fn set_tier(&self, b: BlockId, tier: BlockTier) {
        self.lock_shard_of(b).tier.insert(b, tier);
    }

    /// Forget `b`'s tier record (it re-materialized through the normal
    /// insert path, or its job is gone).
    pub fn clear_tier(&self, b: BlockId) {
        self.lock_shard_of(b).tier.remove(&b);
    }

    /// Resident size of `b` in bytes without recording an access (the
    /// demotion planner sizes candidate sets with this; a policy-visible
    /// `get` here would perturb recency state).
    pub fn peek_bytes(&self, b: BlockId) -> Option<u64> {
        let shard = self.lock_shard_of(b);
        shard.store.get(b).map(|d| MemoryStore::bytes_of(&d))
    }

    /// Is `b` currently pinned? (Demotion never touches pinned blocks —
    /// a pin asserts residency for an in-flight task.)
    pub fn is_pinned(&self, b: BlockId) -> bool {
        self.lock_shard_of(b).pinned.contains(&b)
    }

    /// Pin a block: exempt from eviction until unpinned as many times as
    /// it was pinned. Pinning a not-yet-cached block is allowed (ingest
    /// pins land before the insert).
    pub fn pin(&self, b: BlockId) {
        self.lock_shard_of(b).pin(b);
    }

    pub fn unpin(&self, b: BlockId) {
        self.lock_shard_of(b).unpin(b);
    }

    /// Atomically pin every member of a group, or none (the LERC sticky
    /// set). Returns `false` — with no pins retained — if any member is
    /// not currently cached or the group id is already pinned. On success
    /// the group is recorded in the intent table until [`Self::unpin_group`].
    ///
    /// Members are pinned one shard-lock at a time; the intent is
    /// registered only after the last pin lands, so observers holding the
    /// intent table always see fully-pinned groups.
    pub fn pin_group(&self, group: GroupId, members: &[BlockId]) -> bool {
        if self
            .intents
            .lock()
            .expect("intent lock poisoned")
            .contains_key(&group)
        {
            return false;
        }
        let mut pinned: Vec<BlockId> = Vec::with_capacity(members.len());
        for &b in members {
            let mut shard = self.lock_shard_of(b);
            if !shard.store.contains(b) {
                drop(shard);
                for &p in &pinned {
                    self.lock_shard_of(p).unpin(p);
                }
                return false;
            }
            shard.pin(b);
            pinned.push(b);
        }
        let mut intents = self.intents.lock().expect("intent lock poisoned");
        // Two racing pin_group calls for the same id can both pass the
        // early check; the loser rolls its pins back.
        if intents.contains_key(&group) {
            drop(intents);
            for &p in &pinned {
                self.lock_shard_of(p).unpin(p);
            }
            return false;
        }
        intents.insert(group, pinned);
        true
    }

    /// Release a group pinned by [`Self::pin_group`]. No-op for unknown ids.
    pub fn unpin_group(&self, group: GroupId) {
        let members = self
            .intents
            .lock()
            .expect("intent lock poisoned")
            .remove(&group);
        if let Some(members) = members {
            for b in members {
                self.lock_shard_of(b).unpin(b);
            }
        }
    }

    /// Number of groups currently holding pins.
    pub fn pinned_group_count(&self) -> usize {
        self.intents.lock().expect("intent lock poisoned").len()
    }

    /// Drop every cached block, pin and group intent — a worker failure,
    /// not an eviction: the per-shard policies are told `Remove` so their
    /// indices stay consistent, but no eviction is counted and no victim
    /// is consulted. Returns the blocks that were resident.
    pub fn clear(&self) -> Vec<BlockId> {
        self.intents.lock().expect("intent lock poisoned").clear();
        let mut dropped = Vec::new();
        for s in &self.shards {
            let mut shard = s.lock().expect("shard lock poisoned");
            let blocks: Vec<BlockId> = shard.store.blocks().collect();
            for b in blocks {
                shard.store.remove(b);
                shard.policy.on_event(PolicyEvent::Remove { block: b });
                dropped.push(b);
            }
            shard.pinned.clear();
            shard.pin_counts.clear();
            shard.tier.clear();
        }
        dropped
    }

    /// Forward a DAG/peer hint to the owning shard's policy. Group-wide
    /// events are split per shard so each policy instance only hears
    /// about blocks it can own.
    pub fn policy_event(&self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, .. }
            | PolicyEvent::Access { block, .. }
            | PolicyEvent::Remove { block }
            | PolicyEvent::RefCount { block, .. }
            | PolicyEvent::EffectiveCount { block, .. } => {
                self.lock_shard_of(block).policy.on_event(ev);
            }
            PolicyEvent::GroupBroken { members } => {
                let mut by_shard: FxHashMap<usize, Vec<BlockId>> = FxHashMap::default();
                for &b in members {
                    by_shard.entry(self.shard_idx_of(b)).or_default().push(b);
                }
                for (idx, subset) in by_shard {
                    let mut shard = self.shards[idx].lock().expect("shard lock poisoned");
                    shard
                        .policy
                        .on_event(PolicyEvent::GroupBroken { members: &subset });
                }
            }
        }
    }

    pub fn used(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.lock().expect("shard lock poisoned").store.used())
            .sum()
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("shard lock poisoned").store.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn pinned_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("shard lock poisoned").pinned.len())
            .sum()
    }

    pub fn cached_blocks(&self) -> Vec<BlockId> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.lock().expect("shard lock poisoned").store.blocks());
        }
        out
    }

    /// Aggregate counters across shards.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.shards {
            total.merge(&s.lock().expect("shard lock poisoned").stats);
        }
        total
    }

    /// Invariants: per shard, store and policy agree on membership and the
    /// byte accounting re-sums exactly; cross-shard, every pinned group's
    /// members are cached and pinned. Used by tests and the stress suite.
    pub fn check_invariants(&self) -> Result<()> {
        for (idx, s) in self.shards.iter().enumerate() {
            s.lock().expect("shard lock poisoned").check_invariants(idx)?;
        }
        self.check_group_invariants()
    }

    /// The group-pin invariant alone: every intent-table group is fully
    /// pinned and fully resident (all-or-nothing, no partial pins).
    pub fn check_group_invariants(&self) -> Result<()> {
        let intents = self.intents.lock().expect("intent lock poisoned");
        for (gid, members) in intents.iter() {
            for &b in members {
                let shard = self.lock_shard_of(b);
                if !shard.pinned.contains(&b) {
                    return Err(EngineError::Invariant(format!(
                        "group {gid} member {b} lost its pin"
                    )));
                }
                if !shard.store.contains(b) {
                    return Err(EngineError::Invariant(format!(
                        "group {gid} member {b} evicted while pinned"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;
    use std::sync::Arc;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn payload(words: usize) -> BlockData {
        Arc::new(vec![0.5f32; words])
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        assert_eq!(ShardedStore::new(1024, PolicyKind::Lru, 0).shard_count(), 1);
        assert_eq!(ShardedStore::new(1024, PolicyKind::Lru, 3).shard_count(), 4);
        assert_eq!(ShardedStore::new(1024, PolicyKind::Lru, 8).shard_count(), 8);
    }

    #[test]
    fn capacity_split_is_exact() {
        for shards in [1usize, 2, 4, 8, 16] {
            let s = ShardedStore::new(1000, PolicyKind::Lru, shards);
            let per_shard: u64 = s
                .shards
                .iter()
                .map(|sh| sh.lock().unwrap().store.capacity())
                .sum();
            assert_eq!(per_shard, 1000, "shards={shards}");
        }
    }

    #[test]
    fn single_shard_matches_monolithic_eviction_order() {
        // LRU over one shard must evict in global recency order — the
        // exact behavior the paper experiments rely on.
        let s = ShardedStore::new(100 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(50));
        s.insert(b(2), payload(50));
        let out = s.insert(b(3), payload(50));
        assert_eq!(out.evicted, vec![b(1)]);
        assert!(out.admitted);
        assert!(s.contains(b(2)) && s.contains(b(3)));
        s.check_invariants().unwrap();
    }

    #[test]
    fn blocks_distribute_across_shards() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 8);
        for i in 0..256 {
            s.insert(b(i), payload(4));
        }
        let occupied = s
            .shards
            .iter()
            .filter(|sh| sh.lock().unwrap().store.len() > 0)
            .count();
        assert!(occupied >= 6, "only {occupied}/8 shards used");
        assert_eq!(s.len(), 256);
        s.check_invariants().unwrap();
    }

    #[test]
    fn pin_group_is_all_or_nothing() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 4);
        s.insert(b(1), payload(4));
        s.insert(b(2), payload(4));
        // Member 3 missing: nothing may stay pinned.
        assert!(!s.pin_group(GroupId(7), &[b(1), b(2), b(3)]));
        assert_eq!(s.pinned_count(), 0);
        assert_eq!(s.pinned_group_count(), 0);

        s.insert(b(3), payload(4));
        assert!(s.pin_group(GroupId(7), &[b(1), b(2), b(3)]));
        assert_eq!(s.pinned_count(), 3);
        assert_eq!(s.pinned_group_count(), 1);
        // Same id cannot double-pin.
        assert!(!s.pin_group(GroupId(7), &[b(1)]));
        s.check_invariants().unwrap();

        s.unpin_group(GroupId(7));
        assert_eq!(s.pinned_count(), 0);
        assert_eq!(s.pinned_group_count(), 0);
    }

    #[test]
    fn group_pinned_blocks_survive_eviction_pressure() {
        // Capacity for ~4 payload(8) blocks per shard; flood with inserts.
        let s = ShardedStore::new(4 * 8 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(8));
        s.insert(b(2), payload(8));
        assert!(s.pin_group(GroupId(1), &[b(1), b(2)]));
        for i in 10..40 {
            s.insert(b(i), payload(8));
        }
        assert!(s.contains(b(1)) && s.contains(b(2)));
        s.check_group_invariants().unwrap();
        s.unpin_group(GroupId(1));
        for i in 40..50 {
            s.insert(b(i), payload(8));
        }
        assert!(!s.contains(b(1)) || !s.contains(b(2)), "unpinned pair should churn out");
    }

    #[test]
    fn remove_refuses_pinned_blocks() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        s.insert(b(1), payload(4));
        assert!(s.pin_group(GroupId(3), &[b(1)]));
        assert!(s.remove(b(1)).is_none());
        assert!(s.contains(b(1)));
        s.check_group_invariants().unwrap();
        s.unpin_group(GroupId(3));
        assert!(s.remove(b(1)).is_some());
        assert!(!s.contains(b(1)));
    }

    #[test]
    fn overlapping_pins_are_counted() {
        let s = ShardedStore::new(2 * 8 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(8));
        s.pin(b(1)); // ingest-style pin
        assert!(s.pin_group(GroupId(0), &[b(1)])); // task group pin on top
        s.unpin_group(GroupId(0));
        // The ingest pin must still hold.
        for i in 10..20 {
            s.insert(b(i), payload(8));
        }
        assert!(s.contains(b(1)));
        s.unpin(b(1));
        s.insert(b(99), payload(8));
        s.insert(b(98), payload(8));
        assert!(!s.contains(b(1)));
    }

    #[test]
    fn clear_drops_everything_including_pins() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 4);
        for i in 0..12 {
            s.insert(b(i), payload(4));
        }
        s.pin(b(0));
        assert!(s.pin_group(GroupId(1), &[b(1), b(2)]));
        let mut dropped = s.clear();
        dropped.sort();
        assert_eq!(dropped, (0..12).map(b).collect::<Vec<_>>());
        assert!(s.is_empty());
        assert_eq!(s.used(), 0);
        assert_eq!(s.pinned_count(), 0);
        assert_eq!(s.pinned_group_count(), 0);
        assert_eq!(s.stats().evictions, 0, "a failure is not an eviction");
        s.check_invariants().unwrap();
        // The store is fully usable afterwards (a restarted worker).
        s.insert(b(99), payload(4));
        assert!(s.contains(b(99)));
    }

    #[test]
    fn stats_aggregate_over_shards() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 4);
        for i in 0..16 {
            s.insert(b(i), payload(4));
        }
        for i in 0..16 {
            assert!(s.get(b(i)).is_some());
        }
        assert!(s.get(b(999)).is_none());
        let st = s.stats();
        assert_eq!(st.inserts, 16);
        assert_eq!(st.mem_hits, 16);
        assert_eq!(st.misses, 1);
        assert_eq!(st.evictions, 0);
    }

    #[test]
    fn insert_retaining_returns_victim_payloads_in_order() {
        let s = ShardedStore::new(100 * 4, PolicyKind::Lru, 1);
        s.insert(b(1), payload(50));
        s.insert(b(2), payload(50));
        let (out, payloads) = s.insert_retaining(b(3), payload(50));
        assert_eq!(out.evicted, vec![b(1)]);
        assert_eq!(payloads.len(), 1);
        assert_eq!(payloads[0].len(), 50);
        s.check_invariants().unwrap();
    }

    #[test]
    fn tier_records_survive_until_rematerialization() {
        use crate::cache::store::BlockTier;
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        assert_eq!(s.tier_of(b(1)), None);
        s.insert(b(1), payload(4));
        let _ = s.remove(b(1));
        s.set_tier(b(1), BlockTier::SpilledLocal);
        assert_eq!(s.tier_of(b(1)), Some(BlockTier::SpilledLocal));
        s.check_invariants().unwrap();
        s.set_tier(b(1), BlockTier::Dropped);
        assert_eq!(s.tier_of(b(1)), Some(BlockTier::Dropped));
        // Re-materializing through the normal insert path clears the
        // record: the block is plain memory again.
        s.insert(b(1), payload(4));
        assert_eq!(s.tier_of(b(1)), None);
        // A restore marks the resident as restored-Memory.
        s.set_tier(b(1), BlockTier::Memory);
        assert_eq!(s.tier_of(b(1)), Some(BlockTier::Memory));
        s.check_invariants().unwrap();
        s.clear_tier(b(1));
        assert_eq!(s.tier_of(b(1)), None);
        // clear() wipes tier records with everything else.
        s.set_tier(b(1), BlockTier::Memory);
        s.clear();
        assert_eq!(s.tier_of(b(1)), None);
    }

    #[test]
    fn tier_invariants_catch_inconsistent_records() {
        use crate::cache::store::BlockTier;
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 1);
        s.insert(b(1), payload(4));
        s.set_tier(b(1), BlockTier::SpilledLocal); // resident yet "spilled"
        assert!(s.check_invariants().is_err());
    }

    #[test]
    fn get_with_tier_is_one_coherent_snapshot() {
        use crate::cache::store::BlockTier;
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        assert_eq!(s.get_with_tier(b(1)), (None, None));
        s.insert(b(1), payload(4));
        let (data, tier) = s.get_with_tier(b(1));
        assert!(data.is_some());
        assert_eq!(tier, None);
        s.set_tier(b(1), BlockTier::Memory);
        let (data, tier) = s.get_with_tier(b(1));
        assert!(data.is_some());
        assert_eq!(tier, Some(BlockTier::Memory));
        // Accesses are recorded exactly like `get`.
        assert_eq!(s.stats().mem_hits, 2);
        assert_eq!(s.stats().misses, 1);
    }

    #[test]
    fn peek_bytes_and_is_pinned_do_not_record_accesses() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Lru, 2);
        s.insert(b(1), payload(8));
        assert_eq!(s.peek_bytes(b(1)), Some(32));
        assert_eq!(s.peek_bytes(b(9)), None);
        assert!(!s.is_pinned(b(1)));
        s.pin(b(1));
        assert!(s.is_pinned(b(1)));
        s.unpin(b(1));
        let st = s.stats();
        assert_eq!(st.mem_hits, 0, "peek must not count as a hit");
        assert_eq!(st.misses, 0, "peek must not count as a miss");
    }

    #[test]
    fn group_broken_routes_to_owning_shards() {
        let s = ShardedStore::new(u64::MAX / 2, PolicyKind::Sticky, 4);
        for i in 0..8 {
            s.policy_event(PolicyEvent::RefCount { block: b(i), count: 5 });
            s.insert(b(i), payload(4));
        }
        let members: Vec<BlockId> = (0..4).map(b).collect();
        s.policy_event(PolicyEvent::GroupBroken { members: &members });
        // Sticky must now prefer the broken members as victims, across
        // whichever shards they landed in.
        let mut evicted = Vec::new();
        for sh in &s.shards {
            let mut sh = sh.lock().unwrap();
            while let Some(v) = sh.policy.victim(&FxHashSet::default()) {
                if !members.contains(&v) {
                    break;
                }
                sh.store.remove(v);
                sh.policy.on_event(PolicyEvent::Remove { block: v });
                evicted.push(v);
            }
        }
        evicted.sort();
        assert_eq!(evicted, members);
    }
}
