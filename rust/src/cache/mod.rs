//! Cache eviction policies and the in-memory block store.
//!
//! Every policy implements [`CachePolicy`]: a pure decision structure fed
//! by [`PolicyEvent`]s (inserts, accesses, DAG reference-count updates,
//! peer-group invalidations) and queried for eviction victims. The block
//! manager ([`crate::block`]) owns the byte accounting; policies own only
//! the ordering.
//!
//! Implemented policies (paper §II + §III):
//!
//! | policy | bets on | DAG-aware | peer-aware |
//! |---|---|---|---|
//! | [`lru::Lru`] | recency | no | no |
//! | [`lfu::Lfu`] | frequency | no | no |
//! | [`fifo::Fifo`] | age | no | no |
//! | [`lrfu::Lrfu`] | recency+frequency blend | no | no |
//! | [`lru_k::LruK`] | K-th recency | no | no |
//! | [`lrc::Lrc`] | remaining references | yes | no |
//! | [`lerc::Lerc`] | remaining *effective* references | yes | yes |
//! | [`sticky::Sticky`] | §III-A strawman | yes | yes |

pub mod fifo;
pub mod lerc;
pub mod lfu;
pub mod lrc;
pub mod lrfu;
pub mod lru;
pub mod lru_k;
pub mod policy;
pub mod score;
pub mod sharded;
pub mod sticky;
pub mod store;

pub use policy::{new_policy, CachePolicy, PolicyEvent, Tick};
pub use sharded::{CacheStats, InsertOutcome, ShardedStore};
pub use store::{BlockData, MemoryStore};
