//! LERC — Least *Effective* Reference Count: the paper's contribution.
//!
//! Evicts the block with the fewest **effective** references (Def. 2: a
//! reference by task `t` is effective iff `t`'s dependent blocks, if
//! computed, are all cached). Effective counts are pushed in by the
//! per-worker peer tracker ([`crate::peer`]); the policy itself is a pure
//! ordering over `(effective refs, plain refs, recency)`.
//!
//! The secondary plain-reference-count key makes LERC degrade gracefully
//! to LRC when every group is intact or every group is broken — matching
//! the paper's "LERC builds on LRC" design.

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::score::ScoreIndex;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::BlockId;

#[derive(Debug, Clone, Copy, Default)]
struct Meta {
    eff: u32,
    refs: u32,
    tick: Tick,
}

#[derive(Debug, Default)]
pub struct Lerc {
    idx: ScoreIndex<(u32, u32, Tick)>, // (effective, plain, last tick)
    meta: FxHashMap<BlockId, Meta>,
    /// Counts arriving before insert (or surviving eviction) by block.
    pending: FxHashMap<BlockId, (u32, u32)>, // (eff, refs)
}

impl Lerc {
    fn rescore(&mut self, block: BlockId) {
        if let Some(m) = self.meta.get(&block) {
            self.idx.upsert(block, (m.eff, m.refs, m.tick));
        }
    }

    pub fn effective_count(&self, block: BlockId) -> u32 {
        self.meta
            .get(&block)
            .map(|m| m.eff)
            .or_else(|| self.pending.get(&block).map(|p| p.0))
            .unwrap_or(0)
    }

    pub fn ref_count(&self, block: BlockId) -> u32 {
        self.meta
            .get(&block)
            .map(|m| m.refs)
            .or_else(|| self.pending.get(&block).map(|p| p.1))
            .unwrap_or(0)
    }
}

impl CachePolicy for Lerc {
    fn name(&self) -> &'static str {
        "LERC"
    }

    fn on_event(&mut self, ev: PolicyEvent<'_>) {
        match ev {
            PolicyEvent::Insert { block, tick } => {
                let (eff, refs) = self.pending.get(&block).copied().unwrap_or((0, 0));
                self.meta.insert(block, Meta { eff, refs, tick });
                self.rescore(block);
            }
            PolicyEvent::Access { block, tick } => {
                if let Some(m) = self.meta.get_mut(&block) {
                    m.tick = tick;
                    self.rescore(block);
                }
            }
            PolicyEvent::Remove { block } => {
                if let Some(m) = self.meta.remove(&block) {
                    self.pending.insert(block, (m.eff, m.refs));
                }
                self.idx.remove(block);
            }
            PolicyEvent::RefCount { block, count } => {
                self.pending.entry(block).or_default().1 = count;
                if let Some(m) = self.meta.get_mut(&block) {
                    m.refs = count;
                    self.rescore(block);
                }
            }
            PolicyEvent::EffectiveCount { block, count } => {
                self.pending.entry(block).or_default().0 = count;
                if let Some(m) = self.meta.get_mut(&block) {
                    m.eff = count;
                    self.rescore(block);
                }
            }
            PolicyEvent::GroupBroken { .. } => {} // tracker already sent deltas
        }
    }

    fn victim(&mut self, pinned: &FxHashSet<BlockId>) -> Option<BlockId> {
        self.idx.min_excluding(pinned)
    }

    fn len(&self) -> usize {
        self.idx.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn insert_with(p: &mut Lerc, i: u32, tick: Tick, eff: u32, refs: u32) {
        p.on_event(PolicyEvent::EffectiveCount { block: b(i), count: eff });
        p.on_event(PolicyEvent::RefCount { block: b(i), count: refs });
        p.on_event(PolicyEvent::Insert { block: b(i), tick });
    }

    /// The paper's Fig 1 toy: blocks a(1), b(2), c(3) cached; c's peer d is
    /// on disk so c's reference is ineffective. LERC must evict c.
    #[test]
    fn fig1_toy_evicts_c() {
        let mut p = Lerc::default();
        insert_with(&mut p, 1, 1, 1, 1); // a: effective (peer b cached)
        insert_with(&mut p, 2, 2, 1, 1); // b
        insert_with(&mut p, 3, 3, 0, 1); // c: peer d not in memory
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(3)));
    }

    #[test]
    fn effective_count_dominates_plain_count() {
        let mut p = Lerc::default();
        insert_with(&mut p, 1, 1, 1, 1); // few refs but effective
        insert_with(&mut p, 2, 2, 0, 9); // many refs, none effective
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn falls_back_to_lrc_ordering_when_eff_ties() {
        let mut p = Lerc::default();
        insert_with(&mut p, 1, 1, 1, 3);
        insert_with(&mut p, 2, 2, 1, 1);
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn recency_breaks_full_ties() {
        let mut p = Lerc::default();
        insert_with(&mut p, 1, 1, 1, 1);
        insert_with(&mut p, 2, 2, 1, 1);
        p.on_event(PolicyEvent::Access { block: b(1), tick: 5 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn group_break_delta_reorders() {
        let mut p = Lerc::default();
        insert_with(&mut p, 1, 1, 1, 1);
        insert_with(&mut p, 2, 2, 1, 1);
        insert_with(&mut p, 3, 3, 2, 2);
        // b1's group broke: its effective count drops to 0.
        p.on_event(PolicyEvent::EffectiveCount { block: b(1), count: 0 });
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(1)));
    }

    #[test]
    fn counts_survive_eviction() {
        let mut p = Lerc::default();
        insert_with(&mut p, 1, 1, 2, 2);
        p.on_event(PolicyEvent::Remove { block: b(1) });
        assert_eq!(p.effective_count(b(1)), 2);
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 9 });
        insert_with(&mut p, 2, 10, 0, 0);
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }

    #[test]
    fn counts_arriving_while_uncached_apply_on_insert() {
        let mut p = Lerc::default();
        p.on_event(PolicyEvent::EffectiveCount { block: b(1), count: 3 });
        p.on_event(PolicyEvent::Insert { block: b(1), tick: 1 });
        insert_with(&mut p, 2, 2, 1, 1);
        assert_eq!(p.victim(&FxHashSet::default()), Some(b(2)));
    }
}
