//! `lerc` — CLI launcher for the LERC reproduction experiments.
//!
//! Subcommands (one per paper artifact, see DESIGN.md §4):
//!   toy        Fig 1 eviction-decision table
//!   fig3       all-or-nothing staircase measurement
//!   sweep      Fig 5/6/7 cache-size × policy sweep
//!   comm       §III-C communication-overhead table
//!   ablation   §III-A sticky-eviction ablation
//!   run        one engine run with explicit knobs
//!   trace      flight-recorder run (trace.jsonl + trace.chrome.json)
//!              or `--summarize FILE` for an existing trace
//!   analyze    critical-path decomposition (DESIGN.md §10): either
//!              `--trace FILE` on an existing trace.jsonl, or a traced
//!              run with the telemetry sampler on (also writes
//!              timeline.jsonl + Perfetto counter tracks)
//!   all        everything above, in order
//!
//! Common flags:
//!   --workers N --tenants N --blocks N --block-len N --seed N
//!   --fractions 0.33,0.5,...   cache sizes as input fractions
//!   --policies lru,lrc,lerc    or `all`
//!   --real                     threaded engine instead of the simulator
//!   --pjrt [DIR]               real XLA compute (default artifacts/)
//!   --time-scale X             sleep scaling for --real (default 0.05)
//!   --csv PATH                 also write rows as CSV
//!   --verbose / --quiet        logger level (progress notes / tables only)
//!   --workload NAME            trace: generator (multi-tenant-zip, zip,
//!                              shared-input, double-map-zip-agg, etl,
//!                              two-stage)
//!   --out DIR                  trace: output directory (default .)
//!   --summarize FILE           trace: summarize an existing trace.jsonl
//!   --trace FILE               analyze: existing trace.jsonl to analyze
//!   --json PATH                analyze: write the decomposition as JSON
//!
//! The CLI is hand-rolled: the build environment is offline (no clap).

use lerc_engine::common::config::{
    ComputeMode, CtrlPlane, EngineConfig, PolicyKind, TimelineConfig,
};
use lerc_engine::driver::ClusterEngine;
use lerc_engine::engine::Engine;
use lerc_engine::harness::chart;
use lerc_engine::harness::experiments::{self as exp, ExpOptions};
use lerc_engine::harness::logger::{self, Level};
use lerc_engine::metrics::report::{attribution_table, csv, markdown_table, SweepRow};
use lerc_engine::sim::Simulator;
use lerc_engine::trace::sink::{ChromeSink, JsonlSink, TraceMeta, TraceSink};
use lerc_engine::trace::summary::TraceSummary;
use lerc_engine::trace::{CriticalPathAnalysis, TraceConfig, DEFAULT_RING_CAPACITY};
use lerc_engine::workload::{self, Workload};
use lerc_engine::{out, vlog, warn};
use std::process::ExitCode;

#[derive(Debug, Clone)]
struct Cli {
    cmd: String,
    opts: ExpOptions,
    real: bool,
    pjrt: Option<String>,
    time_scale: f64,
    csv_path: Option<String>,
    policy: PolicyKind,
    cache_mb: Option<f64>,
    level: Level,
    workload_name: String,
    out_dir: String,
    summarize: Option<String>,
    trace_file: Option<String>,
    json_out: Option<String>,
}

fn parse_policy(s: &str) -> Result<PolicyKind, String> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "lru" => PolicyKind::Lru,
        "lfu" => PolicyKind::Lfu,
        "fifo" => PolicyKind::Fifo,
        "lrfu" => PolicyKind::Lrfu,
        "lru-k" | "lruk" | "lru2" | "lru-2" => PolicyKind::LruK,
        "lrc" => PolicyKind::Lrc,
        "lerc" => PolicyKind::Lerc,
        "sticky" => PolicyKind::Sticky,
        other => return Err(format!("unknown policy `{other}`")),
    })
}

fn parse(args: &[String]) -> Result<Cli, String> {
    let mut cli = Cli {
        cmd: args.first().cloned().unwrap_or_else(|| "all".into()),
        opts: ExpOptions::default(),
        real: false,
        pjrt: None,
        time_scale: 0.05,
        csv_path: None,
        policy: PolicyKind::Lerc,
        cache_mb: None,
        level: Level::Normal,
        workload_name: "multi-tenant-zip".into(),
        out_dir: ".".into(),
        summarize: None,
        trace_file: None,
        json_out: None,
    };
    let mut i = 1;
    let need = |i: usize, args: &[String], flag: &str| -> Result<String, String> {
        args.get(i + 1)
            .cloned()
            .ok_or_else(|| format!("{flag} needs a value"))
    };
    while i < args.len() {
        match args[i].as_str() {
            "--workers" => {
                cli.opts.workers = need(i, args, "--workers")?.parse().map_err(|e| format!("{e}"))?;
                i += 2;
            }
            "--tenants" => {
                cli.opts.tenants = need(i, args, "--tenants")?.parse().map_err(|e| format!("{e}"))?;
                i += 2;
            }
            "--blocks" => {
                cli.opts.blocks_per_file =
                    need(i, args, "--blocks")?.parse().map_err(|e| format!("{e}"))?;
                i += 2;
            }
            "--block-len" => {
                cli.opts.block_len =
                    need(i, args, "--block-len")?.parse().map_err(|e| format!("{e}"))?;
                i += 2;
            }
            "--seed" => {
                cli.opts.seed = need(i, args, "--seed")?.parse().map_err(|e| format!("{e}"))?;
                i += 2;
            }
            "--fractions" => {
                cli.opts.fractions = need(i, args, "--fractions")?
                    .split(',')
                    .map(|s| s.parse::<f64>().map_err(|e| format!("{e}")))
                    .collect::<Result<_, _>>()?;
                i += 2;
            }
            "--policies" => {
                let v = need(i, args, "--policies")?;
                cli.opts.policies = if v == "all" {
                    PolicyKind::ALL.to_vec()
                } else {
                    v.split(',').map(parse_policy).collect::<Result<_, _>>()?
                };
                i += 2;
            }
            "--policy" => {
                cli.policy = parse_policy(&need(i, args, "--policy")?)?;
                i += 2;
            }
            "--cache-mb" => {
                cli.cache_mb =
                    Some(need(i, args, "--cache-mb")?.parse().map_err(|e| format!("{e}"))?);
                i += 2;
            }
            "--real" => {
                cli.real = true;
                i += 1;
            }
            "--pjrt" => {
                // Optional value (defaults to artifacts/).
                if let Some(v) = args.get(i + 1) {
                    if !v.starts_with("--") {
                        cli.pjrt = Some(v.clone());
                        i += 2;
                        continue;
                    }
                }
                cli.pjrt = Some("artifacts".into());
                i += 1;
            }
            "--time-scale" => {
                cli.time_scale =
                    need(i, args, "--time-scale")?.parse().map_err(|e| format!("{e}"))?;
                i += 2;
            }
            "--csv" => {
                cli.csv_path = Some(need(i, args, "--csv")?);
                i += 2;
            }
            "--verbose" | "-v" => {
                cli.level = Level::Verbose;
                i += 1;
            }
            "--quiet" | "-q" => {
                cli.level = Level::Quiet;
                i += 1;
            }
            "--workload" => {
                cli.workload_name = need(i, args, "--workload")?;
                i += 2;
            }
            "--out" => {
                cli.out_dir = need(i, args, "--out")?;
                i += 2;
            }
            "--summarize" => {
                cli.summarize = Some(need(i, args, "--summarize")?);
                i += 2;
            }
            "--trace" => {
                cli.trace_file = Some(need(i, args, "--trace")?);
                i += 2;
            }
            "--json" => {
                cli.json_out = Some(need(i, args, "--json")?);
                i += 2;
            }
            other => return Err(format!("unknown flag `{other}` (see --help in source)")),
        }
    }
    Ok(cli)
}

fn write_csv(path: &Option<String>, rows: &[SweepRow]) {
    if let Some(p) = path {
        if let Err(e) = std::fs::write(p, csv(rows)) {
            warn!("cannot write {p}: {e}");
        } else {
            out!("(csv written to {p})");
        }
    }
}

fn compute_mode(cli: &Cli) -> ComputeMode {
    match &cli.pjrt {
        Some(dir) => ComputeMode::Pjrt {
            artifacts_dir: dir.into(),
        },
        None => ComputeMode::Synthetic,
    }
}

fn cmd_sweep(cli: &Cli) -> Result<(), String> {
    out!(
        "## Fig 5/6/7 sweep — {} engine, {} tenants × 2 × {} blocks × {} KiB\n",
        if cli.real { "threaded" } else { "simulated" },
        cli.opts.tenants,
        cli.opts.blocks_per_file,
        cli.opts.block_len * 4 / 1024
    );
    let rows = if cli.real {
        exp::fig5_6_7_sweep_real(&cli.opts, compute_mode(cli), cli.time_scale)
            .map_err(|e| e.to_string())?
    } else {
        exp::fig5_6_7_sweep(&cli.opts).map_err(|e| e.to_string())?
    };
    out!("{}", markdown_table(&rows));
    // ASCII twins of Fig 5 and Fig 7.
    let policies: Vec<String> = {
        let mut v: Vec<String> = rows.iter().map(|r| r.policy.clone()).collect();
        v.dedup();
        v
    };
    let xs: Vec<f64> = {
        let mut v: Vec<f64> = rows.iter().map(|r| r.cache_fraction).collect();
        v.dedup();
        v
    };
    let series_of = |f: &dyn Fn(&lerc_engine::metrics::report::SweepRow) -> f64| {
        policies
            .iter()
            .map(|p| {
                (
                    p.clone(),
                    rows.iter().filter(|r| &r.policy == p).map(f).collect::<Vec<f64>>(),
                )
            })
            .collect::<Vec<_>>()
    };
    let runtime = series_of(&|r| r.makespan_s);
    let named: Vec<(&str, Vec<f64>)> =
        runtime.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
    let fig5 = chart::line_chart(
        "Fig 5 — runtime (s) vs cache fraction",
        "cache fraction",
        &xs,
        &named,
        10,
    );
    out!("{fig5}");
    let eff = series_of(&|r| r.effective_hit_ratio);
    let named: Vec<(&str, Vec<f64>)> =
        eff.iter().map(|(n, v)| (n.as_str(), v.clone())).collect();
    let fig7 = chart::line_chart(
        "Fig 7 — effective cache hit ratio",
        "cache fraction",
        &xs,
        &named,
        10,
    );
    out!("{fig7}");
    write_csv(&cli.csv_path, &rows);
    Ok(())
}

/// Build the workload selected with `--workload` (the `trace` command's
/// generator registry).
fn workload_by_name(cli: &Cli) -> Result<Workload, String> {
    let o = &cli.opts;
    Ok(match cli.workload_name.as_str() {
        "multi-tenant-zip" => {
            workload::multi_tenant_zip(o.tenants, o.blocks_per_file, o.block_len)
        }
        "zip" | "zip-single" => workload::zip_single(o.blocks_per_file, o.block_len),
        "shared-input" => workload::shared_input(o.tenants, o.blocks_per_file, o.block_len),
        "double-map-zip-agg" => {
            workload::generators::double_map_zip_agg(o.blocks_per_file, o.block_len)
        }
        "etl" => workload::generators::etl_pipeline(o.blocks_per_file, o.block_len),
        "two-stage" => workload::generators::two_stage_zip_agg(o.blocks_per_file, o.block_len),
        other => {
            return Err(format!(
                "unknown workload `{other}` (multi-tenant-zip|zip|shared-input|\
                 double-map-zip-agg|etl|two-stage)"
            ))
        }
    })
}

fn cmd_trace(cli: &Cli) -> Result<(), String> {
    // Summarize-only mode: no engine run, just read a trace back.
    if let Some(path) = &cli.summarize {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let summary = TraceSummary::from_jsonl(&text);
        out!("{}", summary.render());
        return Ok(());
    }

    let w = workload_by_name(cli)?;
    let input = w.input_bytes();
    let cache = cli
        .cache_mb
        .map(|mb| (mb * 1024.0 * 1024.0) as u64)
        .unwrap_or(input / 2);
    let (trace_cfg, rec) = TraceConfig::collect(DEFAULT_RING_CAPACITY);
    let cfg = EngineConfig::builder()
        .num_workers(cli.opts.workers)
        .cache_capacity_per_worker(cache / cli.opts.workers as u64)
        .block_len(cli.opts.block_len)
        .policy(cli.policy)
        .seed(cli.opts.seed)
        .compute(compute_mode(cli))
        .time_scale(cli.time_scale)
        .ctrl_plane(CtrlPlane::Broadcast)
        .trace(trace_cfg)
        .build()
        .map_err(|e| e.to_string())?;
    vlog!(
        "trace: {} on {} engine, cache {} MiB",
        cli.workload_name,
        if cli.real { "threaded" } else { "sim" },
        cache / (1024 * 1024)
    );
    let report = if cli.real {
        ClusterEngine::new(cfg).run_workload(&w).map_err(|e| e.to_string())?
    } else {
        Simulator::from_engine_config(cfg).run_workload(&w).map_err(|e| e.to_string())?
    };

    let events = rec.take();
    let meta = TraceMeta {
        engine: if cli.real { "threaded" } else { "sim" }.to_string(),
        clock: rec.clock(),
        workers: cli.opts.workers,
        dropped: rec.dropped(),
    };
    let write_with = |name: &str, sink: &mut dyn FnMut(std::fs::File) -> std::io::Result<()>|
        -> Result<String, String> {
        let path = format!("{}/{}", cli.out_dir, name);
        let f = std::fs::File::create(&path).map_err(|e| format!("{path}: {e}"))?;
        sink(f).map_err(|e| format!("{path}: {e}"))?;
        Ok(path)
    };
    let jsonl = write_with("trace.jsonl", &mut |f| {
        JsonlSink::new(std::io::BufWriter::new(f)).export(&meta, &events)
    })?;
    let chrome = write_with("trace.chrome.json", &mut |f| {
        ChromeSink::new(std::io::BufWriter::new(f)).export(&meta, &events)
    })?;

    out!(
        "trace: {} events ({} dropped) → {jsonl} + {chrome}",
        events.len(),
        meta.dropped
    );
    out!(
        "run: policy={} makespan={:.3}s hit={:.3} effective={:.3} tasks={}",
        report.policy,
        report.makespan.as_secs_f64(),
        report.hit_ratio(),
        report.effective_hit_ratio(),
        report.tasks_run
    );
    if report.attribution.total() > 0 {
        out!();
        out!("{}", attribution_table(&report, 5));
    }
    Ok(())
}

fn cmd_analyze(cli: &Cli) -> Result<(), String> {
    // File mode: reconstruct critical paths from an existing JSONL
    // trace — no engine run, no sampler (the trace carries the spans).
    if let Some(path) = &cli.trace_file {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let analysis = CriticalPathAnalysis::from_jsonl(&text);
        if analysis.jobs.is_empty() {
            return Err(format!("{path}: no completed jobs in trace"));
        }
        out!("{}", analysis.render());
        if !analysis.identity_holds() {
            warn!("Σ-segments ≠ JCT for some job (truncated or dropped trace?)");
        }
        if let Some(p) = &cli.json_out {
            std::fs::write(p, analysis.to_json()).map_err(|e| format!("{p}: {e}"))?;
            out!("decomposition → {p}");
        }
        return Ok(());
    }

    // Run-and-analyze: a traced run with the telemetry sampler on.
    let w = workload_by_name(cli)?;
    let input = w.input_bytes();
    let cache = cli
        .cache_mb
        .map(|mb| (mb * 1024.0 * 1024.0) as u64)
        .unwrap_or(input / 2);
    let (trace_cfg, rec) = TraceConfig::collect(DEFAULT_RING_CAPACITY);
    let cfg = EngineConfig::builder()
        .num_workers(cli.opts.workers)
        .cache_capacity_per_worker(cache / cli.opts.workers as u64)
        .block_len(cli.opts.block_len)
        .policy(cli.policy)
        .seed(cli.opts.seed)
        .compute(compute_mode(cli))
        .time_scale(cli.time_scale)
        .ctrl_plane(CtrlPlane::Broadcast)
        .trace(trace_cfg)
        .timeline(TimelineConfig::default())
        .build()
        .map_err(|e| e.to_string())?;
    vlog!(
        "analyze: {} on {} engine, cache {} MiB",
        cli.workload_name,
        if cli.real { "threaded" } else { "sim" },
        cache / (1024 * 1024)
    );
    let report = if cli.real {
        ClusterEngine::new(cfg).run_workload(&w).map_err(|e| e.to_string())?
    } else {
        Simulator::from_engine_config(cfg).run_workload(&w).map_err(|e| e.to_string())?
    };

    let events = rec.take();
    let meta = TraceMeta {
        engine: if cli.real { "threaded" } else { "sim" }.to_string(),
        clock: rec.clock(),
        workers: cli.opts.workers,
        dropped: rec.dropped(),
    };
    let write_with = |name: &str, sink: &mut dyn FnMut(std::fs::File) -> std::io::Result<()>|
        -> Result<String, String> {
        let path = format!("{}/{}", cli.out_dir, name);
        let f = std::fs::File::create(&path).map_err(|e| format!("{path}: {e}"))?;
        sink(f).map_err(|e| format!("{path}: {e}"))?;
        Ok(path)
    };
    let jsonl = write_with("trace.jsonl", &mut |f| {
        JsonlSink::new(std::io::BufWriter::new(f)).export(&meta, &events)
    })?;
    // Chrome export carries the sampler's counter tracks alongside the
    // task spans so Perfetto shows both on one time axis.
    let chrome = write_with("trace.chrome.json", &mut |f| {
        ChromeSink::new(std::io::BufWriter::new(f))
            .with_timeline(&report.timeline)
            .export(&meta, &events)
    })?;
    let tl_path = format!("{}/timeline.jsonl", cli.out_dir);
    std::fs::write(&tl_path, report.timeline.to_jsonl())
        .map_err(|e| format!("{tl_path}: {e}"))?;

    let analysis = CriticalPathAnalysis::from_events(&events);
    out!("{}", analysis.render());
    if !analysis.identity_holds() {
        warn!("Σ-segments ≠ JCT for some job (dropped trace events?)");
    }
    if !report.timeline.is_empty() {
        out!("{}", report.timeline.render());
    }
    out!(
        "trace: {} events ({} dropped) → {jsonl} + {chrome} + {tl_path}",
        events.len(),
        meta.dropped
    );
    if let Some(p) = &cli.json_out {
        std::fs::write(p, analysis.to_json()).map_err(|e| format!("{p}: {e}"))?;
        out!("decomposition → {p}");
    }
    Ok(())
}

fn cmd_run(cli: &Cli) -> Result<(), String> {
    let w =
        workload::multi_tenant_zip(cli.opts.tenants, cli.opts.blocks_per_file, cli.opts.block_len);
    let input = w.input_bytes();
    let cache = cli
        .cache_mb
        .map(|mb| (mb * 1024.0 * 1024.0) as u64)
        .unwrap_or(input / 2);
    let cfg = EngineConfig::builder()
        .num_workers(cli.opts.workers)
        .cache_capacity_per_worker(cache / cli.opts.workers as u64)
        .block_len(cli.opts.block_len)
        .policy(cli.policy)
        .seed(cli.opts.seed)
        .compute(compute_mode(cli))
        .time_scale(cli.time_scale)
        // The sim always models the broadcast plane; pin the threaded
        // engine to it too so `peer_msgs` stays comparable across
        // `run` and `run --real`.
        .ctrl_plane(CtrlPlane::Broadcast)
        .build()
        .map_err(|e| e.to_string())?;
    let report = if cli.real {
        ClusterEngine::new(cfg).run_workload(&w).map_err(|e| e.to_string())?
    } else {
        Simulator::from_engine_config(cfg).run_workload(&w).map_err(|e| e.to_string())?
    };
    out!(
        "policy={} makespan={:.3}s hit={:.3} effective={:.3} tasks={} evictions={} peer_msgs={}",
        report.policy,
        report.makespan.as_secs_f64(),
        report.hit_ratio(),
        report.effective_hit_ratio(),
        report.tasks_run,
        report.evictions,
        report.messages.peer_protocol_total()
    );
    if logger::enabled(Level::Verbose) && report.attribution.total() > 0 {
        out!();
        out!("{}", attribution_table(&report, 5));
    }
    Ok(())
}

fn run(cli: Cli) -> Result<(), String> {
    match cli.cmd.as_str() {
        "toy" => {
            out!("## Fig 1 toy example — which block is evicted when e arrives?\n");
            exp::print_toy_table(&exp::toy_fig1_table(&cli.opts.policies));
            out!("\npaper: LERC evicts c (the only right choice); LRC evicts a/b/c arbitrarily; LRU evicts the least-recent (a).");
            Ok(())
        }
        "fig3" => {
            out!("## Fig 3 — all-or-nothing staircase (zip, 2 × 10 blocks)\n");
            let rows =
                exp::fig3_all_or_nothing(10, cli.opts.block_len).map_err(|e| e.to_string())?;
            exp::print_fig3(&rows);
            out!("\npaper: hit ratio climbs linearly; runtime steps down only when a PAIR completes.");
            Ok(())
        }
        "sweep" => cmd_sweep(&cli),
        "comm" => {
            out!("## §III-C communication overhead (LERC)\n");
            let rows = exp::comm_overhead(&cli.opts).map_err(|e| e.to_string())?;
            exp::print_comm(&rows);
            out!("\ninvariant: broadcasts ≤ peer groups (at most one per group life).");
            Ok(())
        }
        "ablation" => {
            out!("## §III-A sticky-eviction ablation (shared-input workload)\n");
            let reports =
                exp::ablation_sticky(4, 16, cli.opts.block_len, 0.4).map_err(|e| e.to_string())?;
            out!("| policy | makespan (s) | hit ratio | effective hit ratio |");
            out!("|---|---|---|---|");
            for r in &reports {
                out!(
                    "| {} | {:.3} | {:.3} | {:.3} |",
                    r.policy,
                    r.makespan.as_secs_f64(),
                    r.hit_ratio(),
                    r.effective_hit_ratio()
                );
            }
            Ok(())
        }
        "orders" => {
            out!("## Arrival-order ablation (extension) — LRU vs LERC at 1/2 cache\n");
            let rows = exp::ablation_arrival_order(&cli.opts, 0.5).map_err(|e| e.to_string())?;
            out!("| arrival order | LRU eff | LERC eff | LRU t(s) | LERC t(s) |");
            out!("|---|---|---|---|---|");
            for (name, lru, lerc) in &rows {
                out!(
                    "| {} | {:.3} | {:.3} | {:.3} | {:.3} |",
                    name,
                    lru.effective_hit_ratio(),
                    lerc.effective_hit_ratio(),
                    lru.compute_makespan.as_secs_f64(),
                    lerc.compute_makespan.as_secs_f64()
                );
            }
            out!("\nfinding: LRU's collapse is arrival-order-ROBUST here — the dominant");
            out!("mechanism is zip outputs (recent => hot under LRU) polluting the cache,");
            out!("not ingest order. LERC is unaffected in every order.");
            Ok(())
        }
        "run" => cmd_run(&cli),
        "trace" => cmd_trace(&cli),
        "analyze" => cmd_analyze(&cli),
        "all" => {
            for cmd in ["toy", "fig3", "sweep", "comm", "ablation", "orders"] {
                let mut c = cli.clone();
                c.cmd = cmd.into();
                run(c)?;
                out!();
            }
            Ok(())
        }
        other => Err(format!(
            "unknown command `{other}` (toy|fig3|sweep|comm|ablation|orders|run|trace|analyze|all)"
        )),
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match parse(&args) {
        Ok(cli) => {
            logger::set_level(cli.level);
            match run(cli) {
                Ok(()) => ExitCode::SUCCESS,
                Err(e) => {
                    eprintln!("error: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
