//! Driver-side `PeerTrackerMaster` (paper Fig 4): the authority for
//! peer-group invalidation and the protocol's message accounting.

use crate::common::ids::{BlockId, GroupId, TaskId, WorkerId};
use crate::dag::analysis::PeerGroup;
use crate::scheduler::AliveSet;

use crate::common::fxhash::FxHashMap;

/// Message counters for the §III-C communication-overhead analysis.
#[derive(Debug, Clone, Copy, Default)]
pub struct MasterStats {
    /// Peer-profile registrations pushed to workers (one broadcast per job).
    pub profile_broadcasts: u64,
    /// Eviction reports received from workers (worker → master messages).
    pub reports_received: u64,
    /// Reports that were redundant (groups already invalid) and therefore
    /// did NOT trigger a broadcast.
    pub reports_suppressed: u64,
    /// Invalidation broadcasts issued (master → all-workers messages).
    pub broadcasts_sent: u64,
    /// Groups invalidated across all broadcasts.
    pub groups_invalidated: u64,
}

#[derive(Debug, Clone)]
struct GroupState {
    #[allow(dead_code)] // kept for debugging/inspection parity with the worker replica
    members: Vec<BlockId>,
    complete: bool,
    retired: bool,
}

/// The master replica. All complete→incomplete transitions are decided
/// here so concurrent reports from different workers dedupe to one
/// broadcast (the protocol's "at most one broadcast per group" property).
///
/// Multi-job scope: the online engines call [`Self::register`] /
/// [`Self::register_routed_in`] once per job at admission (group ids are
/// globally unique — they reuse task ids from the engine's shared
/// counter), so `by_member` naturally spans jobs: an eviction of a
/// shared ingest block invalidates every job's complete groups in one
/// broadcast, while [`Self::retire_task`] retires exactly one job's
/// group. The routed interest index likewise accumulates per job — a
/// later job's registration only ever *adds* interested workers.
#[derive(Debug, Default)]
pub struct PeerTrackerMaster {
    groups: FxHashMap<GroupId, GroupState>,
    by_member: FxHashMap<BlockId, Vec<GroupId>>,
    by_task: FxHashMap<TaskId, GroupId>,
    /// Inverted routing index (home-routed control plane): block → the
    /// workers whose replicas hold a group containing it, i.e. the home
    /// workers of all co-members across all of the block's groups. Only
    /// populated by [`Self::register_routed`].
    interested: FxHashMap<BlockId, Vec<WorkerId>>,
    pub stats: MasterStats,
}

impl PeerTrackerMaster {
    /// Parse a job's peer profile (from the DAG scheduler) and record the
    /// broadcast of that profile to workers.
    pub fn register(&mut self, groups: &[PeerGroup]) {
        for g in groups {
            self.groups.insert(
                g.id,
                GroupState {
                    members: g.members.clone(),
                    complete: true,
                    retired: false,
                },
            );
            self.by_task.insert(g.task, g.id);
            for m in &g.members {
                self.by_member.entry(*m).or_default().push(g.id);
            }
        }
        self.stats.profile_broadcasts += 1;
    }

    /// [`Self::register`] plus maintenance of the block → interested-workers
    /// routing index used by the home-routed control plane: an eviction
    /// invalidation for a block need only reach the workers whose
    /// registered peer groups contain it (the home workers of every
    /// co-member), not the whole cluster.
    pub fn register_routed(&mut self, groups: &[PeerGroup], num_workers: u32) {
        self.register_routed_in(groups, &AliveSet::new(num_workers));
    }

    /// [`Self::register_routed`] against a failure-aware worker set:
    /// recovery registers recompute-task groups at the *current* homes of
    /// their members (the surviving workers), keeping the DESIGN.md §1
    /// invariant — every replica that can cache a member holds the group.
    pub fn register_routed_in(&mut self, groups: &[PeerGroup], alive: &AliveSet) {
        self.register(groups);
        // Append first, dedupe each touched entry once at the end: linear
        // in total (member × home) pairs instead of rescanning the entry
        // per insertion.
        let mut touched: Vec<BlockId> = Vec::new();
        for g in groups {
            let homes = alive.homes_of(&g.members);
            for m in &g.members {
                touched.push(*m);
                self.interested.entry(*m).or_default().extend_from_slice(&homes);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for b in touched {
            let ws = self.interested.get_mut(&b).expect("touched entry present");
            ws.sort_unstable();
            ws.dedup();
        }
    }

    /// Record that `worker` now holds replicas of `groups` (restart
    /// repair re-registers a revived worker's home subset): invalidations
    /// for their members must reach it again. Append-only, like the rest
    /// of the index — stale deliveries are no-ops at the replica.
    pub fn add_interest(&mut self, groups: &[PeerGroup], worker: WorkerId) {
        for g in groups {
            for m in &g.members {
                let ws = self.interested.entry(*m).or_default();
                if !ws.contains(&worker) {
                    ws.push(worker);
                    ws.sort_unstable();
                }
            }
        }
    }

    /// Workers whose registered peer groups contain `block` (empty unless
    /// groups were installed via [`Self::register_routed`]). A superset of
    /// the workers with *live* groups containing the block, which keeps
    /// the index append-only; stale deliveries are no-ops at the replica.
    pub fn interested_workers(&self, block: BlockId) -> &[WorkerId] {
        self.interested.get(&block).map(Vec::as_slice).unwrap_or(&[])
    }

    /// A worker reported the eviction of `block`. Returns `Some(block)` if
    /// an invalidation broadcast must go out (the block sat in at least
    /// one complete group), `None` if the report was redundant.
    pub fn on_eviction_report(&mut self, block: BlockId) -> Option<BlockId> {
        self.stats.reports_received += 1;
        let out = self.invalidate_member(block);
        if out.is_none() {
            self.stats.reports_suppressed += 1;
        }
        out
    }

    /// A worker died while caching `block` (recovery's mass eviction).
    /// Identical group-state transition to [`Self::on_eviction_report`],
    /// but not counted as worker→master protocol traffic — the driver
    /// detects the failure itself, no report message crossed the wire.
    pub fn fail_member(&mut self, block: BlockId) -> Option<BlockId> {
        self.invalidate_member(block)
    }

    fn invalidate_member(&mut self, block: BlockId) -> Option<BlockId> {
        let gids: Vec<GroupId> = self
            .by_member
            .get(&block)
            .map(|gs| {
                gs.iter()
                    .filter(|g| {
                        self.groups
                            .get(g)
                            .map(|s| s.complete && !s.retired)
                            .unwrap_or(false)
                    })
                    .copied()
                    .collect()
            })
            .unwrap_or_default();
        if gids.is_empty() {
            return None;
        }
        for gid in &gids {
            self.groups.get_mut(gid).expect("indexed").complete = false;
        }
        self.stats.broadcasts_sent += 1;
        self.stats.groups_invalidated += gids.len() as u64;
        Some(block)
    }

    /// Force groups incomplete without an invalidation event (recovery
    /// registers recompute-task groups whose members are known-uncached:
    /// starting them complete would resurrect broken groups). No stats —
    /// this is driver-side knowledge, not protocol traffic.
    pub fn mark_incomplete(&mut self, gids: &[GroupId]) {
        for g in gids {
            if let Some(st) = self.groups.get_mut(g) {
                st.complete = false;
            }
        }
    }

    /// Task completion (driver-side knowledge; carried by the existing
    /// scheduler→worker completion flow, so not counted as peer traffic).
    pub fn retire_task(&mut self, task: TaskId) {
        if let Some(gid) = self.by_task.get(&task) {
            if let Some(st) = self.groups.get_mut(gid) {
                st.retired = true;
            }
        }
    }

    pub fn group_complete(&self, task: TaskId) -> Option<bool> {
        self.by_task
            .get(&task)
            .and_then(|g| self.groups.get(g))
            .map(|s| s.complete)
    }

    /// Has `task`'s group been retired? (Restart repair re-registers only
    /// unretired groups at a revived worker.)
    pub fn task_retired(&self, task: TaskId) -> Option<bool> {
        self.by_task
            .get(&task)
            .and_then(|g| self.groups.get(g))
            .map(|s| s.retired)
    }

    pub fn group_count(&self) -> usize {
        self.groups.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn group(id: u64, members: &[BlockId]) -> PeerGroup {
        PeerGroup {
            id: GroupId(id),
            task: TaskId(id),
            members: members.to_vec(),
            output: b(100 + id as u32),
        }
    }

    #[test]
    fn first_report_broadcasts_second_suppressed() {
        let mut m = PeerTrackerMaster::default();
        m.register(&[group(0, &[b(1), b(2)])]);
        assert_eq!(m.on_eviction_report(b(1)), Some(b(1)));
        // Peer b2 evicted later: group already incomplete -> suppressed.
        assert_eq!(m.on_eviction_report(b(2)), None);
        assert_eq!(m.stats.broadcasts_sent, 1);
        assert_eq!(m.stats.reports_received, 2);
        assert_eq!(m.stats.reports_suppressed, 1);
    }

    #[test]
    fn at_most_one_broadcast_per_group() {
        let mut m = PeerTrackerMaster::default();
        let groups: Vec<_> = (0..10)
            .map(|i| group(i, &[b(2 * i as u32), b(2 * i as u32 + 1)]))
            .collect();
        m.register(&groups);
        // Evict every block in arbitrary order.
        for i in 0..20 {
            m.on_eviction_report(b(i));
        }
        assert_eq!(m.stats.broadcasts_sent, 10);
        assert_eq!(m.stats.groups_invalidated, 10);
    }

    #[test]
    fn retired_groups_do_not_broadcast() {
        let mut m = PeerTrackerMaster::default();
        m.register(&[group(0, &[b(1), b(2)])]);
        m.retire_task(TaskId(0));
        assert_eq!(m.on_eviction_report(b(1)), None);
        assert_eq!(m.stats.broadcasts_sent, 0);
    }

    #[test]
    fn routed_index_covers_comember_homes() {
        let mut m = PeerTrackerMaster::default();
        // Group 0: blocks 1 & 2 (homes 1, 2 of 4); group 1: blocks 1 & 6
        // (homes 1, 2). Workers interested in b1 = homes of {1, 2, 6}.
        m.register_routed(&[group(0, &[b(1), b(2)]), group(1, &[b(1), b(6)])], 4);
        let ws = |block: BlockId| {
            let mut v: Vec<u32> = m.interested_workers(block).iter().map(|w| w.0).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(ws(b(1)), vec![1, 2]);
        assert_eq!(ws(b(2)), vec![1, 2]);
        assert_eq!(ws(b(6)), vec![1, 2]);
        // Unregistered block: nobody interested.
        assert!(m.interested_workers(b(9)).is_empty());
        // Plain register leaves the routing index empty.
        let mut plain = PeerTrackerMaster::default();
        plain.register(&[group(0, &[b(1), b(2)])]);
        assert!(plain.interested_workers(b(1)).is_empty());
    }

    #[test]
    fn mark_incomplete_skips_stats_and_future_reports() {
        let mut m = PeerTrackerMaster::default();
        m.register(&[group(0, &[b(1), b(2)])]);
        m.mark_incomplete(&[GroupId(0), GroupId(9)]); // unknown id ignored
        assert_eq!(m.group_complete(TaskId(0)), Some(false));
        assert_eq!(m.stats.broadcasts_sent, 0);
        assert_eq!(m.stats.groups_invalidated, 0);
        // Member evictions of an already-incomplete group stay silent.
        assert_eq!(m.on_eviction_report(b(1)), None);
    }

    #[test]
    fn fail_member_invalidates_without_report_accounting() {
        let mut m = PeerTrackerMaster::default();
        m.register(&[group(0, &[b(1), b(2)])]);
        assert_eq!(m.fail_member(b(1)), Some(b(1)));
        assert_eq!(m.fail_member(b(2)), None, "group already broken");
        assert_eq!(m.stats.reports_received, 0);
        assert_eq!(m.stats.reports_suppressed, 0);
        assert_eq!(m.stats.broadcasts_sent, 1);
        assert_eq!(m.group_complete(TaskId(0)), Some(false));
    }

    #[test]
    fn retired_query_and_interest_extension() {
        let mut m = PeerTrackerMaster::default();
        let g = group(0, &[b(1), b(2)]);
        m.register_routed(std::slice::from_ref(&g), 4);
        assert_eq!(m.task_retired(TaskId(0)), Some(false));
        m.retire_task(TaskId(0));
        assert_eq!(m.task_retired(TaskId(0)), Some(true));
        assert_eq!(m.task_retired(TaskId(9)), None);
        // A revived worker re-registers the group: it becomes interested.
        m.add_interest(std::slice::from_ref(&g), WorkerId(3));
        let ws: Vec<u32> = m.interested_workers(b(1)).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![1, 2, 3]);
        // Idempotent.
        m.add_interest(std::slice::from_ref(&g), WorkerId(3));
        assert_eq!(m.interested_workers(b(1)).len(), 3);
    }

    #[test]
    fn routed_registration_respects_the_alive_set() {
        let mut m = PeerTrackerMaster::default();
        let mut alive = AliveSet::new(4);
        alive.kill(WorkerId(1));
        // Members home at 1 and 2; worker 1 is down, so its member
        // probes to worker 2 — interest lands on survivors only.
        m.register_routed_in(&[group(0, &[b(1), b(2)])], &alive);
        let ws: Vec<u32> = m.interested_workers(b(1)).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![2]);
    }

    #[test]
    fn per_job_registration_accumulates_interest_without_disturbing_counts() {
        let mut m = PeerTrackerMaster::default();
        // Job A admitted first: its group over {b1, b2} (homes 1, 2).
        m.register_routed(&[group(0, &[b(1), b(2)])], 4);
        assert_eq!(m.stats.profile_broadcasts, 1);
        // Job B admitted later, sharing b1 with a private b7 (home 3).
        m.register_routed(&[group(100, &[b(1), b(7)])], 4);
        assert_eq!(m.stats.profile_broadcasts, 2);
        let ws: Vec<u32> = m.interested_workers(b(1)).iter().map(|w| w.0).collect();
        assert_eq!(ws, vec![1, 2, 3], "B's registration adds interest, never removes");
        // Retiring A's task leaves B's group live: evicting the shared
        // block still broadcasts for B.
        m.retire_task(TaskId(0));
        assert_eq!(m.on_eviction_report(b(1)), Some(b(1)));
        assert_eq!(m.stats.groups_invalidated, 1, "only B's group was live");
    }

    #[test]
    fn shared_block_invalidates_all_its_groups_in_one_broadcast() {
        let mut m = PeerTrackerMaster::default();
        m.register(&[group(0, &[b(1), b(2)]), group(1, &[b(1), b(3)])]);
        assert_eq!(m.on_eviction_report(b(1)), Some(b(1)));
        assert_eq!(m.stats.broadcasts_sent, 1);
        assert_eq!(m.stats.groups_invalidated, 2);
        assert_eq!(m.group_complete(TaskId(0)), Some(false));
        assert_eq!(m.group_complete(TaskId(1)), Some(false));
    }
}
