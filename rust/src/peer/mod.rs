//! The coordinated peer-tracking protocol (paper §III-C, Fig 4).
//!
//! * [`PeerTrackerMaster`] lives on the driver: it parses peer-groups from
//!   the job DAG, receives *eviction reports* from workers, and issues
//!   *invalidation broadcasts*.
//! * [`WorkerPeerTracker`] lives on every worker: it labels groups
//!   complete/incomplete, decides when a local eviction must be reported,
//!   and converts invalidations into effective-reference-count deltas for
//!   the local LERC policy.
//!
//! The protocol's claim — **at most one broadcast per peer-group life** —
//! holds because a group only triggers traffic on its complete→incomplete
//! edge, after which it never becomes complete again. This is verified by
//! property tests (`rust/tests/proptest_peer.rs`) and measured by
//! `benches/comm_overhead.rs`.

pub mod master;
pub mod tracker;

pub use master::{MasterStats, PeerTrackerMaster};
pub use tracker::WorkerPeerTracker;
