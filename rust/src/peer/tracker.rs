//! Per-worker peer-group state (the `PeerTracker` box in the paper's
//! Fig 4 architecture).

use crate::common::fxhash::FxHashMap;
use crate::common::ids::{BlockId, GroupId, TaskId};
use crate::dag::analysis::PeerGroup;

#[derive(Debug, Clone)]
struct GroupState {
    members: Vec<BlockId>,
    complete: bool,
    retired: bool,
}

/// Worker-side replica of peer-group state.
///
/// Every worker holds *all* groups (the paper broadcasts the peer profile
/// to every worker because an evicted block's peers may not be computed
/// yet, so their home is unknown).
///
/// Multi-job scope: group ids are namespaced by construction (they reuse
/// globally-unique task ids assigned from the engine's shared counter at
/// job admission), so registration is per-job and
/// [`Self::effective_count`] aggregates live groups **across every
/// admitted job** — a shared ingest block referenced by three jobs'
/// complete groups counts 3. Retiring one job's task touches only that
/// job's group, never disturbing the counts other jobs contribute.
#[derive(Debug, Default)]
pub struct WorkerPeerTracker {
    groups: FxHashMap<GroupId, GroupState>,
    by_member: FxHashMap<BlockId, Vec<GroupId>>,
    by_task: FxHashMap<TaskId, GroupId>,
}

impl WorkerPeerTracker {
    /// Install the peer profile for a submitted job. Groups start
    /// "complete" (Def. 2 is vacuous until members materialize) unless the
    /// driver already knows a materialized member is uncached (job
    /// registration never does; recovery's re-registration at a repaired
    /// home passes the master's broken set). Already-registered ids are
    /// skipped, so repair re-sends cannot double-count effective refs.
    pub fn register(&mut self, groups: &[PeerGroup], initially_incomplete: &[GroupId]) {
        for g in groups {
            if self.groups.contains_key(&g.id) {
                continue;
            }
            let complete = !initially_incomplete.contains(&g.id);
            self.groups.insert(
                g.id,
                GroupState {
                    members: g.members.clone(),
                    complete,
                    retired: false,
                },
            );
            self.by_task.insert(g.task, g.id);
            for m in &g.members {
                self.by_member.entry(*m).or_default().push(g.id);
            }
        }
    }

    /// Effective reference count of `block`: the number of live (complete,
    /// unretired) groups referencing it — Def. 2 made countable.
    pub fn effective_count(&self, block: BlockId) -> u32 {
        self.by_member
            .get(&block)
            .map(|gs| {
                gs.iter()
                    .filter(|g| {
                        self.groups
                            .get(g)
                            .map(|s| s.complete && !s.retired)
                            .unwrap_or(false)
                    })
                    .count() as u32
            })
            .unwrap_or(0)
    }

    /// Does any unretired group still reference `block` — i.e. will some
    /// pending task read it again? The spill tier's coordinated mode
    /// refuses to spend budget on blocks this returns `false` for
    /// (consumed intermediates, job results): spilling dead bytes can
    /// only displace bytes a restore would have saved.
    pub fn unconsumed(&self, block: BlockId) -> bool {
        self.by_member
            .get(&block)
            .map(|gs| {
                gs.iter()
                    .any(|g| self.groups.get(g).map(|s| !s.retired).unwrap_or(false))
            })
            .unwrap_or(false)
    }

    /// Co-members of `block`'s *live* (complete, unretired) groups —
    /// deduped, excluding `block` itself. This is the set the coordinated
    /// spill tier demotes alongside an evicted member: once one member
    /// leaves memory, the rest of the group's memory residency buys
    /// nothing (the paper's all-or-nothing argument), so the whole
    /// remaining group moves to the cheap tier together.
    pub fn live_co_members(&self, block: BlockId) -> Vec<BlockId> {
        let Some(gids) = self.by_member.get(&block) else {
            return vec![];
        };
        let mut out: Vec<BlockId> = gids
            .iter()
            .filter(|g| {
                self.groups
                    .get(g)
                    .map(|s| s.complete && !s.retired)
                    .unwrap_or(false)
            })
            .flat_map(|g| self.groups[g].members.iter().copied())
            .filter(|m| *m != block)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// A block was evicted from *this* worker's cache. Per the protocol,
    /// the worker checks whether it belongs to any complete group; if so
    /// the eviction must be reported to the master (which will broadcast).
    /// State is NOT mutated here — the master's broadcast is the
    /// authoritative invalidation (all replicas apply it identically).
    pub fn should_report_eviction(&self, block: BlockId) -> bool {
        self.by_member
            .get(&block)
            .map(|gs| {
                gs.iter().any(|g| {
                    self.groups
                        .get(g)
                        .map(|s| s.complete && !s.retired)
                        .unwrap_or(false)
                })
            })
            .unwrap_or(false)
    }

    /// Apply an invalidation broadcast: `block` was evicted somewhere.
    /// Marks every complete group containing it incomplete and returns the
    /// new effective counts of all affected members (for policy updates),
    /// plus the list of members of newly-broken groups (for Sticky).
    pub fn apply_eviction_broadcast(
        &mut self,
        block: BlockId,
    ) -> (Vec<(BlockId, u32)>, Vec<BlockId>) {
        let gids: Vec<GroupId> = self
            .by_member
            .get(&block)
            .map(|gs| {
                gs.iter()
                    .filter(|g| {
                        self.groups
                            .get(g)
                            .map(|s| s.complete && !s.retired)
                            .unwrap_or(false)
                    })
                    .copied()
                    .collect()
            })
            .unwrap_or_default();

        let mut touched: Vec<BlockId> = Vec::new();
        for gid in &gids {
            let st = self.groups.get_mut(gid).expect("gid from index");
            st.complete = false;
            touched.extend(st.members.iter().copied());
        }
        touched.sort();
        touched.dedup();
        let deltas = touched
            .iter()
            .map(|b| (*b, self.effective_count(*b)))
            .collect();
        (deltas, touched)
    }

    /// A task completed: its group's references are consumed. Returns the
    /// new effective counts of the group's members.
    pub fn retire_task(&mut self, task: TaskId) -> Vec<(BlockId, u32)> {
        let Some(gid) = self.by_task.get(&task).copied() else {
            return vec![];
        };
        let members = {
            let st = self.groups.get_mut(&gid).expect("task index consistent");
            if st.retired {
                return vec![];
            }
            st.retired = true;
            st.members.clone()
        };
        members
            .iter()
            .map(|b| (*b, self.effective_count(*b)))
            .collect()
    }

    /// Members of the peer-group registered for `task`, if any —
    /// diagnostics and a building block for callers assembling sticky
    /// pin sets (the worker pins the locally-cached *subset* of a
    /// task's inputs, which it already holds; see `driver::worker`).
    pub fn group_members(&self, task: TaskId) -> Option<&[BlockId]> {
        self.by_task
            .get(&task)
            .and_then(|g| self.groups.get(g))
            .map(|s| s.members.as_slice())
    }

    /// Is the group for `task` still complete? (Used by tests and by the
    /// engine's effective-hit accounting cross-check.)
    pub fn group_complete(&self, task: TaskId) -> Option<bool> {
        self.by_task
            .get(&task)
            .and_then(|g| self.groups.get(g))
            .map(|s| s.complete)
    }

    pub fn group_count(&self) -> usize {
        self.groups.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn group(id: u64, members: &[BlockId]) -> PeerGroup {
        PeerGroup {
            id: GroupId(id),
            task: TaskId(id),
            members: members.to_vec(),
            output: b(100 + id as u32),
        }
    }

    fn tracker_with(groups: &[PeerGroup]) -> WorkerPeerTracker {
        let mut t = WorkerPeerTracker::default();
        t.register(groups, &[]);
        t
    }

    #[test]
    fn effective_count_counts_live_groups() {
        // b1 in two groups, b2 in one.
        let t = tracker_with(&[group(0, &[b(1), b(2)]), group(1, &[b(1), b(3)])]);
        assert_eq!(t.effective_count(b(1)), 2);
        assert_eq!(t.effective_count(b(2)), 1);
        assert_eq!(t.effective_count(b(9)), 0);
    }

    #[test]
    fn eviction_breaks_groups_once() {
        let mut t = tracker_with(&[group(0, &[b(1), b(2)]), group(1, &[b(1), b(3)])]);
        assert!(t.should_report_eviction(b(1)));
        let (deltas, broken) = t.apply_eviction_broadcast(b(1));
        // Both groups contained b1 -> everyone drops to 0.
        assert_eq!(t.effective_count(b(1)), 0);
        assert_eq!(t.effective_count(b(2)), 0);
        assert_eq!(t.effective_count(b(3)), 0);
        assert_eq!(broken.len(), 3);
        assert!(deltas.iter().all(|&(_, c)| c == 0));
        // Second eviction of the same block: nothing complete remains.
        assert!(!t.should_report_eviction(b(1)));
        let (d2, _) = t.apply_eviction_broadcast(b(1));
        assert!(d2.is_empty());
    }

    #[test]
    fn partial_overlap_breaks_only_containing_groups() {
        let mut t = tracker_with(&[group(0, &[b(1), b(2)]), group(1, &[b(3), b(4)])]);
        t.apply_eviction_broadcast(b(1));
        assert_eq!(t.effective_count(b(3)), 1);
        assert_eq!(t.effective_count(b(4)), 1);
        assert!(t.should_report_eviction(b(4)));
    }

    #[test]
    fn retire_consumes_references() {
        let mut t = tracker_with(&[group(0, &[b(1), b(2)]), group(1, &[b(1), b(3)])]);
        let deltas = t.retire_task(TaskId(0));
        assert_eq!(t.effective_count(b(1)), 1); // group 1 still live
        assert_eq!(t.effective_count(b(2)), 0);
        assert!(deltas.contains(&(b(1), 1)));
        assert!(deltas.contains(&(b(2), 0)));
        // Retiring twice is a no-op.
        assert!(t.retire_task(TaskId(0)).is_empty());
        // Evicting a member of only-retired groups needs no report.
        assert!(!t.should_report_eviction(b(2)));
    }

    #[test]
    fn initially_incomplete_groups_never_count() {
        let mut t = WorkerPeerTracker::default();
        let g = group(0, &[b(1), b(2)]);
        t.register(&[g], &[GroupId(0)]);
        assert_eq!(t.effective_count(b(1)), 0);
        assert!(!t.should_report_eviction(b(1)));
        assert_eq!(t.group_complete(TaskId(0)), Some(false));
    }

    #[test]
    fn re_registration_is_idempotent() {
        let g = group(0, &[b(1), b(2)]);
        let mut t = tracker_with(std::slice::from_ref(&g));
        t.apply_eviction_broadcast(b(1));
        assert_eq!(t.effective_count(b(2)), 0);
        // A repair re-send of the same group must not resurrect it or
        // double-index its members.
        t.register(std::slice::from_ref(&g), &[]);
        assert_eq!(t.effective_count(b(1)), 0);
        assert_eq!(t.effective_count(b(2)), 0);
        assert_eq!(t.group_count(), 1);
    }

    #[test]
    fn group_members_returns_registered_set() {
        let t = tracker_with(&[group(0, &[b(1), b(2)])]);
        assert_eq!(t.group_members(TaskId(0)), Some([b(1), b(2)].as_slice()));
        assert_eq!(t.group_members(TaskId(9)), None);
    }

    #[test]
    fn cross_job_counts_aggregate_and_retire_independently() {
        // Two jobs share block b1 (content-keyed shared ingest). Their
        // groups arrive in separate per-job registrations; the shared
        // block's effective count is the cross-job aggregate.
        let mut t = WorkerPeerTracker::default();
        t.register(&[group(0, &[b(1), b(2)])], &[]); // job A's profile
        t.register(&[group(100, &[b(1), b(3)])], &[]); // job B's, admitted later
        assert_eq!(t.effective_count(b(1)), 2);
        // Job A retiring its task consumes only A's reference; B's group
        // keeps the shared block's count positive.
        let deltas = t.retire_task(TaskId(0));
        assert!(deltas.contains(&(b(1), 1)));
        assert_eq!(t.effective_count(b(1)), 1);
        assert!(t.should_report_eviction(b(1)), "B still protects b1");
        // An eviction of B's private peer breaks only B's group.
        t.apply_eviction_broadcast(b(3));
        assert_eq!(t.effective_count(b(1)), 0);
    }

    #[test]
    fn unconsumed_tracks_retirement_not_completeness() {
        let mut t = tracker_with(&[group(0, &[b(1), b(2)])]);
        assert!(t.unconsumed(b(1)));
        // Breaking the group leaves the reference pending: the task will
        // still read b1 (from disk or spill), so it is not dead yet.
        t.apply_eviction_broadcast(b(2));
        assert!(t.unconsumed(b(1)));
        t.retire_task(TaskId(0));
        assert!(!t.unconsumed(b(1)));
        assert!(!t.unconsumed(b(9)), "unknown blocks are dead");
    }

    #[test]
    fn live_co_members_span_live_groups_only() {
        let mut t = tracker_with(&[
            group(0, &[b(1), b(2)]),
            group(1, &[b(1), b(3)]),
            group(2, &[b(1), b(4)]),
        ]);
        assert_eq!(t.live_co_members(b(1)), vec![b(2), b(3), b(4)]);
        // A broken group's members are no longer gathered...
        t.apply_eviction_broadcast(b(3));
        assert_eq!(t.live_co_members(b(1)), vec![b(2), b(4)]);
        // ...nor a retired group's.
        t.retire_task(TaskId(0));
        assert_eq!(t.live_co_members(b(1)), vec![b(4)]);
        assert!(t.live_co_members(b(9)).is_empty());
    }

    #[test]
    fn unary_groups_behave() {
        let mut t = tracker_with(&[group(0, &[b(1)])]);
        assert_eq!(t.effective_count(b(1)), 1);
        assert!(t.should_report_eviction(b(1)));
        t.apply_eviction_broadcast(b(1));
        assert_eq!(t.effective_count(b(1)), 0);
    }
}
