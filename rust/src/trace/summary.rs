//! Trace summarization: scan a JSONL event log (the [`JsonlSink`]
//! format) and derive per-kind counts, top blocking blocks, and task
//! latency percentiles. Powers `lerc trace --summarize` and the
//! round-trip tests; `tools/trace_report.py` is the out-of-process
//! twin for CI.
//!
//! [`JsonlSink`]: crate::trace::sink::JsonlSink

use crate::metrics::hist::{fmt_nanos, LatencyHistogram};
use std::collections::BTreeMap;

/// Parse one flat JSON object (string/integer values only — exactly what
/// `JsonlSink` emits) into key → raw-value-string pairs. Returns `None`
/// on anything that isn't a flat object; nested values make it fail
/// loudly rather than mis-summarize.
pub fn parse_flat_json(line: &str) -> Option<BTreeMap<String, String>> {
    let line = line.trim();
    let inner = line.strip_prefix('{')?.strip_suffix('}')?;
    let mut out = BTreeMap::new();
    let mut chars = inner.chars().peekable();
    loop {
        // Skip separators / whitespace before a key.
        while matches!(chars.peek(), Some(',') | Some(' ')) {
            chars.next();
        }
        if chars.peek().is_none() {
            return Some(out);
        }
        if chars.next()? != '"' {
            return None;
        }
        let mut key = String::new();
        loop {
            match chars.next()? {
                '"' => break,
                '\\' => key.push(unescape(chars.next()?)?),
                c => key.push(c),
            }
        }
        if chars.next()? != ':' {
            return None;
        }
        let mut val = String::new();
        match chars.peek()? {
            '"' => {
                chars.next();
                loop {
                    match chars.next()? {
                        '"' => break,
                        '\\' => val.push(unescape(chars.next()?)?),
                        c => val.push(c),
                    }
                }
            }
            '{' | '[' => return None, // not flat
            _ => {
                while let Some(&c) = chars.peek() {
                    if c == ',' {
                        break;
                    }
                    val.push(c);
                    chars.next();
                }
                val = val.trim().to_string();
            }
        }
        out.insert(key, val);
    }
}

fn unescape(c: char) -> Option<char> {
    match c {
        '"' => Some('"'),
        '\\' => Some('\\'),
        'n' => Some('\n'),
        'r' => Some('\r'),
        't' => Some('\t'),
        '/' => Some('/'),
        // \uXXXX would need lookahead; the sink never emits it for the
        // ids we serialize, so treat it as malformed here.
        _ => None,
    }
}

/// Aggregate view of one JSONL trace.
#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    pub engine: String,
    pub clock: String,
    pub workers: u64,
    pub dropped: u64,
    /// Event count per kind, sorted by kind.
    pub kinds: BTreeMap<String, u64>,
    /// blocking block (Display form) → attributed-access count.
    pub blocking: BTreeMap<String, u64>,
    /// cause string → attributed-access count.
    pub causes: BTreeMap<String, u64>,
    /// dispatched → published latency per completed task.
    pub task_latency: LatencyHistogram,
    /// ready → dispatched wait per dispatched task.
    pub queue_wait: LatencyHistogram,
    /// Lines that failed to parse as flat JSON.
    pub malformed: u64,
}

impl TraceSummary {
    /// Scan JSONL text. The first line is expected to be the
    /// `trace_meta` record but its absence only costs the header fields.
    pub fn from_jsonl(text: &str) -> Self {
        let mut s = TraceSummary::default();
        let mut ready: BTreeMap<u64, u64> = BTreeMap::new();
        let mut dispatched: BTreeMap<u64, u64> = BTreeMap::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Some(obj) = parse_flat_json(line) else {
                s.malformed += 1;
                continue;
            };
            let kind = obj.get("kind").cloned().unwrap_or_default();
            let num = |k: &str| obj.get(k).and_then(|v| v.parse::<u64>().ok());
            if kind == "trace_meta" {
                s.engine = obj.get("engine").cloned().unwrap_or_default();
                s.clock = obj.get("clock").cloned().unwrap_or_default();
                s.workers = num("workers").unwrap_or(0);
                s.dropped = num("dropped").unwrap_or(0);
                continue;
            }
            *s.kinds.entry(kind.clone()).or_default() += 1;
            let ts = num("ts");
            let task = num("task");
            match kind.as_str() {
                "task_ready" => {
                    if let (Some(t), Some(ts)) = (task, ts) {
                        ready.insert(t, ts);
                    }
                }
                "task_dispatched" => {
                    if let (Some(t), Some(ts)) = (task, ts) {
                        dispatched.insert(t, ts);
                        if let Some(r) = ready.remove(&t) {
                            s.queue_wait.record(ts.saturating_sub(r));
                        }
                    }
                }
                "task_published" => {
                    if let (Some(t), Some(ts)) = (task, ts) {
                        if let Some(d) = dispatched.remove(&t) {
                            s.task_latency.record(ts.saturating_sub(d));
                        }
                    }
                }
                "ineffective_hit" => {
                    if let Some(b) = obj.get("blocking") {
                        *s.blocking.entry(b.clone()).or_default() += 1;
                    }
                    if let Some(c) = obj.get("cause") {
                        *s.causes.entry(c.clone()).or_default() += 1;
                    }
                }
                _ => {}
            }
        }
        s
    }

    pub fn total_events(&self) -> u64 {
        self.kinds.values().sum()
    }

    /// Top-K blocking blocks, count descending then name ascending.
    pub fn top_blocking(&self, k: usize) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> =
            self.blocking.iter().map(|(b, n)| (b.clone(), *n)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// Human-readable multi-line report (the `trace --summarize` body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace: engine={} clock={} workers={} events={} dropped={}\n",
            self.engine,
            self.clock,
            self.workers,
            self.total_events(),
            self.dropped
        ));
        if self.malformed > 0 {
            out.push_str(&format!("warning: {} malformed lines\n", self.malformed));
        }
        out.push_str("\nevent counts:\n");
        for (kind, n) in &self.kinds {
            out.push_str(&format!("  {kind:<24} {n}\n"));
        }
        if self.task_latency.count() > 0 {
            out.push_str(&format!(
                "\ntask latency (dispatch→publish, n={}): p50={} p95={} p99={}\n",
                self.task_latency.count(),
                fmt_nanos(self.task_latency.p50()),
                fmt_nanos(self.task_latency.p95()),
                fmt_nanos(self.task_latency.p99())
            ));
        }
        if self.queue_wait.count() > 0 {
            out.push_str(&format!(
                "queue wait (ready→dispatch, n={}): p50={} p95={} p99={}\n",
                self.queue_wait.count(),
                fmt_nanos(self.queue_wait.p50()),
                fmt_nanos(self.queue_wait.p95()),
                fmt_nanos(self.queue_wait.p99())
            ));
        }
        if !self.blocking.is_empty() {
            out.push_str("\nineffective hits by cause:\n");
            for (cause, n) in &self.causes {
                out.push_str(&format!("  {cause:<24} {n}\n"));
            }
            out.push_str("top blocking blocks:\n");
            for (b, n) in self.top_blocking(10) {
                out.push_str(&format!("  {b:<24} {n}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
{\"kind\":\"trace_meta\",\"schema\":1,\"engine\":\"sim\",\"clock\":\"logical\",\"workers\":2,\"dropped\":0,\"events\":6}
{\"kind\":\"task_ready\",\"ts\":100,\"seq\":0,\"track\":0,\"task\":1}
{\"kind\":\"task_dispatched\",\"ts\":300,\"seq\":1,\"track\":0,\"task\":1,\"worker\":0}
{\"kind\":\"ineffective_hit\",\"ts\":350,\"seq\":2,\"track\":1,\"task\":1,\"worker\":0,\"block\":\"D0[1]\",\"blocking\":\"D1[1]\",\"cause\":\"evicted\"}
{\"kind\":\"ineffective_hit\",\"ts\":350,\"seq\":3,\"track\":1,\"task\":1,\"worker\":0,\"block\":\"D1[1]\",\"blocking\":\"D1[1]\",\"cause\":\"evicted\"}
{\"kind\":\"task_published\",\"ts\":900,\"seq\":4,\"track\":1,\"task\":1,\"worker\":0,\"block\":\"D2[1]\"}
{\"kind\":\"worker_killed\",\"ts\":950,\"seq\":5,\"track\":0,\"worker\":1}
";

    #[test]
    fn parses_flat_objects() {
        let obj = parse_flat_json("{\"kind\":\"task_ready\",\"ts\":100,\"task\":1}").unwrap();
        assert_eq!(obj.get("kind").map(String::as_str), Some("task_ready"));
        assert_eq!(obj.get("ts").map(String::as_str), Some("100"));
    }

    #[test]
    fn rejects_nested_objects() {
        assert!(parse_flat_json("{\"a\":{\"b\":1}}").is_none());
        assert!(parse_flat_json("not json").is_none());
    }

    #[test]
    fn summarizes_counts_latency_and_attribution() {
        let s = TraceSummary::from_jsonl(SAMPLE);
        assert_eq!(s.engine, "sim");
        assert_eq!(s.workers, 2);
        assert_eq!(s.malformed, 0);
        assert_eq!(s.total_events(), 6);
        assert_eq!(s.kinds.get("ineffective_hit"), Some(&2));
        // ready 100 → dispatched 300 → published 900
        assert_eq!(s.queue_wait.count(), 1);
        assert!(s.queue_wait.p50() >= 200);
        assert_eq!(s.task_latency.count(), 1);
        assert!(s.task_latency.p50() >= 600);
        assert_eq!(s.top_blocking(5), vec![("D1[1]".to_string(), 2)]);
        assert_eq!(s.causes.get("evicted"), Some(&2));
    }

    #[test]
    fn render_mentions_the_load_bearing_numbers() {
        let s = TraceSummary::from_jsonl(SAMPLE);
        let out = s.render();
        assert!(out.contains("engine=sim"));
        assert!(out.contains("task latency"));
        assert!(out.contains("D1[1]"));
    }

    #[test]
    fn malformed_lines_are_counted_not_fatal() {
        let s = TraceSummary::from_jsonl("{\"kind\":\"task_ready\",\"task\":1,\"ts\":1}\ngarbage\n");
        assert_eq!(s.malformed, 1);
        assert_eq!(s.total_events(), 1);
    }
}
