//! The typed flight-recorder event taxonomy (DESIGN.md §8).
//!
//! Task lifecycle: admitted → ready → dispatched → inputs-pinned →
//! computed → published. Block lifecycle: inserted / evicted / demoted /
//! restored / dropped / invalidated / recompute-planned. Control plane:
//! eviction reports, invalidation broadcasts, per-replica ctrl drains.
//! Failure points: worker killed / revived. Both engines emit the same
//! schema; only the timestamp domain differs (sim clock vs wall clock).

use crate::common::ids::{BlockId, JobId, TaskId, WorkerId};
use crate::metrics::attribution::IneffectiveCause;

/// One structured trace event. Fields are plain ids, so constructing an
/// event never allocates; strings appear only at export time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    // --- task lifecycle (driver track up to dispatch, worker after) ---
    TaskAdmitted { job: JobId, task: TaskId },
    TaskReady { task: TaskId },
    TaskDispatched { task: TaskId, worker: WorkerId },
    InputsPinned { task: TaskId, worker: WorkerId },
    TaskComputed { task: TaskId, worker: WorkerId },
    TaskPublished { task: TaskId, worker: WorkerId, block: BlockId },
    // --- block lifecycle (worker tracks) ------------------------------
    BlockInserted { block: BlockId, worker: WorkerId },
    BlockEvicted { block: BlockId, worker: WorkerId },
    BlockDemoted { block: BlockId, worker: WorkerId },
    BlockRestored { block: BlockId, worker: WorkerId },
    BlockDropped { block: BlockId, worker: WorkerId },
    BlockInvalidated { block: BlockId, worker: WorkerId },
    RecomputePlanned { block: BlockId, task: TaskId },
    // --- control plane ------------------------------------------------
    EvictionReported { block: BlockId },
    InvalidationBroadcast { block: BlockId },
    CtrlDrained { worker: WorkerId, applied: u64 },
    // --- effectiveness ------------------------------------------------
    IneffectiveHit {
        task: TaskId,
        worker: WorkerId,
        /// The accessed group member this attribution is for.
        block: BlockId,
        /// The co-member that kept the group out of memory.
        blocking: BlockId,
        cause: IneffectiveCause,
    },
    // --- failure / recovery points ------------------------------------
    WorkerKilled { worker: WorkerId },
    WorkerRevived { worker: WorkerId },
}

/// A field value for the exporters (flat: integers and short strings).
#[derive(Debug, Clone, PartialEq)]
pub enum Field {
    U64(u64),
    Str(String),
}

impl TraceEvent {
    /// Stable snake_case kind tag — the JSONL `kind` field and the
    /// logical-equivalence key prefix.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::TaskAdmitted { .. } => "task_admitted",
            TraceEvent::TaskReady { .. } => "task_ready",
            TraceEvent::TaskDispatched { .. } => "task_dispatched",
            TraceEvent::InputsPinned { .. } => "inputs_pinned",
            TraceEvent::TaskComputed { .. } => "task_computed",
            TraceEvent::TaskPublished { .. } => "task_published",
            TraceEvent::BlockInserted { .. } => "block_inserted",
            TraceEvent::BlockEvicted { .. } => "block_evicted",
            TraceEvent::BlockDemoted { .. } => "block_demoted",
            TraceEvent::BlockRestored { .. } => "block_restored",
            TraceEvent::BlockDropped { .. } => "block_dropped",
            TraceEvent::BlockInvalidated { .. } => "block_invalidated",
            TraceEvent::RecomputePlanned { .. } => "recompute_planned",
            TraceEvent::EvictionReported { .. } => "eviction_reported",
            TraceEvent::InvalidationBroadcast { .. } => "invalidation_broadcast",
            TraceEvent::CtrlDrained { .. } => "ctrl_drained",
            TraceEvent::IneffectiveHit { .. } => "ineffective_hit",
            TraceEvent::WorkerKilled { .. } => "worker_killed",
            TraceEvent::WorkerRevived { .. } => "worker_revived",
        }
    }

    /// Visit every field as `(name, value)` — the single source of truth
    /// both exporters serialize from.
    pub fn for_each_field(&self, f: &mut dyn FnMut(&'static str, Field)) {
        match self {
            TraceEvent::TaskAdmitted { job, task } => {
                f("job", Field::U64(job.0 as u64));
                f("task", Field::U64(task.0));
            }
            TraceEvent::TaskReady { task } => f("task", Field::U64(task.0)),
            TraceEvent::TaskDispatched { task, worker }
            | TraceEvent::InputsPinned { task, worker }
            | TraceEvent::TaskComputed { task, worker } => {
                f("task", Field::U64(task.0));
                f("worker", Field::U64(worker.0 as u64));
            }
            TraceEvent::TaskPublished { task, worker, block } => {
                f("task", Field::U64(task.0));
                f("worker", Field::U64(worker.0 as u64));
                f("block", Field::Str(block.to_string()));
            }
            TraceEvent::BlockInserted { block, worker }
            | TraceEvent::BlockEvicted { block, worker }
            | TraceEvent::BlockDemoted { block, worker }
            | TraceEvent::BlockRestored { block, worker }
            | TraceEvent::BlockDropped { block, worker }
            | TraceEvent::BlockInvalidated { block, worker } => {
                f("block", Field::Str(block.to_string()));
                f("worker", Field::U64(worker.0 as u64));
            }
            TraceEvent::RecomputePlanned { block, task } => {
                f("block", Field::Str(block.to_string()));
                f("task", Field::U64(task.0));
            }
            TraceEvent::EvictionReported { block }
            | TraceEvent::InvalidationBroadcast { block } => {
                f("block", Field::Str(block.to_string()));
            }
            TraceEvent::CtrlDrained { worker, applied } => {
                f("worker", Field::U64(worker.0 as u64));
                f("applied", Field::U64(*applied));
            }
            TraceEvent::IneffectiveHit {
                task,
                worker,
                block,
                blocking,
                cause,
            } => {
                f("task", Field::U64(task.0));
                f("worker", Field::U64(worker.0 as u64));
                f("block", Field::Str(block.to_string()));
                f("blocking", Field::Str(blocking.to_string()));
                f("cause", Field::Str(cause.as_str().to_string()));
            }
            TraceEvent::WorkerKilled { worker } | TraceEvent::WorkerRevived { worker } => {
                f("worker", Field::U64(worker.0 as u64));
            }
        }
    }

    /// Timestamp-free identity: `kind` plus every field, used by the
    /// sim≡threaded equivalence test ("equal modulo timestamps").
    pub fn logical_key(&self) -> String {
        let mut key = String::from(self.kind());
        self.for_each_field(&mut |name, value| {
            key.push(' ');
            key.push_str(name);
            key.push('=');
            match value {
                Field::U64(v) => key.push_str(&v.to_string()),
                Field::Str(s) => key.push_str(&s),
            }
        });
        key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    #[test]
    fn kinds_are_stable_snake_case() {
        let ev = TraceEvent::TaskDispatched {
            task: TaskId(3),
            worker: WorkerId(1),
        };
        assert_eq!(ev.kind(), "task_dispatched");
    }

    #[test]
    fn logical_key_carries_every_field() {
        let ev = TraceEvent::IneffectiveHit {
            task: TaskId(7),
            worker: WorkerId(0),
            block: BlockId::new(DatasetId(2), 4),
            blocking: BlockId::new(DatasetId(1), 4),
            cause: IneffectiveCause::Evicted,
        };
        assert_eq!(
            ev.logical_key(),
            "ineffective_hit task=7 worker=0 block=D2[4] blocking=D1[4] cause=evicted"
        );
    }
}
