//! The typed flight-recorder event taxonomy (DESIGN.md §8).
//!
//! Task lifecycle: admitted → ready → dispatched → inputs-pinned →
//! computed → published. Block lifecycle: inserted / evicted / demoted /
//! restored / dropped / invalidated / recompute-planned. Control plane:
//! eviction reports, invalidation broadcasts, per-replica ctrl drains.
//! Failure points: worker killed / revived. Both engines emit the same
//! schema; only the timestamp domain differs (sim clock vs wall clock).

use crate::common::ids::{BlockId, GroupId, JobId, TaskId, WorkerId};
use crate::metrics::attribution::IneffectiveCause;

/// One structured trace event. Fields are plain ids, so constructing an
/// event never allocates; strings appear only at export time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    // --- task lifecycle (driver track up to dispatch, worker after) ---
    TaskAdmitted { job: JobId, task: TaskId },
    TaskReady { task: TaskId },
    TaskDispatched { task: TaskId, worker: WorkerId },
    InputsPinned { task: TaskId, worker: WorkerId },
    TaskComputed { task: TaskId, worker: WorkerId },
    TaskPublished { task: TaskId, worker: WorkerId, block: BlockId },
    // --- block lifecycle (worker tracks) ------------------------------
    BlockInserted { block: BlockId, worker: WorkerId },
    BlockEvicted { block: BlockId, worker: WorkerId },
    BlockDemoted { block: BlockId, worker: WorkerId },
    BlockRestored { block: BlockId, worker: WorkerId },
    BlockDropped { block: BlockId, worker: WorkerId },
    BlockInvalidated { block: BlockId, worker: WorkerId },
    RecomputePlanned { block: BlockId, task: TaskId },
    // --- control plane ------------------------------------------------
    EvictionReported { block: BlockId },
    InvalidationBroadcast { block: BlockId },
    CtrlDrained { worker: WorkerId, applied: u64 },
    // --- effectiveness ------------------------------------------------
    IneffectiveHit {
        task: TaskId,
        worker: WorkerId,
        /// The accessed group member this attribution is for.
        block: BlockId,
        /// The co-member that kept the group out of memory.
        blocking: BlockId,
        cause: IneffectiveCause,
    },
    // --- failure / recovery points ------------------------------------
    WorkerKilled { worker: WorkerId },
    WorkerRevived { worker: WorkerId },
    // --- elastic topology (DESIGN.md §9) ------------------------------
    /// A pending worker slot came online at a quiescent point.
    WorkerJoined { worker: WorkerId },
    /// One peer group warm-migrated whole from `from` to `to` during a
    /// join (group-atomic: all `blocks` members moved in one pinned
    /// batch, memory tier or spill tier alike).
    GroupMigrated {
        group: GroupId,
        from: WorkerId,
        to: WorkerId,
        blocks: u64,
    },
    /// The autoscale policy decided to scale (`action` is "up" or
    /// "down") based on ready-queue depth and alive-fleet memory use.
    ScaleDecision {
        action: &'static str,
        worker: WorkerId,
        ready: u64,
        mem_used: u64,
    },
}

/// A field value for the exporters (flat: integers and short strings).
#[derive(Debug, Clone, PartialEq)]
pub enum Field {
    U64(u64),
    Str(String),
}

impl TraceEvent {
    /// Stable snake_case kind tag — the JSONL `kind` field and the
    /// logical-equivalence key prefix.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::TaskAdmitted { .. } => "task_admitted",
            TraceEvent::TaskReady { .. } => "task_ready",
            TraceEvent::TaskDispatched { .. } => "task_dispatched",
            TraceEvent::InputsPinned { .. } => "inputs_pinned",
            TraceEvent::TaskComputed { .. } => "task_computed",
            TraceEvent::TaskPublished { .. } => "task_published",
            TraceEvent::BlockInserted { .. } => "block_inserted",
            TraceEvent::BlockEvicted { .. } => "block_evicted",
            TraceEvent::BlockDemoted { .. } => "block_demoted",
            TraceEvent::BlockRestored { .. } => "block_restored",
            TraceEvent::BlockDropped { .. } => "block_dropped",
            TraceEvent::BlockInvalidated { .. } => "block_invalidated",
            TraceEvent::RecomputePlanned { .. } => "recompute_planned",
            TraceEvent::EvictionReported { .. } => "eviction_reported",
            TraceEvent::InvalidationBroadcast { .. } => "invalidation_broadcast",
            TraceEvent::CtrlDrained { .. } => "ctrl_drained",
            TraceEvent::IneffectiveHit { .. } => "ineffective_hit",
            TraceEvent::WorkerKilled { .. } => "worker_killed",
            TraceEvent::WorkerRevived { .. } => "worker_revived",
            TraceEvent::WorkerJoined { .. } => "worker_joined",
            TraceEvent::GroupMigrated { .. } => "group_migrated",
            TraceEvent::ScaleDecision { .. } => "scale_decision",
        }
    }

    /// Visit every field as `(name, value)` — the single source of truth
    /// both exporters serialize from.
    pub fn for_each_field(&self, f: &mut dyn FnMut(&'static str, Field)) {
        match self {
            TraceEvent::TaskAdmitted { job, task } => {
                f("job", Field::U64(job.0 as u64));
                f("task", Field::U64(task.0));
            }
            TraceEvent::TaskReady { task } => f("task", Field::U64(task.0)),
            TraceEvent::TaskDispatched { task, worker }
            | TraceEvent::InputsPinned { task, worker }
            | TraceEvent::TaskComputed { task, worker } => {
                f("task", Field::U64(task.0));
                f("worker", Field::U64(worker.0 as u64));
            }
            TraceEvent::TaskPublished { task, worker, block } => {
                f("task", Field::U64(task.0));
                f("worker", Field::U64(worker.0 as u64));
                f("block", Field::Str(block.to_string()));
            }
            TraceEvent::BlockInserted { block, worker }
            | TraceEvent::BlockEvicted { block, worker }
            | TraceEvent::BlockDemoted { block, worker }
            | TraceEvent::BlockRestored { block, worker }
            | TraceEvent::BlockDropped { block, worker }
            | TraceEvent::BlockInvalidated { block, worker } => {
                f("block", Field::Str(block.to_string()));
                f("worker", Field::U64(worker.0 as u64));
            }
            TraceEvent::RecomputePlanned { block, task } => {
                f("block", Field::Str(block.to_string()));
                f("task", Field::U64(task.0));
            }
            TraceEvent::EvictionReported { block }
            | TraceEvent::InvalidationBroadcast { block } => {
                f("block", Field::Str(block.to_string()));
            }
            TraceEvent::CtrlDrained { worker, applied } => {
                f("worker", Field::U64(worker.0 as u64));
                f("applied", Field::U64(*applied));
            }
            TraceEvent::IneffectiveHit {
                task,
                worker,
                block,
                blocking,
                cause,
            } => {
                f("task", Field::U64(task.0));
                f("worker", Field::U64(worker.0 as u64));
                f("block", Field::Str(block.to_string()));
                f("blocking", Field::Str(blocking.to_string()));
                f("cause", Field::Str(cause.as_str().to_string()));
            }
            TraceEvent::WorkerKilled { worker }
            | TraceEvent::WorkerRevived { worker }
            | TraceEvent::WorkerJoined { worker } => {
                f("worker", Field::U64(worker.0 as u64));
            }
            TraceEvent::GroupMigrated {
                group,
                from,
                to,
                blocks,
            } => {
                f("group", Field::U64(group.0));
                f("from", Field::U64(from.0 as u64));
                f("to", Field::U64(to.0 as u64));
                f("blocks", Field::U64(*blocks));
            }
            TraceEvent::ScaleDecision {
                action,
                worker,
                ready,
                mem_used,
            } => {
                f("action", Field::Str((*action).to_string()));
                f("worker", Field::U64(worker.0 as u64));
                f("ready", Field::U64(*ready));
                f("mem_used", Field::U64(*mem_used));
            }
        }
    }

    /// Timestamp-free identity: `kind` plus every field, used by the
    /// sim≡threaded equivalence test ("equal modulo timestamps").
    pub fn logical_key(&self) -> String {
        let mut key = String::from(self.kind());
        self.for_each_field(&mut |name, value| {
            key.push(' ');
            key.push_str(name);
            key.push('=');
            match value {
                Field::U64(v) => key.push_str(&v.to_string()),
                Field::Str(s) => key.push_str(&s),
            }
        });
        key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    #[test]
    fn kinds_are_stable_snake_case() {
        let ev = TraceEvent::TaskDispatched {
            task: TaskId(3),
            worker: WorkerId(1),
        };
        assert_eq!(ev.kind(), "task_dispatched");
    }

    #[test]
    fn logical_key_carries_every_field() {
        let ev = TraceEvent::IneffectiveHit {
            task: TaskId(7),
            worker: WorkerId(0),
            block: BlockId::new(DatasetId(2), 4),
            blocking: BlockId::new(DatasetId(1), 4),
            cause: IneffectiveCause::Evicted,
        };
        assert_eq!(
            ev.logical_key(),
            "ineffective_hit task=7 worker=0 block=D2[4] blocking=D1[4] cause=evicted"
        );
    }

    #[test]
    fn topology_kinds_and_keys() {
        use crate::common::ids::GroupId;
        let joined = TraceEvent::WorkerJoined { worker: WorkerId(5) };
        assert_eq!(joined.kind(), "worker_joined");
        assert_eq!(joined.logical_key(), "worker_joined worker=5");
        let mig = TraceEvent::GroupMigrated {
            group: GroupId(3),
            from: WorkerId(0),
            to: WorkerId(5),
            blocks: 2,
        };
        assert_eq!(mig.kind(), "group_migrated");
        assert_eq!(mig.logical_key(), "group_migrated group=3 from=0 to=5 blocks=2");
        let scale = TraceEvent::ScaleDecision {
            action: "up",
            worker: WorkerId(4),
            ready: 9,
            mem_used: 4096,
        };
        assert_eq!(scale.kind(), "scale_decision");
        assert_eq!(
            scale.logical_key(),
            "scale_decision action=up worker=4 ready=9 mem_used=4096"
        );
    }
}
