//! Trace exporters: JSONL event log and Chrome trace-event JSON
//! (Perfetto-loadable), both hand-rolled — the offline build has no
//! serde. One [`TraceSink`] trait so the CLI and the bench drive either
//! through the same call.

use crate::common::ids::TaskId;
use crate::metrics::Timeline;
use crate::trace::event::{Field, TraceEvent};
use crate::trace::{ClockDomain, Rec};
use std::collections::BTreeMap;
use std::io::{self, Write};

/// Run-level header both exporters embed.
#[derive(Debug, Clone)]
pub struct TraceMeta {
    /// `"sim"` or `"threaded"`.
    pub engine: String,
    pub clock: ClockDomain,
    pub workers: u32,
    /// Ring-overflow drops (events missing from the log).
    pub dropped: u64,
}

pub trait TraceSink {
    fn export(&mut self, meta: &TraceMeta, events: &[Rec]) -> io::Result<()>;
}

/// Escape a string for a JSON literal (our payloads are `D3[7]`-style,
/// but the exporter must never emit invalid JSON regardless).
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn push_fields(line: &mut String, event: &TraceEvent) {
    event.for_each_field(&mut |name, value| {
        line.push_str(",\"");
        line.push_str(name);
        line.push_str("\":");
        match value {
            Field::U64(v) => line.push_str(&v.to_string()),
            Field::Str(s) => {
                line.push('"');
                line.push_str(&esc(&s));
                line.push('"');
            }
        }
    });
}

/// One flat JSON object per line; the first line is a `trace_meta`
/// record (`tools/trace_report.py` validates this shape in CI).
pub struct JsonlSink<W: Write> {
    w: W,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(w: W) -> Self {
        Self { w }
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

impl<W: Write> TraceSink for JsonlSink<W> {
    fn export(&mut self, meta: &TraceMeta, events: &[Rec]) -> io::Result<()> {
        writeln!(
            self.w,
            "{{\"kind\":\"trace_meta\",\"schema\":1,\"engine\":\"{}\",\"clock\":\"{}\",\
             \"workers\":{},\"dropped\":{},\"events\":{}}}",
            esc(&meta.engine),
            meta.clock.as_str(),
            meta.workers,
            meta.dropped,
            events.len()
        )?;
        let mut line = String::new();
        for r in events {
            line.clear();
            line.push_str("{\"kind\":\"");
            line.push_str(r.event.kind());
            line.push_str("\",\"ts\":");
            line.push_str(&r.ts.to_string());
            line.push_str(",\"seq\":");
            line.push_str(&r.seq.to_string());
            line.push_str(",\"track\":");
            line.push_str(&r.track.to_string());
            push_fields(&mut line, &r.event);
            line.push('}');
            writeln!(self.w, "{line}")?;
        }
        self.w.flush()
    }
}

/// Chrome trace-event JSON (the array form): one track per worker plus
/// a driver track, "X" spans for the task phases fetch → compute →
/// publish, "i" instants for cache/ctrl/failure actions, and — when a
/// [`Timeline`] is attached — "C" counter tracks for the continuous
/// telemetry series (DESIGN.md §10). Load it at ui.perfetto.dev or
/// chrome://tracing.
pub struct ChromeSink<W: Write> {
    w: W,
    timeline: Option<Timeline>,
}

impl<W: Write> ChromeSink<W> {
    pub fn new(w: W) -> Self {
        Self { w, timeline: None }
    }

    /// Attach the run's telemetry timeline: counter tracks (ready-queue
    /// depth, tier occupancy, windowed effective-hit ratio, per-worker
    /// busy fraction, fair-share flows) ride next to the task spans on
    /// the same clock. Empty timelines are ignored.
    pub fn with_timeline(mut self, timeline: &Timeline) -> Self {
        if !timeline.is_empty() {
            self.timeline = Some(timeline.clone());
        }
        self
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

fn us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1000.0)
}

#[derive(Default)]
struct TaskTimes {
    dispatched: Option<u64>,
    pinned: Option<(u64, u32)>,
    computed: Option<(u64, u32)>,
    published: Option<(u64, u32)>,
}

impl<W: Write> TraceSink for ChromeSink<W> {
    fn export(&mut self, meta: &TraceMeta, events: &[Rec]) -> io::Result<()> {
        let mut first = true;
        let mut emit = |w: &mut W, obj: String| -> io::Result<()> {
            if first {
                first = false;
                write!(w, "[\n{obj}")
            } else {
                write!(w, ",\n{obj}")
            }
        };
        // Track names: 0 = driver, 1+w = worker w.
        emit(
            &mut self.w,
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"tid\":0,\
                 \"args\":{{\"name\":\"lerc {} ({} clock)\"}}}}",
                esc(&meta.engine),
                meta.clock.as_str()
            ),
        )?;
        for track in 0..=meta.workers as usize {
            let name = if track == 0 {
                "driver".to_string()
            } else {
                format!("worker-{}", track - 1)
            };
            emit(
                &mut self.w,
                format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{track},\
                     \"args\":{{\"name\":\"{name}\"}}}}"
                ),
            )?;
        }

        // Phase spans need each task's lifecycle timestamps.
        let mut tasks: BTreeMap<TaskId, TaskTimes> = BTreeMap::new();
        for r in events {
            match r.event {
                TraceEvent::TaskDispatched { task, .. } => {
                    tasks.entry(task).or_default().dispatched = Some(r.ts);
                }
                TraceEvent::InputsPinned { task, .. } => {
                    tasks.entry(task).or_default().pinned = Some((r.ts, r.track));
                }
                TraceEvent::TaskComputed { task, .. } => {
                    tasks.entry(task).or_default().computed = Some((r.ts, r.track));
                }
                TraceEvent::TaskPublished { task, .. } => {
                    tasks.entry(task).or_default().published = Some((r.ts, r.track));
                }
                _ => {}
            }
        }
        for (task, t) in &tasks {
            let mut span = |w: &mut W,
                            phase: &str,
                            start: u64,
                            end: u64,
                            tid: u32|
             -> io::Result<()> {
                emit(
                    w,
                    format!(
                        "{{\"name\":\"{task} {phase}\",\"cat\":\"task\",\"ph\":\"X\",\
                         \"ts\":{},\"dur\":{},\"pid\":0,\"tid\":{tid},\
                         \"args\":{{\"task\":{}}}}}",
                        us(start),
                        us(end.saturating_sub(start)),
                        task.0
                    ),
                )
            };
            if let (Some(d), Some((p, tid))) = (t.dispatched, t.pinned) {
                span(&mut self.w, "fetch", d, p, tid)?;
            }
            if let (Some((p, _)), Some((c, tid))) = (t.pinned, t.computed) {
                span(&mut self.w, "compute", p, c, tid)?;
            }
            if let (Some((c, _)), Some((pb, tid))) = (t.computed, t.published) {
                span(&mut self.w, "publish", c, pb, tid)?;
            }
        }

        // Instants for cache, control-plane, attribution, and failure
        // events ("s":"t": thread-scoped).
        for r in events {
            let instant = matches!(
                r.event,
                TraceEvent::BlockInserted { .. }
                    | TraceEvent::BlockEvicted { .. }
                    | TraceEvent::BlockDemoted { .. }
                    | TraceEvent::BlockRestored { .. }
                    | TraceEvent::BlockDropped { .. }
                    | TraceEvent::BlockInvalidated { .. }
                    | TraceEvent::RecomputePlanned { .. }
                    | TraceEvent::EvictionReported { .. }
                    | TraceEvent::InvalidationBroadcast { .. }
                    | TraceEvent::CtrlDrained { .. }
                    | TraceEvent::IneffectiveHit { .. }
                    | TraceEvent::WorkerKilled { .. }
                    | TraceEvent::WorkerRevived { .. }
            );
            if !instant {
                continue;
            }
            let mut args = String::new();
            r.event.for_each_field(&mut |name, value| {
                if !args.is_empty() {
                    args.push(',');
                }
                args.push('"');
                args.push_str(name);
                args.push_str("\":");
                match value {
                    Field::U64(v) => args.push_str(&v.to_string()),
                    Field::Str(s) => {
                        args.push('"');
                        args.push_str(&esc(&s));
                        args.push('"');
                    }
                }
            });
            emit(
                &mut self.w,
                format!(
                    "{{\"name\":\"{}\",\"cat\":\"cache\",\"ph\":\"i\",\"s\":\"t\",\
                     \"ts\":{},\"pid\":0,\"tid\":{},\"args\":{{{args}}}}}",
                    r.event.kind(),
                    us(r.ts),
                    r.track
                ),
            )?;
        }
        // Counter tracks from the attached timeline ("C" phase: one
        // counter series per name, args carry the value). Perfetto draws
        // them as stacked area charts alongside the spans.
        if let Some(tl) = self.timeline.clone() {
            let ratios = tl.window_effective_ratios();
            let slots = tl.worker_slots();
            for (i, s) in tl.samples.iter().enumerate() {
                let ts = us(s.ts);
                let mut counter = |w: &mut W, name: &str, args: String| -> io::Result<()> {
                    emit(
                        w,
                        format!(
                            "{{\"name\":\"{name}\",\"cat\":\"timeline\",\"ph\":\"C\",\
                             \"ts\":{ts},\"pid\":0,\"args\":{{{args}}}}}"
                        ),
                    )
                };
                counter(
                    &mut self.w,
                    "ready_depth",
                    format!("\"ready\":{}", s.ready_depth),
                )?;
                counter(
                    &mut self.w,
                    "cache_bytes",
                    format!("\"mem\":{},\"spill\":{}", s.mem_bytes, s.spill_bytes),
                )?;
                counter(
                    &mut self.w,
                    "effective_hit_ratio",
                    format!("\"window\":{:.4}", ratios[i]),
                )?;
                if s.net_flows > 0 || s.net_bytes > 0 {
                    counter(
                        &mut self.w,
                        "net_flows",
                        format!("\"in_flight\":{}", s.net_flows),
                    )?;
                }
                let prev = if i == 0 { None } else { tl.samples.get(i - 1) };
                for w in 0..slots {
                    let frac = match prev {
                        Some(p) => s.window_busy_fraction(p, w),
                        None => 0.0,
                    };
                    counter(
                        &mut self.w,
                        &format!("busy_w{w}"),
                        format!("\"busy\":{frac:.4}"),
                    )?;
                }
            }
        }
        writeln!(self.w, "\n]")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{BlockId, DatasetId, JobId, WorkerId};

    fn sample() -> (TraceMeta, Vec<Rec>) {
        let meta = TraceMeta {
            engine: "sim".into(),
            clock: ClockDomain::Logical,
            workers: 1,
            dropped: 0,
        };
        let b = BlockId::new(DatasetId(0), 0);
        let mk = |ts, seq, track, event| Rec {
            ts,
            seq,
            track,
            event,
        };
        let events = vec![
            mk(0, 0, 0, TraceEvent::TaskAdmitted { job: JobId(0), task: TaskId(1) }),
            mk(1, 1, 0, TraceEvent::TaskReady { task: TaskId(1) }),
            mk(2, 2, 0, TraceEvent::TaskDispatched { task: TaskId(1), worker: WorkerId(0) }),
            mk(3, 3, 1, TraceEvent::InputsPinned { task: TaskId(1), worker: WorkerId(0) }),
            mk(5, 4, 1, TraceEvent::TaskComputed { task: TaskId(1), worker: WorkerId(0) }),
            mk(6, 5, 1, TraceEvent::BlockInserted { block: b, worker: WorkerId(0) }),
            mk(6, 6, 1, TraceEvent::TaskPublished {
                task: TaskId(1),
                worker: WorkerId(0),
                block: b,
            }),
        ];
        (meta, events)
    }

    #[test]
    fn jsonl_meta_first_then_one_line_per_event() {
        let (meta, events) = sample();
        let mut sink = JsonlSink::new(Vec::new());
        sink.export(&meta, &events).unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 1 + events.len());
        assert!(lines[0].contains("\"kind\":\"trace_meta\""));
        assert!(lines[0].contains("\"events\":7"));
        assert!(lines[1].contains("\"kind\":\"task_admitted\""));
        assert!(lines[1].contains("\"job\":0"));
        assert!(lines[7].contains("\"block\":\"D0[0]\""));
        for l in &lines {
            assert!(l.starts_with('{') && l.ends_with('}'), "not flat JSON: {l}");
        }
    }

    #[test]
    fn chrome_export_is_an_array_with_spans_and_metadata() {
        let (meta, events) = sample();
        let mut sink = ChromeSink::new(Vec::new());
        sink.export(&meta, &events).unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        assert!(out.trim_start().starts_with('['));
        assert!(out.trim_end().ends_with(']'));
        assert!(out.contains("\"thread_name\""));
        assert!(out.contains("\"name\":\"worker-0\""));
        assert!(out.contains("\"T1 fetch\""));
        assert!(out.contains("\"T1 compute\""));
        assert!(out.contains("\"T1 publish\""));
        assert!(out.contains("\"ph\":\"i\"")); // block_inserted instant
        // Balanced braces: crude structural sanity without a parser.
        assert_eq!(out.matches('{').count(), out.matches('}').count());
    }

    #[test]
    fn chrome_counters_ride_the_timeline() {
        use crate::metrics::{Timeline, TimelineSample};
        let (meta, events) = sample();
        let mut tl = Timeline::new(4);
        tl.push(TimelineSample {
            ts: 1_000,
            dispatched: 4,
            ready_depth: 2,
            accesses: 4,
            effective_hits: 2,
            mem_bytes: 8192,
            worker_busy: vec![100],
            ..Default::default()
        });
        tl.push(TimelineSample {
            ts: 2_000,
            dispatched: 8,
            ready_depth: 0,
            accesses: 8,
            effective_hits: 6,
            mem_bytes: 4096,
            worker_busy: vec![900],
            ..Default::default()
        });
        let mut sink = ChromeSink::new(Vec::new()).with_timeline(&tl);
        sink.export(&meta, &events).unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        assert!(out.contains("\"ph\":\"C\""));
        assert!(out.contains("\"name\":\"ready_depth\""));
        assert!(out.contains("\"name\":\"cache_bytes\""));
        assert!(out.contains("\"name\":\"effective_hit_ratio\""));
        assert!(out.contains("\"name\":\"busy_w0\""));
        // Window 2 effective ratio (6-2)/(8-4) and busy 800ns/1000ns.
        assert!(out.contains("\"window\":1.0000"));
        assert!(out.contains("\"busy\":0.8000"));
        assert_eq!(out.matches('{').count(), out.matches('}').count());
    }

    #[test]
    fn chrome_without_timeline_has_no_counters() {
        let (meta, events) = sample();
        let mut sink = ChromeSink::new(Vec::new());
        sink.export(&meta, &events).unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        assert!(!out.contains("\"ph\":\"C\""));
    }

    #[test]
    fn strings_are_escaped() {
        assert_eq!(esc("a\"b\\c\n"), "a\\\"b\\\\c\\n");
    }
}
