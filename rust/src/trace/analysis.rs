//! Critical-path analyzer (DESIGN.md §10): from effective hit *ratio*
//! to effective hit *time*.
//!
//! Reconstructs each job's dependency-and-resource critical path from
//! the recorded [`TraceEvent`] stream and decomposes its JCT into
//! segments as an **exact identity**: Σ segment nanos == completed −
//! admitted, per job, on both engines. The walk is backward from the
//! job's last-published task: each node's predecessor is the candidate
//! task (same job, or a lineage-recompute task) whose publish most
//! recently preceded the node becoming ready — on the deterministic
//! simulator that publish *is* the readiness edge, so repeats produce
//! identical node sequences; on the threaded engine wall timestamps
//! jitter, so agreement is asserted structurally (the identity and the
//! segment taxonomy), not on exact times.
//!
//! Segment taxonomy (each span carries the task it belongs to):
//!
//! * `sched`      — inter-node gap: predecessor publish → node ready
//!   (dependency release + scheduler latency),
//! * `migration`  — a `sched` gap that contains a topology quiescent
//!   point (`worker_joined` / `group_migrated`),
//! * `queue`      — ready → dispatch (the queue-wait histogramed per
//!   job since PR 8, here placed on the path),
//! * `fetch_mem`  — dispatch → inputs-pinned with the peer group
//!   wholly in memory (an *effective* hit, per Def. 1),
//! * `fetch_<cause>` — dispatch → inputs-pinned on a broken group,
//!   keyed by the first `IneffectiveCause` observed for the task
//!   (`fetch_evicted`, `fetch_spilled`, …),
//! * `compute`    — inputs-pinned → computed,
//! * `publish`    — computed → published,
//! * `recompute`  — a lineage-recompute node's whole ready → publish
//!   span (recovery work on the path, kept as one opaque span).
//!
//! **Cache benefit accounting** is the time-domain `top_blocking`: for
//! every critical-path fetch on a broken group, the fetch-segment nanos
//! are charged to each distinct blocking block implicated by the
//! task's `ineffective_hit` attributions. Charges are *implicated
//! time* — two blocks breaking the same fetch each get the full span —
//! so they rank blocks by potential savings rather than partitioning
//! the makespan.

use crate::trace::event::TraceEvent;
use crate::trace::sink::esc;
use crate::trace::summary::parse_flat_json;
use crate::trace::Rec;

use std::collections::{BTreeMap, BTreeSet};

/// One critical-path span. `start`/`end` are nanos in the run's trace
/// clock domain (sim logical / threaded wall), clamped monotone so the
/// per-job telescoping identity holds exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    /// Taxonomy tag (`sched`, `migration`, `queue`, `fetch_mem`,
    /// `fetch_<cause>`, `compute`, `publish`, `recompute`).
    pub kind: String,
    /// Task the span belongs to; `None` for inter-node gaps.
    pub task: Option<u64>,
    pub start: u64,
    pub end: u64,
}

impl Segment {
    pub fn nanos(&self) -> u64 {
        self.end - self.start
    }
}

/// One job's reconstructed critical path.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct JobPath {
    pub job: u32,
    /// `task_admitted` timestamp (== the report's JCT origin on the
    /// simulator).
    pub admitted: u64,
    /// Last `task_published` timestamp of the job's own tasks.
    pub completed: u64,
    /// Critical-path task ids, source → terminal.
    pub nodes: Vec<u64>,
    /// Tiling of `[admitted, completed]`: contiguous, monotone, exact.
    pub segments: Vec<Segment>,
    /// Blocking block → critical-path fetch nanos implicated by it.
    pub benefit: BTreeMap<String, u64>,
}

impl JobPath {
    pub fn jct(&self) -> u64 {
        self.completed - self.admitted
    }

    /// Σ segments — the identity partner of [`Self::jct`].
    pub fn segment_total(&self) -> u64 {
        self.segments.iter().map(Segment::nanos).sum()
    }

    /// Segment nanos aggregated by kind.
    pub fn by_kind(&self) -> BTreeMap<String, u64> {
        let mut out = BTreeMap::new();
        for s in &self.segments {
            *out.entry(s.kind.clone()).or_insert(0) += s.nanos();
        }
        out
    }

    /// Nanos matching a kind prefix (e.g. `"fetch"` sums `fetch_mem`
    /// and every `fetch_<cause>`).
    pub fn kind_prefix_total(&self, prefix: &str) -> u64 {
        self.segments
            .iter()
            .filter(|s| s.kind.starts_with(prefix))
            .map(Segment::nanos)
            .sum()
    }
}

/// Per-task lifecycle timestamps gathered in the first pass.
#[derive(Debug, Clone, Default)]
struct TaskTimes {
    ready: Option<u64>,
    dispatched: Option<u64>,
    pinned: Option<u64>,
    computed: Option<u64>,
    published: Option<u64>,
    /// Distinct (blocking block, cause) pairs from `ineffective_hit`.
    blocking: Vec<(String, String)>,
}

/// Event collector shared by the typed ([`CriticalPathAnalysis::from_events`])
/// and JSONL ([`CriticalPathAnalysis::from_jsonl`]) front ends.
#[derive(Debug, Default)]
struct Collector {
    tasks: BTreeMap<u64, TaskTimes>,
    /// task → job, from `task_admitted`.
    job_of: BTreeMap<u64, u32>,
    /// job → first `task_admitted` timestamp.
    job_admitted: BTreeMap<u32, u64>,
    /// Lineage-recompute tasks (`recompute_planned`), members of every
    /// job's predecessor candidate set.
    recompute: BTreeSet<u64>,
    /// Topology quiescent points (`worker_joined` / `group_migrated`).
    migration_marks: Vec<u64>,
}

impl Collector {
    fn task(&mut self, id: u64) -> &mut TaskTimes {
        self.tasks.entry(id).or_default()
    }

    fn admitted(&mut self, job: u32, task: u64, ts: u64) {
        self.job_of.insert(task, job);
        let slot = self.job_admitted.entry(job).or_insert(ts);
        *slot = (*slot).min(ts);
    }

    fn ineffective(&mut self, task: u64, blocking: String, cause: String) {
        let t = self.task(task);
        if !t.blocking.iter().any(|(b, _)| *b == blocking) {
            t.blocking.push((blocking, cause));
        }
    }

    fn finish(self) -> CriticalPathAnalysis {
        let mut jobs = Vec::new();
        let job_ids: BTreeSet<u32> = self.job_admitted.keys().copied().collect();
        for job in job_ids {
            if let Some(path) = self.job_path(job) {
                jobs.push(path);
            }
        }
        CriticalPathAnalysis { jobs }
    }

    /// Backward walk + forward tiling for one job; `None` if no task of
    /// the job ever published (the job never completed in the trace).
    fn job_path(&self, job: u32) -> Option<JobPath> {
        let admitted = *self.job_admitted.get(&job)?;
        // Predecessor candidates: the job's own tasks plus recompute
        // tasks (lineage repairs gate readiness across job boundaries).
        let mine = |t: &u64| {
            self.job_of.get(t) == Some(&job) || self.recompute.contains(t)
        };
        // Terminal node: the job's own last-published task, ties broken
        // by task id so the walk is deterministic.
        let (terminal, completed) = self
            .tasks
            .iter()
            .filter(|(t, _)| self.job_of.get(*t) == Some(&job))
            .filter_map(|(t, tt)| tt.published.map(|p| (*t, p)))
            .max_by_key(|&(t, p)| (p, t))?;

        let mut nodes = vec![terminal];
        let mut visited: BTreeSet<u64> = [terminal].into();
        let mut cur = terminal;
        loop {
            let tt = &self.tasks[&cur];
            // The readiness edge: the publish that released this node.
            let Some(ready) = tt.ready.or(tt.dispatched) else { break };
            let pred = self
                .tasks
                .iter()
                .filter(|(t, _)| mine(t) && !visited.contains(*t))
                .filter_map(|(t, tt)| tt.published.map(|p| (*t, p)))
                .filter(|&(_, p)| p <= ready)
                .max_by_key(|&(t, p)| (p, t));
            match pred {
                Some((t, _)) => {
                    visited.insert(t);
                    nodes.push(t);
                    cur = t;
                }
                None => break,
            }
        }
        nodes.reverse();

        // Forward tiling: clamp every boundary into [cursor, completed]
        // so the segments telescope to exactly completed - admitted.
        let mut segments = Vec::new();
        let mut benefit: BTreeMap<String, u64> = BTreeMap::new();
        let mut cursor = admitted;
        let push = |segments: &mut Vec<Segment>,
                    cursor: &mut u64,
                    kind: String,
                    task: Option<u64>,
                    raw_end: Option<u64>| {
            let end = raw_end.unwrap_or(*cursor).clamp(*cursor, completed);
            if end > *cursor {
                segments.push(Segment {
                    kind,
                    task,
                    start: *cursor,
                    end,
                });
                *cursor = end;
            }
        };
        for &t in &nodes {
            let tt = &self.tasks[&t];
            // Gap up to readiness: scheduler/dependency release, or a
            // topology pause if a quiescent point landed inside it.
            let ready = tt.ready.or(tt.dispatched);
            let gap_end = ready.unwrap_or(cursor).clamp(cursor, completed);
            let gap_kind = if self
                .migration_marks
                .iter()
                .any(|&m| m > cursor && m <= gap_end)
            {
                "migration"
            } else {
                "sched"
            };
            push(&mut segments, &mut cursor, gap_kind.into(), None, ready);
            if self.recompute.contains(&t) {
                // Recovery work stays one opaque span on the path.
                push(&mut segments, &mut cursor, "recompute".into(), Some(t), tt.published);
                continue;
            }
            push(&mut segments, &mut cursor, "queue".into(), Some(t), tt.dispatched);
            let fetch_kind = match tt.blocking.first() {
                Some((_, cause)) => format!("fetch_{cause}"),
                None => "fetch_mem".into(),
            };
            let fetch_start = cursor;
            push(&mut segments, &mut cursor, fetch_kind, Some(t), tt.pinned);
            let fetch_nanos = cursor - fetch_start;
            if fetch_nanos > 0 {
                for (block, _) in &tt.blocking {
                    *benefit.entry(block.clone()).or_insert(0) += fetch_nanos;
                }
            }
            push(&mut segments, &mut cursor, "compute".into(), Some(t), tt.computed);
            push(&mut segments, &mut cursor, "publish".into(), Some(t), tt.published);
        }
        // Trailing slack (clock skew on the threaded engine can leave
        // the terminal publish short of `completed` after clamping).
        push(&mut segments, &mut cursor, "sched".into(), None, Some(completed));

        Some(JobPath {
            job,
            admitted,
            completed,
            nodes,
            segments,
            benefit,
        })
    }
}

/// The analyzer's output: one [`JobPath`] per completed job, sorted by
/// job id.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CriticalPathAnalysis {
    pub jobs: Vec<JobPath>,
}

impl CriticalPathAnalysis {
    /// Analyze an in-memory recorder drain (`TraceRecorder::take`).
    pub fn from_events(events: &[Rec]) -> Self {
        let mut c = Collector::default();
        for rec in events {
            let ts = rec.ts;
            match &rec.event {
                TraceEvent::TaskAdmitted { job, task } => c.admitted(job.0, task.0, ts),
                TraceEvent::TaskReady { task } => c.task(task.0).ready = Some(ts),
                TraceEvent::TaskDispatched { task, .. } => {
                    c.task(task.0).dispatched = Some(ts)
                }
                TraceEvent::InputsPinned { task, .. } => c.task(task.0).pinned = Some(ts),
                TraceEvent::TaskComputed { task, .. } => c.task(task.0).computed = Some(ts),
                TraceEvent::TaskPublished { task, .. } => {
                    c.task(task.0).published = Some(ts)
                }
                TraceEvent::RecomputePlanned { task, .. } => {
                    c.recompute.insert(task.0);
                }
                TraceEvent::IneffectiveHit {
                    task,
                    blocking,
                    cause,
                    ..
                } => c.ineffective(task.0, blocking.to_string(), cause.as_str().to_string()),
                TraceEvent::WorkerJoined { .. } | TraceEvent::GroupMigrated { .. } => {
                    c.migration_marks.push(ts)
                }
                _ => {}
            }
        }
        c.finish()
    }

    /// Analyze a JSONL trace written by `JsonlSink` (the `lerc analyze
    /// --trace FILE` path). Unknown kinds and malformed lines are
    /// skipped, mirroring `TraceSummary`.
    pub fn from_jsonl(text: &str) -> Self {
        let mut c = Collector::default();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Some(obj) = parse_flat_json(line) else { continue };
            let num = |k: &str| obj.get(k).and_then(|v| v.parse::<u64>().ok());
            let (Some(kind), Some(ts)) = (obj.get("kind"), num("ts")) else { continue };
            match (kind.as_str(), num("task")) {
                ("task_admitted", Some(t)) => {
                    if let Some(j) = num("job") {
                        c.admitted(j as u32, t, ts);
                    }
                }
                ("task_ready", Some(t)) => c.task(t).ready = Some(ts),
                ("task_dispatched", Some(t)) => c.task(t).dispatched = Some(ts),
                ("inputs_pinned", Some(t)) => c.task(t).pinned = Some(ts),
                ("task_computed", Some(t)) => c.task(t).computed = Some(ts),
                ("task_published", Some(t)) => c.task(t).published = Some(ts),
                ("recompute_planned", Some(t)) => {
                    c.recompute.insert(t);
                }
                ("ineffective_hit", Some(t)) => {
                    if let (Some(b), Some(cause)) = (obj.get("blocking"), obj.get("cause")) {
                        c.ineffective(t, b.clone(), cause.clone());
                    }
                }
                ("worker_joined", _) | ("group_migrated", _) => {
                    c.migration_marks.push(ts)
                }
                _ => {}
            }
        }
        c.finish()
    }

    /// Top-k blocking blocks by implicated critical-path fetch nanos,
    /// across every job — the time-domain `top_blocking`.
    pub fn top_benefit(&self, k: usize) -> Vec<(String, u64)> {
        let mut merged: BTreeMap<&str, u64> = BTreeMap::new();
        for j in &self.jobs {
            for (b, n) in &j.benefit {
                *merged.entry(b).or_insert(0) += n;
            }
        }
        let mut v: Vec<(String, u64)> =
            merged.into_iter().map(|(b, n)| (b.to_string(), n)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// True iff every job's tiling telescopes exactly (the Σ-segments
    /// identity the tests pin on both engines).
    pub fn identity_holds(&self) -> bool {
        self.jobs.iter().all(|j| j.segment_total() == j.jct())
    }

    /// Markdown decomposition table + top-benefit blocks (the `lerc
    /// analyze` body).
    pub fn render(&self) -> String {
        use crate::metrics::hist::fmt_nanos;
        let mut out = String::new();
        out.push_str("## Critical-path decomposition (Σ segments == JCT)\n\n");
        out.push_str(
            "| job | nodes | sched | migration | queue | fetch | compute | publish | recompute | JCT |\n",
        );
        out.push_str("|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|\n");
        for j in &self.jobs {
            let k = j.by_kind();
            let get = |name: &str| k.get(name).copied().unwrap_or(0);
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
                j.job,
                j.nodes.len(),
                fmt_nanos(get("sched")),
                fmt_nanos(get("migration")),
                fmt_nanos(get("queue")),
                fmt_nanos(j.kind_prefix_total("fetch")),
                fmt_nanos(get("compute")),
                fmt_nanos(get("publish")),
                fmt_nanos(get("recompute")),
                fmt_nanos(j.jct()),
            ));
        }
        let top = self.top_benefit(10);
        if !top.is_empty() {
            out.push_str("\n## Top blocking blocks by critical-path fetch time\n\n");
            out.push_str("| block | implicated time |\n|---|---:|\n");
            for (b, n) in top {
                out.push_str(&format!("| {b} | {} |\n", fmt_nanos(n)));
            }
        }
        out
    }

    /// Hand-rolled JSON export (the CI decomposition artifact).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"schema\":1,\"jobs\":[");
        for (i, j) in self.jobs.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"job\":{},\"admitted\":{},\"completed\":{},\"jct\":{},\"nodes\":[",
                j.job,
                j.admitted,
                j.completed,
                j.jct()
            ));
            for (k, n) in j.nodes.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                out.push_str(&n.to_string());
            }
            out.push_str("],\"segments\":[");
            for (k, s) in j.segments.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                match s.task {
                    Some(t) => out.push_str(&format!(
                        "{{\"kind\":\"{}\",\"task\":{t},\"start\":{},\"end\":{}}}",
                        esc(&s.kind),
                        s.start,
                        s.end
                    )),
                    None => out.push_str(&format!(
                        "{{\"kind\":\"{}\",\"start\":{},\"end\":{}}}",
                        esc(&s.kind),
                        s.start,
                        s.end
                    )),
                }
            }
            out.push_str("],\"benefit\":{");
            for (k, (b, n)) in j.benefit.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                out.push_str(&format!("\"{}\":{n}", esc(b)));
            }
            out.push_str("}}");
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{BlockId, DatasetId, JobId, TaskId, WorkerId};
    use crate::metrics::attribution::IneffectiveCause;
    use crate::trace::{ClockDomain, TraceRecorder};

    /// Build a two-task chain by hand: admitted@0, t1 ready@10
    /// dispatched@15 pinned@40 computed@70 published@80, t2 (gated on
    /// t1) ready@80 dispatched@85 pinned@90 computed@120 published@130.
    fn chain_recorder() -> Vec<Rec> {
        let rec = TraceRecorder::new(1024);
        rec.begin(2, ClockDomain::Logical);
        let b = BlockId::new(DatasetId(1), 0);
        let blocking = BlockId::new(DatasetId(2), 0);
        let w = WorkerId(0);
        let evs: Vec<(u64, TraceEvent)> = vec![
            (0, TraceEvent::TaskAdmitted { job: JobId(0), task: TaskId(1) }),
            (0, TraceEvent::TaskAdmitted { job: JobId(0), task: TaskId(2) }),
            (10, TraceEvent::TaskReady { task: TaskId(1) }),
            (15, TraceEvent::TaskDispatched { task: TaskId(1), worker: w }),
            (
                20,
                TraceEvent::IneffectiveHit {
                    task: TaskId(1),
                    worker: w,
                    block: b,
                    blocking,
                    cause: IneffectiveCause::Evicted,
                },
            ),
            (40, TraceEvent::InputsPinned { task: TaskId(1), worker: w }),
            (70, TraceEvent::TaskComputed { task: TaskId(1), worker: w }),
            (80, TraceEvent::TaskPublished { task: TaskId(1), worker: w, block: b }),
            (80, TraceEvent::TaskReady { task: TaskId(2) }),
            (85, TraceEvent::TaskDispatched { task: TaskId(2), worker: w }),
            (90, TraceEvent::InputsPinned { task: TaskId(2), worker: w }),
            (120, TraceEvent::TaskComputed { task: TaskId(2), worker: w }),
            (130, TraceEvent::TaskPublished { task: TaskId(2), worker: w, block: b }),
        ];
        for (ts, ev) in evs {
            rec.emit(0, Some(ts), ev);
        }
        rec.take()
    }

    #[test]
    fn chain_decomposes_exactly() {
        let a = CriticalPathAnalysis::from_events(&chain_recorder());
        assert_eq!(a.jobs.len(), 1);
        let j = &a.jobs[0];
        assert_eq!(j.nodes, vec![1, 2]);
        assert_eq!(j.jct(), 130);
        assert_eq!(j.segment_total(), j.jct());
        assert!(a.identity_holds());
        let k = j.by_kind();
        // t1: sched 10, queue 5, fetch_evicted 25, compute 30, publish
        // 10; t2: queue 5, fetch_mem 5, compute 30, publish 10.
        assert_eq!(k["sched"], 10);
        assert_eq!(k["queue"], 10);
        assert_eq!(k["fetch_evicted"], 25);
        assert_eq!(k["fetch_mem"], 5);
        assert_eq!(k["compute"], 60);
        assert_eq!(k["publish"], 20);
        // The broken fetch charges its 25ns to the blocking block.
        assert_eq!(j.benefit["D2[0]"], 25);
        assert_eq!(a.top_benefit(5), vec![("D2[0]".to_string(), 25)]);
    }

    #[test]
    fn jsonl_front_end_agrees_with_typed() {
        use crate::trace::sink::{JsonlSink, TraceMeta, TraceSink};
        let events = chain_recorder();
        let typed = CriticalPathAnalysis::from_events(&events);
        let meta = TraceMeta {
            engine: "sim".into(),
            clock: ClockDomain::Logical,
            workers: 1,
            dropped: 0,
        };
        let mut sink = JsonlSink::new(Vec::new());
        sink.export(&meta, &events).unwrap();
        let text = String::from_utf8(sink.into_inner()).unwrap();
        let parsed = CriticalPathAnalysis::from_jsonl(&text);
        assert_eq!(parsed, typed);
    }

    #[test]
    fn render_and_json_carry_the_table() {
        let a = CriticalPathAnalysis::from_events(&chain_recorder());
        let md = a.render();
        assert!(md.contains("| job |"));
        assert!(md.contains("Top blocking blocks"));
        let json = a.to_json();
        assert!(json.starts_with("{\"schema\":1"));
        assert!(json.contains("\"benefit\":{\"D2[0]\":25}"));
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn migration_mark_renames_the_gap() {
        let rec = TraceRecorder::new(64);
        rec.begin(2, ClockDomain::Logical);
        let b = BlockId::new(DatasetId(1), 0);
        let w = WorkerId(0);
        let evs: Vec<(u64, TraceEvent)> = vec![
            (0, TraceEvent::TaskAdmitted { job: JobId(3), task: TaskId(9) }),
            (5, TraceEvent::WorkerJoined { worker: WorkerId(1) }),
            (20, TraceEvent::TaskReady { task: TaskId(9) }),
            (20, TraceEvent::TaskDispatched { task: TaskId(9), worker: w }),
            (20, TraceEvent::InputsPinned { task: TaskId(9), worker: w }),
            (30, TraceEvent::TaskComputed { task: TaskId(9), worker: w }),
            (30, TraceEvent::TaskPublished { task: TaskId(9), worker: w, block: b }),
        ];
        for (ts, ev) in evs {
            rec.emit(0, Some(ts), ev);
        }
        let a = CriticalPathAnalysis::from_events(&rec.take());
        assert_eq!(a.jobs.len(), 1);
        let j = &a.jobs[0];
        assert_eq!(j.by_kind()["migration"], 20);
        assert!(a.identity_holds());
    }
}
