//! Flight recorder: structured event tracing for both engines
//! (DESIGN.md §8).
//!
//! Architecture: one [`TraceRecorder`] holds a bounded ring per track
//! (track 0 is the driver/simulator control plane, track `1 + w` is
//! worker `w`). Each track is single-writer in the threaded engine, so
//! its mutex is uncontended except at drain time; the simulator writes
//! every track from its one thread. A full ring *drops the event and
//! counts the drop* — recording never blocks and never grows. Rings are
//! drained into the collected log at quiescent points (no task in
//! flight anywhere) and at teardown, so the PR-7 lock-free read path is
//! never perturbed mid-task.
//!
//! Off-is-free invariant: engines carry a [`TraceConfig`]; when it is
//! `Off` every emission site is a single enum-discriminant branch — the
//! event closure is not even constructed — and `RunReport` is
//! byte-identical to a tracing run (pinned by `tests/trace.rs`).

pub mod analysis;
pub mod event;
pub mod sink;
pub mod summary;

pub use analysis::CriticalPathAnalysis;
pub use event::{Field, TraceEvent};
pub use sink::{ChromeSink, JsonlSink, TraceMeta, TraceSink};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Which clock produced the timestamps: the simulator's modeled clock
/// or the threaded engine's monotonic wall clock. Nanoseconds either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockDomain {
    /// Simulated time (deterministic).
    Logical,
    /// Monotonic nanos since `TraceRecorder::begin`.
    Wall,
}

impl ClockDomain {
    pub fn as_str(self) -> &'static str {
        match self {
            ClockDomain::Logical => "logical",
            ClockDomain::Wall => "wall",
        }
    }
}

/// One recorded event: timestamp (nanos in the run's clock domain), a
/// globally-unique emission sequence number, the track it was recorded
/// on, and the typed event.
#[derive(Debug, Clone)]
pub struct Rec {
    pub ts: u64,
    pub seq: u64,
    pub track: u32,
    pub event: TraceEvent,
}

/// Tracing mode carried on `EngineConfig`. `Off` is the default and is
/// free; `Collect` shares a recorder the caller drains after the run.
#[derive(Clone)]
pub enum TraceConfig {
    Off,
    Collect(Arc<TraceRecorder>),
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig::Off
    }
}

impl std::fmt::Debug for TraceConfig {
    // Manual: `EngineConfig` derives Debug and the recorder's rings are
    // noise (and mid-run state) no config dump should carry.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceConfig::Off => f.write_str("Off"),
            TraceConfig::Collect(_) => f.write_str("Collect"),
        }
    }
}

impl TraceConfig {
    /// A fresh collecting config plus the recorder handle to drain.
    pub fn collect(capacity_per_track: usize) -> (Self, Arc<TraceRecorder>) {
        let rec = Arc::new(TraceRecorder::new(capacity_per_track));
        (TraceConfig::Collect(rec.clone()), rec)
    }

    pub fn recorder(&self) -> Option<&Arc<TraceRecorder>> {
        match self {
            TraceConfig::Off => None,
            TraceConfig::Collect(rec) => Some(rec),
        }
    }

    pub fn is_on(&self) -> bool {
        matches!(self, TraceConfig::Collect(_))
    }

    /// Emit one event. `ts: None` stamps wall-clock nanos from the run
    /// base (the threaded engine); the simulator passes `Some(now)`.
    /// When `Off`, the closure is never called — the hot path pays one
    /// branch and zero allocations.
    #[inline]
    pub fn emit(&self, track: usize, ts: Option<u64>, ev: impl FnOnce() -> TraceEvent) {
        if let TraceConfig::Collect(rec) = self {
            rec.emit(track, ts, ev());
        }
    }
}

/// Default per-track ring capacity for CLI-constructed recorders.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

struct Ring {
    buf: VecDeque<Rec>,
}

/// The shared recorder: per-track bounded rings, a drop counter, and
/// the drained event log.
pub struct TraceRecorder {
    capacity: usize,
    rings: RwLock<Vec<Mutex<Ring>>>,
    seq: AtomicU64,
    dropped: AtomicU64,
    collected: Mutex<Vec<Rec>>,
    clock: Mutex<(ClockDomain, Option<Instant>)>,
}

impl TraceRecorder {
    pub fn new(capacity_per_track: usize) -> Self {
        Self {
            capacity: capacity_per_track.max(1),
            rings: RwLock::new(Vec::new()),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            collected: Mutex::new(Vec::new()),
            clock: Mutex::new((ClockDomain::Logical, None)),
        }
    }

    /// Reset for a run: allocate `tracks` rings, zero the counters, set
    /// the clock domain (wall runs stamp elapsed-from-now). Engines call
    /// this at run start; a recorder reused across runs keeps only the
    /// last run's events.
    pub fn begin(&self, tracks: usize, clock: ClockDomain) {
        let mut rings = self.rings.write().expect("trace rings poisoned");
        rings.clear();
        for _ in 0..tracks {
            rings.push(Mutex::new(Ring {
                buf: VecDeque::with_capacity(self.capacity.min(1024)),
            }));
        }
        self.seq.store(0, Ordering::SeqCst);
        self.dropped.store(0, Ordering::SeqCst);
        self.collected.lock().expect("trace log poisoned").clear();
        *self.clock.lock().expect("trace clock poisoned") = (
            clock,
            match clock {
                ClockDomain::Wall => Some(Instant::now()),
                ClockDomain::Logical => None,
            },
        );
    }

    pub fn clock(&self) -> ClockDomain {
        self.clock.lock().expect("trace clock poisoned").0
    }

    fn now(&self) -> u64 {
        match *self.clock.lock().expect("trace clock poisoned") {
            (_, Some(base)) => base.elapsed().as_nanos().min(u64::MAX as u128) as u64,
            _ => 0,
        }
    }

    /// Record one event on `track`. Never blocks on a full ring: the
    /// event is dropped and counted instead. Unknown tracks (an engine
    /// emitting before `begin`) count as drops too.
    pub fn emit(&self, track: usize, ts: Option<u64>, event: TraceEvent) {
        let ts = ts.unwrap_or_else(|| self.now());
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let rings = self.rings.read().expect("trace rings poisoned");
        let Some(ring) = rings.get(track) else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let mut ring = ring.lock().expect("trace ring poisoned");
        if ring.buf.len() >= self.capacity {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        ring.buf.push_back(Rec {
            ts,
            seq,
            track: track as u32,
            event,
        });
    }

    /// Move every ring's contents into the collected log (quiescent
    /// points and teardown).
    pub fn drain(&self) {
        let rings = self.rings.read().expect("trace rings poisoned");
        let mut log = self.collected.lock().expect("trace log poisoned");
        for ring in rings.iter() {
            let mut ring = ring.lock().expect("trace ring poisoned");
            log.extend(ring.buf.drain(..));
        }
    }

    /// Events dropped on full rings (or unknown tracks) since `begin`.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }

    /// Drain and take the full event log, ordered by emission sequence
    /// (globally unique, so the order is total and deterministic for the
    /// simulator).
    pub fn take(&self) -> Vec<Rec> {
        self.drain();
        let mut log = std::mem::take(&mut *self.collected.lock().expect("trace log poisoned"));
        log.sort_by_key(|r| r.seq);
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::TaskId;

    fn ev(n: u64) -> TraceEvent {
        TraceEvent::TaskReady { task: TaskId(n) }
    }

    #[test]
    fn off_config_never_builds_the_event() {
        let cfg = TraceConfig::Off;
        cfg.emit(0, None, || panic!("event constructed while Off"));
    }

    #[test]
    fn collects_in_sequence_order() {
        let (cfg, rec) = TraceConfig::collect(16);
        rec.begin(2, ClockDomain::Logical);
        cfg.emit(0, Some(5), || ev(0));
        cfg.emit(1, Some(1), || ev(1));
        cfg.emit(0, Some(9), || ev(2));
        let log = rec.take();
        assert_eq!(log.len(), 3);
        let tasks: Vec<u64> = log
            .iter()
            .map(|r| match r.event {
                TraceEvent::TaskReady { task } => task.0,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(tasks, vec![0, 1, 2]);
        assert_eq!(log[0].ts, 5);
        assert_eq!(log[1].track, 1);
    }

    #[test]
    fn full_ring_drops_and_counts_never_blocks() {
        let (cfg, rec) = TraceConfig::collect(4);
        rec.begin(1, ClockDomain::Logical);
        for i in 0..10 {
            cfg.emit(0, Some(i), || ev(i));
        }
        assert_eq!(rec.dropped(), 6);
        assert_eq!(rec.take().len(), 4);
    }

    #[test]
    fn drain_frees_ring_capacity() {
        let (cfg, rec) = TraceConfig::collect(4);
        rec.begin(1, ClockDomain::Logical);
        for i in 0..4 {
            cfg.emit(0, Some(i), || ev(i));
        }
        rec.drain();
        for i in 4..8 {
            cfg.emit(0, Some(i), || ev(i));
        }
        assert_eq!(rec.dropped(), 0);
        assert_eq!(rec.take().len(), 8);
    }

    #[test]
    fn unknown_track_counts_as_drop() {
        let (cfg, rec) = TraceConfig::collect(4);
        rec.begin(1, ClockDomain::Logical);
        cfg.emit(7, Some(0), || ev(0));
        assert_eq!(rec.dropped(), 1);
    }

    #[test]
    fn begin_resets_prior_run() {
        let (cfg, rec) = TraceConfig::collect(8);
        rec.begin(1, ClockDomain::Logical);
        cfg.emit(0, Some(0), || ev(0));
        rec.begin(1, ClockDomain::Logical);
        cfg.emit(0, Some(1), || ev(1));
        let log = rec.take();
        assert_eq!(log.len(), 1);
        assert_eq!(log[0].seq, 0);
    }
}
