//! DAG analysis: reference counts (LRC) and peer-groups (LERC).
//!
//! *Reference count* of block `b` (paper §II-B / [LRC]): the number of
//! **unmaterialized** blocks whose computation depends on `b`. Maintained
//! dynamically — completing a task materializes its output, consuming one
//! reference from each input.
//!
//! *Peer-group* of task `t` (paper §III): the set of `t`'s input blocks.
//! The all-or-nothing property holds per group; the peer tracker
//! ([`crate::peer`]) manages each group's complete/incomplete state.

use crate::common::ids::{BlockId, GroupId, TaskId};
use crate::dag::task::Task;

use std::collections::HashMap;

/// A task's input block set — the unit of the all-or-nothing property.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeerGroup {
    pub id: GroupId,
    pub task: TaskId,
    pub members: Vec<BlockId>,
    pub output: BlockId,
}

/// Extract one peer-group per task. Group ids reuse the task id value so
/// the mapping is stable and self-describing.
pub fn peer_groups(tasks: &[Task]) -> Vec<PeerGroup> {
    tasks
        .iter()
        .map(|t| PeerGroup {
            id: GroupId(t.id.0),
            task: t.id,
            members: t.inputs.clone(),
            output: t.output,
        })
        .collect()
}

/// Dynamic reference-count table (the CacheManagerMaster profile in the
/// paper's Fig 4).
#[derive(Debug, Clone, Default)]
pub struct RefCounts {
    counts: HashMap<BlockId, u32>,
}

impl RefCounts {
    /// Build the initial profile: every task input gets one reference per
    /// consuming (unmaterialized) output block.
    pub fn from_tasks(tasks: &[Task]) -> Self {
        let mut counts: HashMap<BlockId, u32> = HashMap::new();
        for t in tasks {
            for b in &t.inputs {
                *counts.entry(*b).or_default() += 1;
            }
            // Outputs start with zero references unless consumed downstream.
            counts.entry(t.output).or_default();
        }
        Self { counts }
    }

    pub fn get(&self, b: BlockId) -> u32 {
        self.counts.get(&b).copied().unwrap_or(0)
    }

    /// A task completed: its output is now materialized, consuming one
    /// reference from each input. Returns the blocks whose count changed
    /// (with their new values) so callers can push policy updates.
    pub fn on_task_complete(&mut self, task: &Task) -> Vec<(BlockId, u32)> {
        let mut changed = Vec::with_capacity(task.inputs.len());
        for b in &task.inputs {
            let c = self.counts.entry(*b).or_default();
            debug_assert!(*c > 0, "completing {} would underflow ref of {b}", task.id);
            *c = c.saturating_sub(1);
            changed.push((*b, *c));
        }
        changed
    }

    /// Register mid-run tasks (lineage recovery's recompute clones): each
    /// input gains one reference, outputs keep (or get) an entry. Returns
    /// the changed `(block, new_count)` pairs for policy updates —
    /// symmetric with [`Self::on_task_complete`], which will consume the
    /// references when the recompute finishes.
    pub fn add_tasks(&mut self, tasks: &[Task]) -> Vec<(BlockId, u32)> {
        let mut touched: Vec<BlockId> = Vec::new();
        for t in tasks {
            for b in &t.inputs {
                *self.counts.entry(*b).or_default() += 1;
                touched.push(*b);
            }
            self.counts.entry(t.output).or_default();
        }
        touched.sort();
        touched.dedup();
        touched.iter().map(|b| (*b, self.counts[b])).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&BlockId, &u32)> {
        self.counts.iter()
    }

    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{DatasetId, JobId};
    use crate::dag::graph::JobDag;
    use crate::dag::task::enumerate_tasks;

    fn two_stage() -> (JobDag, Vec<Task>) {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 4, 1024);
        let b = dag.input("B", 4, 1024);
        let c = dag.zip("C", a, b);
        dag.aggregate("D", c);
        let mut next = 0;
        let tasks = enumerate_tasks(&dag, &mut next);
        (dag, tasks)
    }

    #[test]
    fn initial_counts_match_dag() {
        let (_, tasks) = two_stage();
        let rc = RefCounts::from_tasks(&tasks);
        // Each A/B block feeds one zip task; each C block feeds one agg task.
        assert_eq!(rc.get(BlockId::new(DatasetId(0), 0)), 1);
        assert_eq!(rc.get(BlockId::new(DatasetId(1), 3)), 1);
        assert_eq!(rc.get(BlockId::new(DatasetId(2), 2)), 1);
        // D blocks have no consumers.
        assert_eq!(rc.get(BlockId::new(DatasetId(3), 0)), 0);
    }

    #[test]
    fn completion_decrements_inputs() {
        let (_, tasks) = two_stage();
        let mut rc = RefCounts::from_tasks(&tasks);
        let zip0 = &tasks[0];
        let changed = rc.on_task_complete(zip0);
        assert_eq!(changed.len(), 2);
        for (b, c) in changed {
            assert_eq!(c, 0);
            assert_eq!(rc.get(b), 0);
        }
        // Unrelated blocks untouched.
        assert_eq!(rc.get(BlockId::new(DatasetId(0), 1)), 1);
    }

    #[test]
    fn shared_input_counts_all_consumers() {
        // One dataset consumed by two transforms -> ref count 2 per block.
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 2, 1024);
        dag.aggregate("G1", a);
        dag.partition("P1", a);
        let mut next = 0;
        let tasks = enumerate_tasks(&dag, &mut next);
        let mut rc = RefCounts::from_tasks(&tasks);
        assert_eq!(rc.get(BlockId::new(a, 0)), 2);
        rc.on_task_complete(&tasks[0]);
        assert_eq!(rc.get(BlockId::new(a, 0)), 1);
    }

    #[test]
    fn add_tasks_restores_consumed_references() {
        let (_, tasks) = two_stage();
        let mut rc = RefCounts::from_tasks(&tasks);
        let zip0 = tasks[0].clone();
        rc.on_task_complete(&zip0);
        assert_eq!(rc.get(zip0.inputs[0]), 0);
        // A recompute clone of zip_0 re-references its inputs.
        let clone = Task {
            id: TaskId(77),
            ..zip0.clone()
        };
        let changed = rc.add_tasks(std::slice::from_ref(&clone));
        assert_eq!(changed.len(), 2);
        assert!(changed.iter().all(|&(_, c)| c == 1));
        assert_eq!(rc.get(zip0.inputs[0]), 1);
        // Completing the recompute consumes them again, no underflow.
        rc.on_task_complete(&clone);
        assert_eq!(rc.get(zip0.inputs[0]), 0);
    }

    #[test]
    fn peer_groups_mirror_tasks() {
        let (_, tasks) = two_stage();
        let groups = peer_groups(&tasks);
        assert_eq!(groups.len(), tasks.len());
        for (g, t) in groups.iter().zip(&tasks) {
            assert_eq!(g.task, t.id);
            assert_eq!(g.members, t.inputs);
            assert_eq!(g.output, t.output);
            assert_eq!(g.id.0, t.id.0);
        }
    }
}
