//! Task enumeration: one task per block of every transform dataset.

use crate::common::ids::{BlockId, JobId, TaskId};
use crate::dag::graph::JobDag;

/// Compute kind — the AOT artifact the task executes.
pub type TaskKind = &'static str;

/// One schedulable unit: materializes `output` from `inputs`.
/// `inputs` is exactly the task's *peer-group* (paper §III).
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    pub job: JobId,
    pub kind: String,
    pub inputs: Vec<BlockId>,
    pub output: BlockId,
    /// Input block length in elements (selects the artifact variant).
    pub input_len: usize,
    /// Output block length in elements.
    pub output_len: usize,
}

/// Enumerate every task of `dag`, assigning ids from `*next_id` onwards.
/// Tasks appear in topological order (parents' datasets precede children's
/// because the builder appends datasets topologically).
pub fn enumerate_tasks(dag: &JobDag, next_id: &mut u64) -> Vec<Task> {
    let mut tasks = Vec::new();
    for ds in dag.transforms() {
        let input_len = dag.dataset(ds.parents[0]).block_len;
        let kind = ds
            .op
            .task_kind()
            .expect("transform datasets have a task kind")
            .to_string();
        for index in 0..ds.num_blocks {
            let id = TaskId(*next_id);
            *next_id += 1;
            tasks.push(Task {
                id,
                job: dag.job,
                kind: kind.clone(),
                inputs: dag.block_parents(ds.id, index),
                output: BlockId::new(ds.id, index),
                input_len,
                output_len: ds.block_len,
            });
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    #[test]
    fn enumerates_one_task_per_output_block() {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 4, 1024);
        let b = dag.input("B", 4, 1024);
        let c = dag.zip("C", a, b);
        let mut next = 0;
        let tasks = enumerate_tasks(&dag, &mut next);
        assert_eq!(tasks.len(), 4);
        assert_eq!(next, 4);
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.output, BlockId::new(c, i as u32));
            assert_eq!(t.inputs.len(), 2);
            assert_eq!(t.kind, "zip_task");
            assert_eq!(t.input_len, 1024);
            assert_eq!(t.output_len, 2048);
        }
    }

    #[test]
    fn task_ids_continue_across_jobs() {
        let mut dag1 = JobDag::new(JobId(0), 0);
        let a = dag1.input("A", 2, 1024);
        dag1.aggregate("G", a);
        let mut dag2 = JobDag::new(JobId(1), 10);
        let b = dag2.input("B", 3, 1024);
        dag2.partition("P", b);

        let mut next = 0;
        let t1 = enumerate_tasks(&dag1, &mut next);
        let t2 = enumerate_tasks(&dag2, &mut next);
        assert_eq!(t1.len(), 2);
        assert_eq!(t2.len(), 3);
        let ids: Vec<u64> = t1.iter().chain(&t2).map(|t| t.id.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn multi_stage_tasks_are_topological() {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 4, 1024);
        let b = dag.input("B", 4, 1024);
        let c = dag.zip("C", a, b);
        let _d = dag.aggregate("D", c);
        let mut next = 0;
        let tasks = enumerate_tasks(&dag, &mut next);
        assert_eq!(tasks.len(), 8);
        // Zip tasks (producing C) come before aggregate tasks (consuming C).
        assert!(tasks[..4].iter().all(|t| t.output.dataset == c));
        assert!(tasks[4..]
            .iter()
            .all(|t| t.inputs.iter().all(|i| i.dataset == c)));
        assert_eq!(tasks[4].inputs, vec![BlockId::new(DatasetId(2), 0)]);
    }
}
