//! Lineage DAGs: datasets, block-level dependencies, reference-count and
//! peer-group analysis.
//!
//! A [`JobDag`](graph::JobDag) is the engine's analog of a Spark job: a DAG
//! of datasets, each partitioned into blocks. Every block of every
//! non-input dataset is materialized by exactly one [`Task`](task::Task)
//! whose inputs are the block-level parents dictated by the dataset's
//! [`Op`](ops::Op). A task's input set is its *peer-group* (paper §III):
//! the unit over which the all-or-nothing property holds.

pub mod analysis;
pub mod graph;
pub mod ops;
pub mod task;

pub use analysis::{peer_groups, PeerGroup, RefCounts};
pub use graph::{Dataset, JobDag};
pub use ops::Op;
pub use task::{Task, TaskKind};
