//! Data-parallel operators and their block-level dependency shapes.

/// The operators the engine supports. Each non-`Input` op maps 1:1 onto an
/// AOT-compiled task artifact (see `python/compile/model.py::TASKS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Leaf dataset: blocks are ingested from external storage.
    Input,
    /// `C_i = zip(A_i, B_i)` — the paper's Fig 2 workload. Binary, aligned.
    Zip,
    /// `C_i = A_{2i} ++ A_{2i+1}` — the paper's Fig 1 workload. Unary on
    /// the dataset, binary on blocks (factor fixed at 2 to match the
    /// AOT artifact).
    Coalesce,
    /// Pairwise hash-join of co-partitioned datasets: `C_i = join(A_i, B_i)`.
    Join,
    /// Windowed reduction: `C_i = window_sum(A_i)`. Unary.
    Aggregate,
    /// Shuffle map-side: partition ids + histogram. Unary.
    Partition,
    /// Fused zip + reduce-values: `C_i = reduce(zip(A_i, B_i))`. Binary.
    ZipReduce,
    /// Elementwise affine map: `C_i = scale * A_i + shift`. Unary.
    Map,
}

impl Op {
    /// Arity in *blocks per task* (how many input blocks one output block
    /// depends on).
    pub fn block_arity(&self) -> usize {
        match self {
            Op::Input => 0,
            Op::Aggregate | Op::Partition | Op::Map => 1,
            Op::Zip | Op::Coalesce | Op::Join | Op::ZipReduce => 2,
        }
    }

    /// Arity in parent *datasets*.
    pub fn dataset_arity(&self) -> usize {
        match self {
            Op::Input => 0,
            Op::Coalesce | Op::Aggregate | Op::Partition | Op::Map => 1,
            Op::Zip | Op::Join | Op::ZipReduce => 2,
        }
    }

    /// Name of the AOT artifact implementing this op's compute.
    pub fn task_kind(&self) -> Option<&'static str> {
        match self {
            Op::Input => None,
            Op::Zip => Some("zip_task"),
            Op::Coalesce => Some("coalesce_task"),
            Op::Join => Some("zip_task"), // pairwise join shares the zip kernel
            Op::Aggregate => Some("agg_task"),
            Op::Partition => Some("partition_task"),
            Op::ZipReduce => Some("zip_reduce_task"),
            Op::Map => Some("map_task"),
        }
    }

    /// Output block length in elements, given input block length `n`.
    pub fn output_len(&self, n: usize) -> usize {
        match self {
            Op::Input => n,
            Op::Zip | Op::Join => 2 * n,  // (n, 2) kv pairs
            Op::Coalesce => 2 * n,        // concatenation of two blocks
            Op::Aggregate => n / 128,     // windowed partial sums
            Op::Partition => n,           // i32 ids (same byte width as f32)
            Op::ZipReduce => n / 128,
            Op::Map => n,
        }
    }

    /// Number of output blocks given the first parent's block count.
    pub fn output_blocks(&self, parent_blocks: u32) -> u32 {
        match self {
            Op::Input => parent_blocks,
            Op::Coalesce => parent_blocks / 2,
            _ => parent_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arities() {
        assert_eq!(Op::Zip.block_arity(), 2);
        assert_eq!(Op::Zip.dataset_arity(), 2);
        assert_eq!(Op::Coalesce.block_arity(), 2);
        assert_eq!(Op::Coalesce.dataset_arity(), 1);
        assert_eq!(Op::Aggregate.block_arity(), 1);
        assert_eq!(Op::Input.block_arity(), 0);
    }

    #[test]
    fn task_kinds_map_to_artifacts() {
        assert_eq!(Op::Zip.task_kind(), Some("zip_task"));
        assert_eq!(Op::Join.task_kind(), Some("zip_task"));
        assert_eq!(Op::Input.task_kind(), None);
        for op in [Op::Coalesce, Op::Aggregate, Op::Partition, Op::ZipReduce] {
            assert!(op.task_kind().is_some());
        }
    }

    #[test]
    fn output_shapes() {
        assert_eq!(Op::Zip.output_len(65536), 131072);
        assert_eq!(Op::Aggregate.output_len(65536), 512);
        assert_eq!(Op::Coalesce.output_blocks(100), 50);
        assert_eq!(Op::Zip.output_blocks(100), 100);
    }
}
