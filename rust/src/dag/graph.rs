//! Job DAGs: datasets linked by operators, with a fluent builder API.

use crate::common::ids::{BlockId, DatasetId, JobId};
use crate::dag::ops::Op;

/// One dataset (RDD analog) in a job DAG.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub id: DatasetId,
    pub name: String,
    pub op: Op,
    pub parents: Vec<DatasetId>,
    pub num_blocks: u32,
    /// Block length in elements (f32 or i32 — both 4 bytes).
    pub block_len: usize,
}

impl Dataset {
    pub fn block_bytes(&self) -> u64 {
        (self.block_len * 4) as u64
    }

    pub fn blocks(&self) -> impl Iterator<Item = BlockId> + '_ {
        let id = self.id;
        (0..self.num_blocks).map(move |i| BlockId::new(id, i))
    }
}

/// A job: a DAG of datasets. Dataset ids are globally unique across jobs
/// (the builder takes a base offset so multiple tenants never collide).
#[derive(Debug, Clone)]
pub struct JobDag {
    pub job: JobId,
    pub datasets: Vec<Dataset>,
    base: u32,
}

impl JobDag {
    /// `base` is the first dataset id this job may use; callers building
    /// multi-tenant workloads hand each job a disjoint range.
    pub fn new(job: JobId, base: u32) -> Self {
        Self {
            job,
            datasets: Vec::new(),
            base,
        }
    }

    fn next_id(&self) -> DatasetId {
        DatasetId(self.base + self.datasets.len() as u32)
    }

    pub fn dataset(&self, id: DatasetId) -> &Dataset {
        self.datasets
            .iter()
            .find(|d| d.id == id)
            .expect("dataset id belongs to this dag")
    }

    /// Leaf dataset ingested from external storage.
    pub fn input(&mut self, name: &str, num_blocks: u32, block_len: usize) -> DatasetId {
        let id = self.next_id();
        self.datasets.push(Dataset {
            id,
            name: name.to_string(),
            op: Op::Input,
            parents: vec![],
            num_blocks,
            block_len,
        });
        id
    }

    /// Reference a **shared** ingest dataset by its explicit id: several
    /// jobs in one `JobQueue` declaring the same `DatasetId` (with the
    /// same shape) read the same external bytes, and the engines ingest
    /// each shared block once — `BlockId` is the content key. Callers
    /// reserve an id range outside every job's private base (see
    /// `workload::generators`). Panics if the id collides with a dataset
    /// this DAG already owns.
    pub fn shared_input(
        &mut self,
        name: &str,
        id: DatasetId,
        num_blocks: u32,
        block_len: usize,
    ) -> DatasetId {
        assert!(
            self.datasets.iter().all(|d| d.id != id),
            "shared dataset {id} collides within one dag"
        );
        self.datasets.push(Dataset {
            id,
            name: name.to_string(),
            op: Op::Input,
            parents: vec![],
            num_blocks,
            block_len,
        });
        id
    }

    fn transform(&mut self, name: &str, op: Op, parents: Vec<DatasetId>) -> DatasetId {
        assert_eq!(parents.len(), op.dataset_arity(), "{op:?} arity mismatch");
        let p0 = self.dataset(parents[0]);
        if op.dataset_arity() == 2 {
            let p1 = self.dataset(parents[1]);
            assert_eq!(
                p0.num_blocks, p1.num_blocks,
                "binary ops require aligned partitioning"
            );
            assert_eq!(p0.block_len, p1.block_len);
        }
        if op == Op::Coalesce {
            assert!(
                p0.num_blocks % 2 == 0,
                "coalesce requires an even block count"
            );
        }
        let num_blocks = op.output_blocks(p0.num_blocks);
        let block_len = op.output_len(p0.block_len);
        let id = self.next_id();
        self.datasets.push(Dataset {
            id,
            name: name.to_string(),
            op,
            parents,
            num_blocks,
            block_len,
        });
        id
    }

    pub fn zip(&mut self, name: &str, a: DatasetId, b: DatasetId) -> DatasetId {
        self.transform(name, Op::Zip, vec![a, b])
    }

    pub fn join(&mut self, name: &str, a: DatasetId, b: DatasetId) -> DatasetId {
        self.transform(name, Op::Join, vec![a, b])
    }

    pub fn coalesce(&mut self, name: &str, a: DatasetId) -> DatasetId {
        self.transform(name, Op::Coalesce, vec![a])
    }

    pub fn aggregate(&mut self, name: &str, a: DatasetId) -> DatasetId {
        self.transform(name, Op::Aggregate, vec![a])
    }

    pub fn partition(&mut self, name: &str, a: DatasetId) -> DatasetId {
        self.transform(name, Op::Partition, vec![a])
    }

    pub fn zip_reduce(&mut self, name: &str, a: DatasetId, b: DatasetId) -> DatasetId {
        self.transform(name, Op::ZipReduce, vec![a, b])
    }

    pub fn map(&mut self, name: &str, a: DatasetId) -> DatasetId {
        self.transform(name, Op::Map, vec![a])
    }

    /// Block-level parents of block `index` of dataset `d`.
    pub fn block_parents(&self, d: DatasetId, index: u32) -> Vec<BlockId> {
        let ds = self.dataset(d);
        match ds.op {
            Op::Input => vec![],
            Op::Zip | Op::Join | Op::ZipReduce => vec![
                BlockId::new(ds.parents[0], index),
                BlockId::new(ds.parents[1], index),
            ],
            Op::Coalesce => vec![
                BlockId::new(ds.parents[0], 2 * index),
                BlockId::new(ds.parents[0], 2 * index + 1),
            ],
            Op::Aggregate | Op::Partition | Op::Map => vec![BlockId::new(ds.parents[0], index)],
        }
    }

    /// All input (leaf) datasets.
    pub fn inputs(&self) -> impl Iterator<Item = &Dataset> {
        self.datasets.iter().filter(|d| d.op == Op::Input)
    }

    /// All transform (non-leaf) datasets, in creation (topological) order.
    pub fn transforms(&self) -> impl Iterator<Item = &Dataset> {
        self.datasets.iter().filter(|d| d.op != Op::Input)
    }

    /// Total bytes across the blocks of all input datasets.
    pub fn input_bytes(&self) -> u64 {
        self.inputs()
            .map(|d| d.num_blocks as u64 * d.block_bytes())
            .sum()
    }

    /// Validate the DAG: parents exist and precede children (the builder
    /// guarantees this; external deserialization may not).
    pub fn validate(&self) -> crate::common::error::Result<()> {
        use crate::common::error::EngineError;
        for (pos, d) in self.datasets.iter().enumerate() {
            for p in &d.parents {
                let ppos = self
                    .datasets
                    .iter()
                    .position(|x| x.id == *p)
                    .ok_or_else(|| EngineError::Config(format!("{}: missing parent {p}", d.id)))?;
                if ppos >= pos {
                    return Err(EngineError::Config(format!(
                        "{}: parent {p} does not precede child",
                        d.id
                    )));
                }
            }
            if d.op.dataset_arity() != d.parents.len() {
                return Err(EngineError::Config(format!("{}: arity mismatch", d.id)));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zip_dag() -> JobDag {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 10, 1024);
        let b = dag.input("B", 10, 1024);
        dag.zip("C", a, b);
        dag
    }

    #[test]
    fn zip_block_parents_are_aligned_pairs() {
        let dag = zip_dag();
        let c = dag.datasets[2].id;
        assert_eq!(
            dag.block_parents(c, 3),
            vec![
                BlockId::new(DatasetId(0), 3),
                BlockId::new(DatasetId(1), 3)
            ]
        );
    }

    #[test]
    fn coalesce_block_parents_are_adjacent() {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 10, 1024);
        let x = dag.coalesce("X", a);
        assert_eq!(dag.dataset(x).num_blocks, 5);
        assert_eq!(
            dag.block_parents(x, 2),
            vec![
                BlockId::new(DatasetId(0), 4),
                BlockId::new(DatasetId(0), 5)
            ]
        );
    }

    #[test]
    fn shared_input_keeps_explicit_id_and_feeds_transforms() {
        // Two jobs referencing the same shared dataset id produce
        // identical block ids — the content key the engines dedup on.
        let mk = |job: u32, base: u32| {
            let mut dag = JobDag::new(JobId(job), base);
            let s = dag.shared_input("S", DatasetId(7), 4, 1024);
            let v = dag.input("V", 4, 1024);
            dag.zip("kv", s, v);
            dag
        };
        let a = mk(0, 100);
        let b = mk(1, 200);
        assert!(a.validate().is_ok());
        assert_eq!(
            a.dataset(DatasetId(7)).blocks().collect::<Vec<_>>(),
            b.dataset(DatasetId(7)).blocks().collect::<Vec<_>>()
        );
        // Private datasets stay in their own ranges.
        assert_eq!(a.datasets[1].id, DatasetId(101));
        assert_eq!(b.datasets[1].id, DatasetId(201));
    }

    #[test]
    #[should_panic(expected = "collides")]
    fn shared_input_rejects_in_dag_collision() {
        let mut dag = JobDag::new(JobId(0), 7);
        dag.input("A", 2, 1024); // takes DatasetId(7)
        dag.shared_input("S", DatasetId(7), 2, 1024);
    }

    #[test]
    fn dataset_ids_respect_base() {
        let mut dag = JobDag::new(JobId(3), 100);
        let a = dag.input("A", 1, 1024);
        assert_eq!(a, DatasetId(100));
    }

    #[test]
    fn output_shape_propagates() {
        let dag = zip_dag();
        let c = &dag.datasets[2];
        assert_eq!(c.block_len, 2048);
        assert_eq!(c.num_blocks, 10);
    }

    #[test]
    fn validate_accepts_builder_output() {
        assert!(zip_dag().validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "aligned partitioning")]
    fn zip_rejects_misaligned() {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 10, 1024);
        let b = dag.input("B", 5, 1024);
        dag.zip("C", a, b);
    }

    #[test]
    fn input_bytes_sums_leaves() {
        let dag = zip_dag();
        assert_eq!(dag.input_bytes(), 2 * 10 * 1024 * 4);
    }

    #[test]
    fn chained_transforms() {
        let mut dag = JobDag::new(JobId(0), 0);
        let a = dag.input("A", 8, 1024);
        let b = dag.input("B", 8, 1024);
        let c = dag.zip("C", a, b);
        let d = dag.aggregate("D", c);
        assert_eq!(dag.dataset(d).block_len, 2048 / 128);
        assert_eq!(
            dag.block_parents(d, 1),
            vec![BlockId::new(c, 1)]
        );
    }
}
