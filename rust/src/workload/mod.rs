//! Workload generators: every experiment scenario in the paper plus
//! extension scenarios for ablations and property tests.

pub mod generators;

pub use generators::*;

use crate::common::ids::BlockId;
use crate::dag::graph::JobDag;

/// A runnable workload: one or more jobs (tenants) plus the order in which
/// input blocks arrive during the ingest phase.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub dags: Vec<JobDag>,
    /// Global arrival order of input-dataset blocks (the order the cache
    /// sees inserts during ingest — this ordering is what defeats LRU in
    /// the paper's §IV experiment).
    pub ingest_order: Vec<BlockId>,
    /// Fig-3-style controlled cache contents: when `Some`, ONLY these
    /// blocks are admitted to (and pinned in) the cache at ingest; all
    /// other input blocks go to disk only and the policy never evicts the
    /// pinned set. `None` = normal policy-managed caching.
    pub pinned_cache: Option<Vec<BlockId>>,
}

impl Workload {
    /// Total bytes of all input blocks.
    pub fn input_bytes(&self) -> u64 {
        self.dags.iter().map(|d| d.input_bytes()).sum()
    }

    /// Total number of tasks across all jobs.
    pub fn task_count(&self) -> usize {
        self.dags
            .iter()
            .flat_map(|d| d.transforms())
            .map(|ds| ds.num_blocks as usize)
            .sum()
    }

    /// Validate all DAGs and the ingest order (every input block appears
    /// exactly once).
    pub fn validate(&self) -> crate::common::error::Result<()> {
        use crate::common::error::EngineError;
        use std::collections::HashSet;
        for dag in &self.dags {
            dag.validate()?;
        }
        let expect: HashSet<BlockId> = self
            .dags
            .iter()
            .flat_map(|d| d.inputs().flat_map(|ds| ds.blocks().collect::<Vec<_>>()))
            .collect();
        let got: HashSet<BlockId> = self.ingest_order.iter().copied().collect();
        if got.len() != self.ingest_order.len() {
            return Err(EngineError::Config("duplicate block in ingest order".into()));
        }
        if got != expect {
            return Err(EngineError::Config(format!(
                "ingest order covers {} blocks, inputs have {}",
                got.len(),
                expect.len()
            )));
        }
        Ok(())
    }
}
