//! Workload generators: every experiment scenario in the paper plus
//! extension scenarios for ablations and property tests.

pub mod generators;

pub use generators::*;

use crate::common::ids::BlockId;
use crate::dag::graph::JobDag;

/// A runnable workload: one or more jobs (tenants) plus the order in which
/// input blocks arrive during the ingest phase.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub dags: Vec<JobDag>,
    /// Global arrival order of input-dataset blocks (the order the cache
    /// sees inserts during ingest — this ordering is what defeats LRU in
    /// the paper's §IV experiment).
    pub ingest_order: Vec<BlockId>,
    /// Fig-3-style controlled cache contents: when `Some`, ONLY these
    /// blocks are admitted to (and pinned in) the cache at ingest; all
    /// other input blocks go to disk only and the policy never evicts the
    /// pinned set. `None` = normal policy-managed caching.
    pub pinned_cache: Option<Vec<BlockId>>,
}

/// One submission to an online multi-job run: a [`Workload`] plus its
/// arrival point and dispatch priority.
///
/// Arrival is a **global dispatch index**, the same deterministic logical
/// clock the failure plan uses (`FailurePlan::at_dispatch`): the job is
/// admitted once the engine has dispatched `arrival` tasks across all
/// jobs. Both engines hold dispatch at the boundary and admit there, so
/// the interleaving prefix is identical in the simulator and the threaded
/// engine. If a queue quiesces before an arrival index can be reached
/// (nothing pending, in flight, or ready), the next job is admitted
/// immediately — an arrival index is "no earlier than", never a deadlock.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub workload: Workload,
    /// Global dispatch index at which the job arrives (0 = at start).
    pub arrival: u64,
    /// Dispatch priority: among ready tasks, higher dispatches first
    /// (FIFO within a level). Default 0.
    pub priority: u8,
}

/// An ordered set of job submissions sharing one cluster run: the unit
/// the online engines execute (`crate::engine::Engine::run`).
///
/// Jobs share the block cache. A `BlockId` is the **content key** for
/// ingest data: two jobs declaring the same input `DatasetId` (see
/// `JobDag::shared_input`) read the same external bytes, the engines
/// ingest each shared block once, and reference counts / peer-group
/// effective counts aggregate over every live job that reaches the block.
#[derive(Debug, Clone, Default)]
pub struct JobQueue {
    pub name: String,
    pub jobs: Vec<JobSpec>,
}

impl JobQueue {
    /// Wrap one workload as a queue of one job arriving at dispatch 0 —
    /// the classic offline run (`ClusterEngine::run` delegates to this).
    pub fn single(workload: Workload) -> Self {
        let name = workload.name.clone();
        Self {
            name,
            jobs: vec![JobSpec {
                workload,
                arrival: 0,
                priority: 0,
            }],
        }
    }

    /// Append a submission.
    pub fn submit(&mut self, workload: Workload, arrival: u64, priority: u8) -> &mut Self {
        self.jobs.push(JobSpec {
            workload,
            arrival,
            priority,
        });
        self
    }

    /// Total tasks across every job.
    pub fn task_count(&self) -> usize {
        self.jobs.iter().map(|j| j.workload.task_count()).sum()
    }

    /// Validate every job's workload plus the cross-job sharing rules:
    /// job ids are distinct, and a dataset appearing in several jobs is a
    /// shared *input* with identical shape everywhere (the content-key
    /// contract — same id, same bytes).
    pub fn validate(&self) -> crate::common::error::Result<()> {
        use crate::common::error::EngineError;
        use crate::common::ids::{DatasetId, JobId};
        use crate::dag::ops::Op;
        use std::collections::{HashMap, HashSet};
        let mut seen_jobs: HashSet<JobId> = HashSet::new();
        // dataset -> (op-is-input, num_blocks, block_len)
        let mut datasets: HashMap<DatasetId, (bool, u32, usize)> = HashMap::new();
        for spec in &self.jobs {
            spec.workload.validate()?;
            // Fig-3-style controlled cache contents are a single-job
            // experiment: with several jobs, the first admitter ingests
            // each shared block, so a later job's pin/cache choices
            // would be silently dropped. Refuse instead of diverging.
            if self.jobs.len() > 1 && spec.workload.pinned_cache.is_some() {
                return Err(EngineError::Config(
                    "pinned_cache is only supported in single-job queues (per-job \
                     pin reconciliation over shared ingest is undefined)"
                        .into(),
                ));
            }
            for dag in &spec.workload.dags {
                if !seen_jobs.insert(dag.job) {
                    return Err(EngineError::Config(format!(
                        "job id {} submitted twice in one queue",
                        dag.job
                    )));
                }
                for ds in &dag.datasets {
                    let shape = (ds.op == Op::Input, ds.num_blocks, ds.block_len);
                    match datasets.get(&ds.id) {
                        None => {
                            datasets.insert(ds.id, shape);
                        }
                        Some(prev) => {
                            if !(prev.0 && shape.0) {
                                return Err(EngineError::Config(format!(
                                    "dataset {} appears in several jobs but is not a \
                                     shared input in all of them",
                                    ds.id
                                )));
                            }
                            if prev.1 != shape.1 || prev.2 != shape.2 {
                                return Err(EngineError::Config(format!(
                                    "shared dataset {} has mismatched shape across jobs",
                                    ds.id
                                )));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

impl Workload {
    /// Total bytes of all input blocks.
    pub fn input_bytes(&self) -> u64 {
        self.dags.iter().map(|d| d.input_bytes()).sum()
    }

    /// Total number of tasks across all jobs.
    pub fn task_count(&self) -> usize {
        self.dags
            .iter()
            .flat_map(|d| d.transforms())
            .map(|ds| ds.num_blocks as usize)
            .sum()
    }

    /// Validate all DAGs and the ingest order (every input block appears
    /// exactly once within this workload; cross-job sharing is validated
    /// by [`JobQueue::validate`]).
    pub fn validate(&self) -> crate::common::error::Result<()> {
        use crate::common::error::EngineError;
        use std::collections::HashSet;
        for dag in &self.dags {
            dag.validate()?;
        }
        let expect: HashSet<BlockId> = self
            .dags
            .iter()
            .flat_map(|d| d.inputs().flat_map(|ds| ds.blocks().collect::<Vec<_>>()))
            .collect();
        let got: HashSet<BlockId> = self.ingest_order.iter().copied().collect();
        if got.len() != self.ingest_order.len() {
            return Err(EngineError::Config("duplicate block in ingest order".into()));
        }
        if got != expect {
            return Err(EngineError::Config(format!(
                "ingest order covers {} blocks, inputs have {}",
                got.len(),
                expect.len()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::{DatasetId, JobId};
    use crate::dag::graph::JobDag;
    use crate::workload::generators;

    #[test]
    fn single_wraps_one_job_at_dispatch_zero() {
        let q = JobQueue::single(generators::zip_single(4, 1024));
        q.validate().unwrap();
        assert_eq!(q.jobs.len(), 1);
        assert_eq!(q.jobs[0].arrival, 0);
        assert_eq!(q.jobs[0].priority, 0);
        assert_eq!(q.task_count(), 4);
    }

    #[test]
    fn duplicate_job_ids_are_rejected() {
        let mut q = JobQueue::default();
        q.submit(generators::zip_single(4, 1024), 0, 0);
        q.submit(generators::zip_single(4, 1024), 2, 0); // same JobId(0)
        let err = q.validate().unwrap_err();
        assert!(err.to_string().contains("submitted twice"), "{err}");
    }

    #[test]
    fn shared_dataset_shape_mismatch_is_rejected() {
        let mk = |job: u32, base: u32, blocks: u32| {
            let mut dag = JobDag::new(JobId(job), base);
            let s = dag.shared_input("S", DatasetId(0), blocks, 1024);
            dag.aggregate("G", s);
            let ingest_order = dag.dataset(s).blocks().collect();
            Workload {
                name: format!("j{job}"),
                dags: vec![dag],
                ingest_order,
                pinned_cache: None,
            }
        };
        let mut ok = JobQueue::default();
        ok.submit(mk(0, 100, 4), 0, 0);
        ok.submit(mk(1, 200, 4), 1, 0);
        ok.validate().unwrap();

        let mut bad = JobQueue::default();
        bad.submit(mk(0, 100, 4), 0, 0);
        bad.submit(mk(1, 200, 6), 1, 0);
        let err = bad.validate().unwrap_err();
        assert!(err.to_string().contains("mismatched shape"), "{err}");
    }

    #[test]
    fn pinned_cache_is_rejected_in_multi_job_queues() {
        let mut pinned = generators::zip_single(4, 1024);
        pinned.pinned_cache = Some(pinned.ingest_order.clone());
        // Alone: fine (the Fig-3 harness path).
        JobQueue::single(pinned.clone()).validate().unwrap();
        // In company: refused — a later job's pin choices on shared
        // blocks could not be honored.
        let mut other = generators::zip_single(4, 1024);
        other.dags[0].job = JobId(1);
        let mut q = JobQueue::default();
        q.submit(other, 0, 0);
        q.submit(pinned, 2, 0);
        let err = q.validate().unwrap_err();
        assert!(err.to_string().contains("single-job"), "{err}");
    }

    #[test]
    fn shared_transform_ids_are_rejected() {
        // Job 1 reuses job 0's *transform* dataset id: not a shared
        // input, so the queue must refuse it.
        let mk = |job: u32, base: u32| {
            let mut dag = JobDag::new(JobId(job), base);
            let a = dag.input("A", 2, 1024);
            dag.aggregate("G", a); // dataset base+1
            let ingest_order = dag.dataset(a).blocks().collect();
            Workload {
                name: format!("j{job}"),
                dags: vec![dag],
                ingest_order,
                pinned_cache: None,
            }
        };
        let mut q = JobQueue::default();
        q.submit(mk(0, 100), 0, 0);
        q.submit(mk(1, 100), 1, 0); // whole id range collides
        let err = q.validate().unwrap_err();
        assert!(err.to_string().contains("not a shared input"), "{err}");
    }
}
