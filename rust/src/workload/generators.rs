//! Concrete workload builders, one per experiment scenario, plus
//! multi-job arrival traces ([`JobQueue`]) for the online engines.

use crate::common::ids::{BlockId, DatasetId, JobId};
use crate::common::rng::SplitMix64;
use crate::dag::graph::JobDag;
use crate::workload::{JobQueue, Workload};

/// Dataset-id stride reserved per job so tenants never collide.
const JOB_ID_STRIDE: u32 = 64;

/// Dataset ids below this are reserved for cross-job **shared** ingest
/// datasets (content-keyed; see `JobDag::shared_input`); private job
/// bases start at `SHARED_ID_SPAN`.
const SHARED_ID_SPAN: u32 = 64;

/// Private dataset-id base for job `j` in a multi-job queue.
fn job_base(j: u32) -> u32 {
    SHARED_ID_SPAN + j * JOB_ID_STRIDE
}

/// The paper's §IV experiment: `tenants` parallel zip jobs, each zipping
/// two files of `blocks_per_file` blocks.
///
/// Ingest order models parallel tenants writing their first file, then
/// their second: round-robin across tenants over file-A blocks, then
/// round-robin over file-B blocks. Under LRU the A (key) blocks are
/// always the oldest when the B (value) blocks arrive — the §IV-B
/// "effective hit ratio of LRU is near zero" mechanism.
pub fn multi_tenant_zip(tenants: u32, blocks_per_file: u32, block_len: usize) -> Workload {
    let mut dags = Vec::new();
    for j in 0..tenants {
        let mut dag = JobDag::new(JobId(j), j * JOB_ID_STRIDE);
        let a = dag.input("keys", blocks_per_file, block_len);
        let b = dag.input("values", blocks_per_file, block_len);
        dag.zip("kv", a, b);
        dags.push(dag);
    }
    let ingest_order = parallel_tenant_ingest(&dags);
    Workload {
        name: format!("multi_tenant_zip(t={tenants},b={blocks_per_file})"),
        dags,
        ingest_order,
        pinned_cache: None,
    }
}

/// Single zip job (the Fig 2 DAG): two RDDs of `blocks` blocks each.
pub fn zip_single(blocks: u32, block_len: usize) -> Workload {
    multi_tenant_zip_named(1, blocks, block_len, "zip_single")
}

fn multi_tenant_zip_named(
    tenants: u32,
    blocks: u32,
    block_len: usize,
    name: &str,
) -> Workload {
    let mut w = multi_tenant_zip(tenants, blocks, block_len);
    w.name = name.to_string();
    w
}

/// The Fig 1 toy: one input dataset of 4 unit blocks (a, b, c, d)
/// coalesced pairwise into x (a++b) and y (c++d), plus a fifth block `e`
/// (its own dataset, consumed by an aggregate task) whose arrival forces
/// the eviction decision the paper analyzes.
pub fn toy_fig1(block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let abcd = dag.input("abcd", 4, block_len);
    dag.coalesce("xy", abcd);
    let e = dag.input("e", 1, block_len);
    dag.aggregate("agg_e", e);
    let ingest_order = vec![
        BlockId::new(abcd, 0), // a
        BlockId::new(abcd, 1), // b
        BlockId::new(abcd, 2), // c
        BlockId::new(abcd, 3), // d
        BlockId::new(e, 0),    // e arrives last, forcing an eviction
    ];
    Workload {
        name: "toy_fig1".into(),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// Cross-validation (paper §II-B's motivating high-reference-count case):
/// one training dataset consumed by `folds` aggregate passes, plus a
/// low-reuse scratch dataset competing for cache.
pub fn cross_validation(folds: u32, blocks: u32, block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let train = dag.input("train", blocks, block_len);
    for f in 0..folds {
        dag.aggregate(&format!("fold{f}"), train);
    }
    let scratch = dag.input("scratch", blocks, block_len);
    dag.partition("shuffle", scratch);
    let ingest_order = dataset_blocks(&dag, train)
        .chain(dataset_blocks(&dag, scratch))
        .collect();
    Workload {
        name: format!("cross_validation(k={folds})"),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// Two-stage pipeline: zip then aggregate (exercises stage cascades and
/// peer-groups over *transform* outputs).
pub fn two_stage_zip_agg(blocks: u32, block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let a = dag.input("A", blocks, block_len);
    let b = dag.input("B", blocks, block_len);
    let c = dag.zip("C", a, b);
    dag.aggregate("D", c);
    let ingest_order = dataset_blocks(&dag, a).chain(dataset_blocks(&dag, b)).collect();
    Workload {
        name: "two_stage_zip_agg".into(),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// Mixed multi-tenant workload: zip, coalesce and zip_reduce jobs side by
/// side (the "representative workloads" extension).
pub fn mixed_tenants(tenants: u32, blocks: u32, block_len: usize) -> Workload {
    let mut dags = Vec::new();
    for j in 0..tenants {
        let mut dag = JobDag::new(JobId(j), j * JOB_ID_STRIDE);
        match j % 3 {
            0 => {
                let a = dag.input("A", blocks, block_len);
                let b = dag.input("B", blocks, block_len);
                dag.zip("kv", a, b);
            }
            1 => {
                let a = dag.input("A", blocks, block_len);
                dag.coalesce("merged", a);
            }
            _ => {
                let a = dag.input("A", blocks, block_len);
                let b = dag.input("B", blocks, block_len);
                dag.zip_reduce("reduced", a, b);
            }
        }
        dags.push(dag);
    }
    let ingest_order = parallel_tenant_ingest(&dags);
    Workload {
        name: format!("mixed_tenants(t={tenants})"),
        dags,
        ingest_order,
        pinned_cache: None,
    }
}

/// A shared-input scenario for the sticky-policy ablation (§III-A): one
/// dataset feeding several binary tasks, so surrendering a shared block
/// hurts multiple groups.
pub fn shared_input(consumers: u32, blocks: u32, block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let shared = dag.input("shared", blocks, block_len);
    for c in 0..consumers {
        let other = dag.input(&format!("other{c}"), blocks, block_len);
        dag.zip(&format!("z{c}"), shared, other);
    }
    let mut ingest_order: Vec<BlockId> = dataset_blocks(&dag, shared).collect();
    for ds in dag.inputs().filter(|d| d.id != shared) {
        ingest_order.extend(ds.blocks());
    }
    Workload {
        name: format!("shared_input(c={consumers})"),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// One job's zip workload for a multi-job queue: `zip(keys, values)`
/// with private dataset ids from [`job_base`]; when `shared`, the key
/// file is the queue-wide shared dataset `DatasetId(0)` instead (50% of
/// this job's input blocks are then shared with every other shared job).
fn multijob_zip_job(j: u32, blocks_per_file: u32, block_len: usize, shared: bool) -> Workload {
    let mut dag = JobDag::new(JobId(j), job_base(j));
    let a = if shared {
        dag.shared_input("shared_keys", DatasetId(0), blocks_per_file, block_len)
    } else {
        dag.input("keys", blocks_per_file, block_len)
    };
    let b = dag.input("values", blocks_per_file, block_len);
    dag.zip("kv", a, b);
    // Per-job ingest order keeps the paper's keys-before-values LRU
    // pathology; the engine dedups shared keys already ingested by an
    // earlier job.
    let ingest_order = dataset_blocks(&dag, a).chain(dataset_blocks(&dag, b)).collect();
    Workload {
        name: format!("zip_job(j={j},shared={shared})"),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// Online multi-job trace: `jobs` zip tenants entering one shared
/// cluster run, spaced `arrival_gap` dispatches apart. With `shared`,
/// every job zips the queue-wide shared key file against its private
/// value file (50% shared input — the cross-job effective-refcount
/// scenario); otherwise inputs are fully private (0% shared).
pub fn multijob_zip_shared(
    jobs: u32,
    blocks_per_file: u32,
    block_len: usize,
    shared: bool,
    arrival_gap: u64,
) -> JobQueue {
    let mut q = JobQueue {
        name: format!(
            "multijob_zip(j={jobs},b={blocks_per_file},shared={}%,gap={arrival_gap})",
            if shared { 50 } else { 0 }
        ),
        jobs: Vec::new(),
    };
    for j in 0..jobs {
        let w = multijob_zip_job(j, blocks_per_file, block_len, shared);
        q.submit(w, j as u64 * arrival_gap, 0);
    }
    q
}

/// Online multi-job trace with Poisson arrivals: exponential
/// inter-arrival gaps (mean `mean_gap` dispatches, deterministic in
/// `seed`) between `jobs` private zip tenants.
pub fn multijob_poisson(
    jobs: u32,
    blocks_per_file: u32,
    block_len: usize,
    mean_gap: f64,
    seed: u64,
) -> JobQueue {
    let mut rng = SplitMix64::new(seed ^ 0xA881_7AB5);
    let mut q = JobQueue {
        name: format!("multijob_poisson(j={jobs},b={blocks_per_file},mean={mean_gap})"),
        jobs: Vec::new(),
    };
    let mut arrival = 0.0f64;
    for j in 0..jobs {
        if j > 0 {
            // Inverse-CDF exponential sample; 1-u keeps ln's argument
            // away from zero.
            arrival += -(1.0 - rng.next_f64()).ln() * mean_gap;
        }
        let w = multijob_zip_job(j, blocks_per_file, block_len, false);
        q.submit(w, arrival.round() as u64, 0);
    }
    q
}

/// Online priority mix: long low-priority batch zips interleaved with
/// short high-priority interactive aggregates, all spaced `arrival_gap`
/// dispatches apart — the scenario where priority dispatch shortens
/// interactive JCT under load.
pub fn multijob_priority_mix(
    jobs: u32,
    blocks_per_file: u32,
    block_len: usize,
    arrival_gap: u64,
) -> JobQueue {
    let mut q = JobQueue {
        name: format!("multijob_priority_mix(j={jobs},b={blocks_per_file})"),
        jobs: Vec::new(),
    };
    for j in 0..jobs {
        let interactive = j % 2 == 1;
        let (w, priority) = if interactive {
            let mut dag = JobDag::new(JobId(j), job_base(j));
            let a = dag.input("probe", (blocks_per_file / 2).max(1), block_len);
            dag.aggregate("answer", a);
            let ingest_order = dataset_blocks(&dag, a).collect();
            (
                Workload {
                    name: format!("interactive(j={j})"),
                    dags: vec![dag],
                    ingest_order,
                    pinned_cache: None,
                },
                3u8,
            )
        } else {
            (multijob_zip_job(j, blocks_per_file, block_len, false), 0u8)
        };
        q.submit(w, j as u64 * arrival_gap, priority);
    }
    q
}

/// Random job DAG for property tests: a chain of 1–4 transforms over 1–2
/// inputs with random ops, deterministic in `seed`.
pub fn random_dag(seed: u64, max_blocks: u32, block_len: usize) -> Workload {
    random_dag_for_job(seed, 0, 0, max_blocks, block_len)
}

/// [`random_dag`] with an explicit job id and dataset-id base, so
/// several random jobs can share one multi-job queue without colliding.
pub fn random_dag_for_job(
    seed: u64,
    job: u32,
    base: u32,
    max_blocks: u32,
    block_len: usize,
) -> Workload {
    let mut rng = SplitMix64::new(seed);
    // Even block count >= 2 so coalesce is always legal.
    let blocks = (2 + 2 * rng.next_below(max_blocks as u64 / 2).max(0)) as u32;
    let mut dag = JobDag::new(JobId(job), base);
    let a = dag.input("A", blocks, block_len);
    let b = dag.input("B", blocks, block_len);
    let mut frontier = vec![a, b];
    let n_transforms = 1 + rng.next_below(4) as usize;
    for t in 0..n_transforms {
        let name = format!("t{t}");
        let pick =
            |rng: &mut SplitMix64, f: &[DatasetId]| f[rng.next_below(f.len() as u64) as usize];
        let x = pick(&mut rng, &frontier);
        // Binary ops need an aligned partner with the same block count
        // and len; only original inputs are guaranteed compatible, so
        // apply binary ops to (a, b) and unary ops anywhere.
        let out = match rng.next_below(4) {
            0 => dag.zip(&name, a, b),
            1 => dag.aggregate(&name, x),
            2 => dag.partition(&name, x),
            _ => dag.zip_reduce(&name, a, b),
        };
        frontier.push(out);
    }
    let ingest_order = dataset_blocks(&dag, a).chain(dataset_blocks(&dag, b)).collect();
    Workload {
        name: format!("random_dag(seed={seed})"),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// Three-stage ETL pipeline exercising Op::Map: map(A) -> M,
/// zip(M, B) -> C, aggregate(C) -> D. Stage-2 peer-groups span a
/// *transform* output and a raw input — the general case of Def. 2.
pub fn etl_pipeline(blocks: u32, block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let a = dag.input("raw", blocks, block_len);
    let b = dag.input("dim", blocks, block_len);
    let m = dag.map("cleaned", a);
    let c = dag.zip("joined", m, b);
    dag.aggregate("rollup", c);
    let ingest_order = dataset_blocks(&dag, a).chain(dataset_blocks(&dag, b)).collect();
    Workload {
        name: "etl_pipeline".into(),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// The spill-tier scenario (DESIGN.md §5): map(A) -> M, map(B) -> N,
/// zip(M, N) -> C, aggregate(C) -> D. Stage-2 peer-groups pair two
/// *transform* blocks that are co-located at one home (index-aligned
/// placement), and M_i sits exposed for the whole span between its map
/// and its partner's — exactly the window in which a tight memory budget
/// demotes it and the pre-dispatch group restore has to bring it back.
/// The consumed intermediates plus the D sinks supply the dead bytes
/// that separate coordinated from naive per-block demotion.
pub fn double_map_zip_agg(blocks: u32, block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let a = dag.input("A", blocks, block_len);
    let b = dag.input("B", blocks, block_len);
    let m = dag.map("M", a);
    let n = dag.map("N", b);
    let c = dag.zip("C", m, n);
    dag.aggregate("D", c);
    let ingest_order = dataset_blocks(&dag, a).chain(dataset_blocks(&dag, b)).collect();
    Workload {
        name: "double_map_zip_agg".into(),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

/// How input blocks arrive during ingest — an ablation axis: the LRU
/// pathology in the paper's §IV depends on the parallel-tenant order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalOrder {
    /// Round-robin across tenants, file A fully before file B per tenant
    /// (the paper's parallel-tenant model; default).
    ParallelTenants,
    /// Each tenant ingests both files completely before the next starts.
    SequentialJobs,
    /// A_i and B_i arrive adjacently (pair-local order).
    Interleaved,
    /// Deterministic shuffle of the whole arrival sequence.
    Shuffled(u64),
}

/// The §IV multi-tenant zip workload with a configurable arrival order.
pub fn multi_tenant_zip_ordered(
    tenants: u32,
    blocks_per_file: u32,
    block_len: usize,
    order: ArrivalOrder,
) -> Workload {
    let mut w = multi_tenant_zip(tenants, blocks_per_file, block_len);
    w.name = format!("{}[{order:?}]", w.name);
    match order {
        ArrivalOrder::ParallelTenants => {}
        ArrivalOrder::SequentialJobs => {
            w.ingest_order = w
                .dags
                .iter()
                .flat_map(|d| {
                    d.inputs()
                        .flat_map(|ds| ds.blocks().collect::<Vec<_>>())
                        .collect::<Vec<_>>()
                })
                .collect();
        }
        ArrivalOrder::Interleaved => {
            w.ingest_order = w
                .dags
                .iter()
                .flat_map(|d| {
                    let a = d.datasets[0].id;
                    let b = d.datasets[1].id;
                    (0..d.datasets[0].num_blocks)
                        .flat_map(move |i| [BlockId::new(a, i), BlockId::new(b, i)])
                        .collect::<Vec<_>>()
                })
                .collect();
        }
        ArrivalOrder::Shuffled(seed) => {
            let mut rng = SplitMix64::new(seed);
            // Fisher-Yates with the deterministic engine RNG.
            let v = &mut w.ingest_order;
            for i in (1..v.len()).rev() {
                let j = rng.next_below(i as u64 + 1) as usize;
                v.swap(i, j);
            }
        }
    }
    w
}

/// Round-robin across tenants: each tenant emits its input datasets in
/// order (file A fully before file B), tenants interleave block-wise.
pub fn parallel_tenant_ingest(dags: &[JobDag]) -> Vec<BlockId> {
    // Per dag: the concatenated list of its input blocks, file-major.
    let per_job: Vec<Vec<BlockId>> = dags
        .iter()
        .map(|d| {
            d.inputs()
                .flat_map(|ds| ds.blocks().collect::<Vec<_>>())
                .collect()
        })
        .collect();
    let max_len = per_job.iter().map(|v| v.len()).max().unwrap_or(0);
    let mut order = Vec::new();
    for i in 0..max_len {
        for job in &per_job {
            if let Some(b) = job.get(i) {
                order.push(*b);
            }
        }
    }
    order
}

fn dataset_blocks(dag: &JobDag, id: DatasetId) -> impl Iterator<Item = BlockId> + '_ {
    dag.dataset(id).blocks()
}

/// Event-core scale workload: one `width`-block input followed by
/// `depth` chained maps — `width * depth` tasks with block-local
/// dependencies only. Every stage exposes `width`-way parallelism, so a
/// large fleet stays saturated while per-task bookkeeping (not DAG
/// fan-in) dominates — exactly what `benches/event_scale.rs` wants to
/// measure about the discrete-event engine itself.
pub fn scale_map_chain(width: u32, depth: u32, block_len: usize) -> Workload {
    let mut dag = JobDag::new(JobId(0), 0);
    let input = dag.input("src", width, block_len);
    let mut prev = input;
    for stage in 0..depth {
        prev = dag.map(&format!("m{stage}"), prev);
    }
    let ingest_order = dataset_blocks(&dag, input).collect();
    Workload {
        name: format!("scale_map_chain(w={width},d={depth})"),
        dags: vec![dag],
        ingest_order,
        pinned_cache: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_tenant_zip_validates() {
        let w = multi_tenant_zip(10, 100, 1024);
        w.validate().unwrap();
        assert_eq!(w.dags.len(), 10);
        assert_eq!(w.task_count(), 1000);
        assert_eq!(w.input_bytes(), 10 * 2 * 100 * 1024 * 4);
        assert_eq!(w.ingest_order.len(), 2000);
    }

    #[test]
    fn ingest_order_keys_before_values_per_tenant() {
        let w = multi_tenant_zip(2, 3, 1024);
        // Per tenant: file A blocks (dataset base+0) must all appear
        // before file B blocks (dataset base+1).
        for dag in &w.dags {
            let a = dag.datasets[0].id;
            let b = dag.datasets[1].id;
            let last_a = w
                .ingest_order
                .iter()
                .rposition(|x| x.dataset == a)
                .unwrap();
            let first_b = w
                .ingest_order
                .iter()
                .position(|x| x.dataset == b)
                .unwrap();
            assert!(last_a < first_b);
        }
    }

    #[test]
    fn double_map_zip_agg_shape() {
        let w = double_map_zip_agg(6, 1024);
        w.validate().unwrap();
        // 6 maps per input + 6 zips + 6 aggs.
        assert_eq!(w.task_count(), 24);
        assert_eq!(w.ingest_order.len(), 12);
        let dag = &w.dags[0];
        // Stage-2 groups pair the two map outputs: both transform blocks.
        let mut next = 0;
        let tasks = crate::dag::task::enumerate_tasks(dag, &mut next);
        let zip = tasks.iter().find(|t| t.kind == "zip_task").expect("zip stage");
        assert_eq!(zip.inputs.len(), 2);
        let inputs: Vec<u32> = zip.inputs.iter().map(|b| b.dataset.0).collect();
        assert!(inputs.iter().all(|d| *d >= 2), "zip reads transform datasets");
    }

    #[test]
    fn toy_fig1_shape() {
        let w = toy_fig1(2048);
        w.validate().unwrap();
        assert_eq!(w.task_count(), 3); // 2 coalesce + 1 aggregate
        assert_eq!(w.ingest_order.len(), 5);
    }

    #[test]
    fn cross_validation_ref_counts() {
        use crate::dag::analysis::RefCounts;
        use crate::dag::task::enumerate_tasks;
        let w = cross_validation(5, 4, 1024);
        w.validate().unwrap();
        let mut next = 0;
        let tasks = enumerate_tasks(&w.dags[0], &mut next);
        let rc = RefCounts::from_tasks(&tasks);
        // Every training block is referenced by all 5 folds.
        let train = w.dags[0].datasets[0].id;
        assert_eq!(rc.get(BlockId::new(train, 0)), 5);
    }

    #[test]
    fn shared_input_and_mixed_validate() {
        shared_input(3, 4, 1024).validate().unwrap();
        mixed_tenants(6, 4, 1024).validate().unwrap();
        two_stage_zip_agg(8, 1024).validate().unwrap();
    }

    #[test]
    fn etl_pipeline_validates_and_uses_map() {
        use crate::dag::ops::Op;
        let w = etl_pipeline(8, 1024);
        w.validate().unwrap();
        assert_eq!(w.task_count(), 24); // map + zip + agg per block
        assert!(w.dags[0].datasets.iter().any(|d| d.op == Op::Map));
    }

    #[test]
    fn arrival_orders_permute_same_blocks() {
        use std::collections::HashSet;
        let base = multi_tenant_zip(3, 4, 1024);
        let want: HashSet<_> = base.ingest_order.iter().copied().collect();
        for order in [
            ArrivalOrder::ParallelTenants,
            ArrivalOrder::SequentialJobs,
            ArrivalOrder::Interleaved,
            ArrivalOrder::Shuffled(7),
        ] {
            let w = multi_tenant_zip_ordered(3, 4, 1024, order);
            w.validate().unwrap();
            let got: HashSet<_> = w.ingest_order.iter().copied().collect();
            assert_eq!(got, want, "{order:?}");
        }
        // Interleaved puts pairs adjacent.
        let w = multi_tenant_zip_ordered(3, 4, 1024, ArrivalOrder::Interleaved);
        let a = w.dags[0].datasets[0].id;
        let b = w.dags[0].datasets[1].id;
        let ia = w.ingest_order.iter().position(|x| *x == BlockId::new(a, 0)).unwrap();
        let ib = w.ingest_order.iter().position(|x| *x == BlockId::new(b, 0)).unwrap();
        assert_eq!(ib, ia + 1);
    }

    #[test]
    fn random_dags_validate_many_seeds() {
        for seed in 0..50 {
            let w = random_dag(seed, 12, 1024);
            w.validate().unwrap();
            assert!(w.task_count() > 0);
        }
    }

    #[test]
    fn multijob_shared_queue_validates_and_shares_keys() {
        let q = multijob_zip_shared(3, 4, 1024, true, 5);
        q.validate().unwrap();
        assert_eq!(q.jobs.len(), 3);
        assert_eq!(q.jobs[1].arrival, 5);
        // Every job's key dataset is the queue-wide shared one.
        for spec in &q.jobs {
            let dag = &spec.workload.dags[0];
            assert_eq!(dag.datasets[0].id, DatasetId(0));
            assert!(spec.workload.ingest_order.contains(&BlockId::new(DatasetId(0), 0)));
        }
        // Unshared variant keeps inputs fully private.
        let p = multijob_zip_shared(3, 4, 1024, false, 5);
        p.validate().unwrap();
        let d0 = p.jobs[0].workload.dags[0].datasets[0].id;
        let d1 = p.jobs[1].workload.dags[0].datasets[0].id;
        assert_ne!(d0, d1);
    }

    #[test]
    fn multijob_poisson_arrivals_are_deterministic_and_monotone() {
        let a = multijob_poisson(6, 4, 1024, 8.0, 17);
        let b = multijob_poisson(6, 4, 1024, 8.0, 17);
        a.validate().unwrap();
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.arrival, y.arrival);
        }
        assert_eq!(a.jobs[0].arrival, 0);
        for w in a.jobs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn priority_mix_alternates_priorities() {
        let q = multijob_priority_mix(4, 6, 1024, 3);
        q.validate().unwrap();
        assert_eq!(q.jobs[0].priority, 0);
        assert_eq!(q.jobs[1].priority, 3);
        assert!(q.jobs[1].workload.task_count() < q.jobs[0].workload.task_count());
    }

    #[test]
    fn random_dags_for_distinct_jobs_form_a_valid_queue() {
        for seed in 0..20 {
            let mut q = JobQueue {
                name: "pair".into(),
                jobs: Vec::new(),
            };
            q.submit(random_dag_for_job(seed, 0, job_base(0), 10, 1024), 0, 0);
            q.submit(random_dag_for_job(seed + 1000, 1, job_base(1), 10, 1024), 4, 1);
            q.validate().unwrap();
        }
    }

    #[test]
    fn scale_map_chain_is_width_times_depth_tasks() {
        let w = scale_map_chain(8, 5, 256);
        w.validate().unwrap();
        assert_eq!(w.task_count(), 8 * 5);
        assert_eq!(w.ingest_order.len(), 8);
    }
}
