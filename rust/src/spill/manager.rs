//! SpillManager: byte-budgeted accounting for one worker's local spill
//! area.
//!
//! The manager is *decision-only*: it decides which demotion sets are
//! admitted, which residents are reclaimed for room, and keeps exact byte
//! accounting. Moving the actual bytes is the engine's job — the threaded
//! engine round-trips real files through a per-worker
//! [`DiskStore`](crate::storage::DiskStore) spill directory, the
//! simulator only charges the §2 cost model — so both engines share one
//! admission/eviction policy and cannot drift on *which* blocks spill.
//!
//! Two disciplines ([`SpillMode`]):
//!
//! * **Coordinated** — an offer is a whole demotion set (a memory victim
//!   plus its gathered live-group co-members) and is admitted
//!   **all-or-nothing**: budget pressure may reclaim only *dead*
//!   residents (blocks no pending task will read again), never a needed
//!   one. A needed block, once spilled, stays spilled until restored.
//! * **PerBlock** — the naive baseline: single-block offers, admitted by
//!   reclaiming the *oldest* residents regardless of need.

use crate::common::config::{SpillConfig, SpillMode};
use crate::common::error::{EngineError, Result};
use crate::common::fxhash::FxHashMap;
use crate::common::ids::BlockId;
use std::collections::VecDeque;

/// The manager's verdict on one demotion offer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OfferOutcome {
    /// Residents reclaimed to make room, in reclamation order. Their
    /// bytes are gone (tier → Dropped); the caller reports/re-plans them.
    pub evicted: Vec<BlockId>,
    /// Whether the offered set was admitted (all of it, or none).
    pub admitted: bool,
}

/// Byte-budgeted residency accounting for one worker's spill area.
#[derive(Debug)]
pub struct SpillManager {
    cfg: SpillConfig,
    resident: FxHashMap<BlockId, u64>,
    /// Admission order; may hold stale ids after [`Self::release`]
    /// (skipped lazily during reclamation scans).
    order: VecDeque<BlockId>,
    used: u64,
}

impl SpillManager {
    pub fn new(cfg: SpillConfig) -> Self {
        Self {
            cfg,
            resident: FxHashMap::default(),
            order: VecDeque::new(),
            used: 0,
        }
    }

    pub fn mode(&self) -> SpillMode {
        self.cfg.mode
    }

    pub fn config(&self) -> &SpillConfig {
        &self.cfg
    }

    pub fn budget(&self) -> u64 {
        self.cfg.budget_per_worker
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.resident.len()
    }

    pub fn is_empty(&self) -> bool {
        self.resident.is_empty()
    }

    pub fn contains(&self, b: BlockId) -> bool {
        self.resident.contains_key(&b)
    }

    /// Resident size of `b` in the spill area, if present.
    pub fn bytes_of(&self, b: BlockId) -> Option<u64> {
        self.resident.get(&b).copied()
    }

    /// Offer a demotion set: all blocks are admitted together or none.
    /// `dead(b)` reports whether resident `b` can be reclaimed freely
    /// (no pending task will read it again); only the Coordinated mode
    /// consults it — PerBlock reclaims oldest-first, need-blind.
    pub fn offer(
        &mut self,
        set: &[(BlockId, u64)],
        dead: impl Fn(BlockId) -> bool,
    ) -> OfferOutcome {
        let total: u64 = set.iter().map(|(_, bytes)| *bytes).sum();
        if total > self.cfg.budget_per_worker || set.is_empty() {
            return OfferOutcome {
                evicted: vec![],
                admitted: false,
            };
        }
        let mut evicted: Vec<BlockId> = Vec::new();
        if self.used + total > self.cfg.budget_per_worker {
            match self.cfg.mode {
                SpillMode::Coordinated => {
                    // Two-phase: find enough *dead* bytes first, refuse
                    // without side effects when they do not exist — a
                    // needed resident is never displaced by an incoming
                    // set (the set is dropped instead; its task will
                    // recompute, which is the cost the coordinated
                    // discipline accepted by keeping the resident).
                    let mut reclaimable: u64 = 0;
                    let mut candidates: Vec<BlockId> = Vec::new();
                    for &b in self.order.iter() {
                        if self.used - reclaimable + total <= self.cfg.budget_per_worker {
                            break;
                        }
                        if let Some(&bytes) = self.resident.get(&b) {
                            if dead(b) && !candidates.contains(&b) {
                                reclaimable += bytes;
                                candidates.push(b);
                            }
                        }
                    }
                    if self.used - reclaimable + total > self.cfg.budget_per_worker {
                        return OfferOutcome {
                            evicted: vec![],
                            admitted: false,
                        };
                    }
                    for b in candidates {
                        self.forget(b);
                        evicted.push(b);
                    }
                }
                SpillMode::PerBlock => {
                    while self.used + total > self.cfg.budget_per_worker {
                        let Some(b) = self.pop_oldest() else {
                            // Resident map empty yet still over: cannot
                            // happen (total <= budget), but refuse safely.
                            return OfferOutcome {
                                evicted,
                                admitted: false,
                            };
                        };
                        evicted.push(b);
                    }
                }
            }
        }
        for &(b, bytes) in set {
            debug_assert!(!self.resident.contains_key(&b), "double-spill of {b}");
            self.resident.insert(b, bytes);
            self.order.push_back(b);
            self.used += bytes;
        }
        OfferOutcome {
            evicted,
            admitted: true,
        }
    }

    /// Oldest resident in admission order (skipping stale entries).
    fn pop_oldest(&mut self) -> Option<BlockId> {
        while let Some(b) = self.order.front().copied() {
            if self.resident.contains_key(&b) {
                self.forget(b);
                return Some(b);
            }
            self.order.pop_front();
        }
        None
    }

    fn forget(&mut self, b: BlockId) {
        if let Some(bytes) = self.resident.remove(&b) {
            self.used -= bytes;
        }
    }

    /// Take `b` out of the spill accounting (restored to memory, purged,
    /// or re-homed away). Returns its resident size, `None` if absent.
    pub fn release(&mut self, b: BlockId) -> Option<u64> {
        let bytes = self.resident.remove(&b)?;
        self.used -= bytes;
        Some(bytes)
    }

    /// Residents in admission order (kill handling, diagnostics).
    pub fn resident_blocks(&self) -> Vec<BlockId> {
        self.order
            .iter()
            .copied()
            .filter(|b| self.resident.contains_key(b))
            .collect()
    }

    /// Wipe the spill area (a worker kill — crash semantics: local spill
    /// dies with its worker). Returns what was resident.
    pub fn clear(&mut self) -> Vec<BlockId> {
        let lost = self.resident_blocks();
        self.resident.clear();
        self.order.clear();
        self.used = 0;
        lost
    }

    /// Byte accounting re-sums exactly and stays within budget.
    pub fn check_invariants(&self) -> Result<()> {
        let recounted: u64 = self.resident.values().sum();
        if recounted != self.used {
            return Err(EngineError::Invariant(format!(
                "spill accounting drifted ({} used vs {} recounted)",
                self.used, recounted
            )));
        }
        if self.used > self.cfg.budget_per_worker {
            return Err(EngineError::Invariant(format!(
                "spill area over budget ({} used vs {} budget)",
                self.used, self.cfg.budget_per_worker
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::SpillConfig;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn coordinated_offer_is_all_or_nothing() {
        let mut m = SpillManager::new(SpillConfig::coordinated(100));
        let out = m.offer(&[(b(1), 40), (b(2), 40)], |_| false);
        assert!(out.admitted && out.evicted.is_empty());
        assert_eq!(m.used(), 80);
        // 40 more does not fit and nothing is dead: refused whole, no
        // side effects.
        let out = m.offer(&[(b(3), 30), (b(4), 10)], |_| false);
        assert!(!out.admitted);
        assert!(out.evicted.is_empty());
        assert_eq!(m.used(), 80);
        assert_eq!(m.len(), 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn coordinated_reclaims_only_dead_residents() {
        let mut m = SpillManager::new(SpillConfig::coordinated(100));
        assert!(m.offer(&[(b(1), 50)], |_| false).admitted);
        assert!(m.offer(&[(b(2), 50)], |_| false).admitted);
        // b1 is dead: reclaiming it makes room; b2 (needed) survives.
        let out = m.offer(&[(b(3), 40)], |x| x == b(1));
        assert!(out.admitted);
        assert_eq!(out.evicted, vec![b(1)]);
        assert!(m.contains(b(2)) && m.contains(b(3)));
        assert_eq!(m.used(), 90);
        m.check_invariants().unwrap();
    }

    #[test]
    fn per_block_reclaims_oldest_blindly() {
        let mut m = SpillManager::new(SpillConfig::per_block(100));
        assert!(m.offer(&[(b(1), 50)], |_| false).admitted);
        assert!(m.offer(&[(b(2), 50)], |_| false).admitted);
        // Naive FIFO: b1 goes even though nothing says it is dead.
        let out = m.offer(&[(b(3), 40)], |_| false);
        assert!(out.admitted);
        assert_eq!(out.evicted, vec![b(1)]);
        assert_eq!(m.used(), 90);
        m.check_invariants().unwrap();
    }

    #[test]
    fn oversized_and_zero_budget_offers_are_refused() {
        let mut m = SpillManager::new(SpillConfig::coordinated(100));
        assert!(!m.offer(&[(b(1), 60), (b(2), 60)], |_| true).admitted);
        assert!(m.is_empty());
        let mut zero = SpillManager::new(SpillConfig::coordinated(0));
        assert!(!zero.offer(&[(b(1), 1)], |_| true).admitted);
        let mut pb = SpillManager::new(SpillConfig::per_block(0));
        assert!(!pb.offer(&[(b(1), 1)], |_| true).admitted);
    }

    #[test]
    fn release_and_clear_keep_accounting_exact() {
        let mut m = SpillManager::new(SpillConfig::coordinated(1000));
        m.offer(&[(b(1), 100), (b(2), 200)], |_| false);
        assert_eq!(m.release(b(1)), Some(100));
        assert_eq!(m.release(b(1)), None);
        assert_eq!(m.used(), 200);
        m.check_invariants().unwrap();
        // Stale order entries are skipped by later reclamation scans.
        assert!(m.offer(&[(b(3), 900)], |_| true).admitted);
        assert_eq!(m.resident_blocks(), vec![b(3)]);
        let lost = m.clear();
        assert_eq!(lost, vec![b(3)]);
        assert_eq!(m.used(), 0);
        assert!(m.is_empty());
        m.check_invariants().unwrap();
    }

    #[test]
    fn per_block_reclamation_order_skips_released_entries() {
        let mut m = SpillManager::new(SpillConfig::per_block(100));
        m.offer(&[(b(1), 40)], |_| false);
        m.offer(&[(b(2), 40)], |_| false);
        m.release(b(1));
        let out = m.offer(&[(b(3), 80)], |_| false);
        assert!(out.admitted);
        assert_eq!(out.evicted, vec![b(2)], "stale b1 skipped, oldest live b2 goes");
    }
}
