//! GroupRestorer: the driver's pre-dispatch view of tier residency.
//!
//! Workers report tier transitions home-routed (only a block's home
//! worker ever demotes or restores it, and only the driver consumes the
//! reports — no broadcasts). The restorer folds those reports into one
//! block → [`BlockTier`] view; before dispatching a task the driver asks
//! for the task's spilled input members and issues the group restore
//! (ctrl messages in the threaded engine, synchronous promotion in the
//! simulator). The view is optimistic — a planned member is marked
//! restored immediately — and the worker-side handler skips entries that
//! are no longer in its spill area, so stale plans degrade to no-ops and
//! the fetch path's read-through/durable fallbacks keep the run correct.
//!
//! On the event-driven simulator the restore charge is a pre-dispatch
//! disk read the dependent task waits on: a flat charge under
//! `NetModel::Flat` (exactly the legacy loop's timing) or a contended
//! disk-channel flow under `NetModel::FairShare` (DESIGN.md §6).

use crate::cache::store::BlockTier;
use crate::common::config::{RestorePolicy, SpillConfig};
use crate::common::fxhash::FxHashMap;
use crate::common::ids::BlockId;

#[derive(Debug)]
pub struct GroupRestorer {
    promote: bool,
    view: FxHashMap<BlockId, BlockTier>,
}

impl GroupRestorer {
    pub fn new(cfg: &SpillConfig) -> Self {
        Self {
            promote: cfg.restore == RestorePolicy::GroupPromote,
            view: FxHashMap::default(),
        }
    }

    /// Does this restorer issue pre-dispatch promotions at all?
    /// (`RestorePolicy::ReadThrough` leaves blocks spilled and lets the
    /// fetch path read them in place.)
    pub fn promotes(&self) -> bool {
        self.promote
    }

    pub fn note_spilled(&mut self, b: BlockId) {
        self.view.insert(b, BlockTier::SpilledLocal);
    }

    pub fn note_dropped(&mut self, b: BlockId) {
        self.view.insert(b, BlockTier::Dropped);
    }

    pub fn note_restored(&mut self, b: BlockId) {
        self.view.insert(b, BlockTier::Memory);
    }

    /// The block re-materialized through the normal insert path (task
    /// completion, recompute) or died with its worker: plain memory rules
    /// apply again.
    pub fn forget(&mut self, b: BlockId) {
        self.view.remove(&b);
    }

    pub fn tier(&self, b: BlockId) -> Option<BlockTier> {
        self.view.get(&b).copied()
    }

    /// Blocks of `inputs` this view believes are spilled — the
    /// pre-dispatch restore set for one task's peer group, promoted as a
    /// whole. Marks them restored optimistically; empty under
    /// [`RestorePolicy::ReadThrough`].
    pub fn plan_restore(&mut self, inputs: &[BlockId]) -> Vec<BlockId> {
        if !self.promote {
            return vec![];
        }
        let set: Vec<BlockId> = inputs
            .iter()
            .copied()
            .filter(|b| self.view.get(b) == Some(&BlockTier::SpilledLocal))
            .collect();
        for &b in &set {
            self.view.insert(b, BlockTier::Memory);
        }
        set
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::SpillMode;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn cfg(restore: RestorePolicy) -> SpillConfig {
        SpillConfig {
            budget_per_worker: 1024,
            mode: SpillMode::Coordinated,
            restore,
        }
    }

    #[test]
    fn plan_restore_selects_spilled_members_and_marks_them() {
        let mut r = GroupRestorer::new(&cfg(RestorePolicy::GroupPromote));
        assert!(r.promotes());
        r.note_spilled(b(1));
        r.note_spilled(b(2));
        r.note_dropped(b(3));
        let set = r.plan_restore(&[b(1), b(2), b(3), b(4)]);
        assert_eq!(set, vec![b(1), b(2)]);
        assert_eq!(r.tier(b(1)), Some(BlockTier::Memory));
        assert_eq!(r.tier(b(3)), Some(BlockTier::Dropped));
        assert_eq!(r.tier(b(4)), None);
        // Already planned: a second task over the same group plans nothing.
        assert!(r.plan_restore(&[b(1), b(2)]).is_empty());
    }

    #[test]
    fn read_through_plans_nothing() {
        let mut r = GroupRestorer::new(&cfg(RestorePolicy::ReadThrough));
        assert!(!r.promotes());
        r.note_spilled(b(1));
        let set = r.plan_restore(&[b(1)]);
        assert!(set.is_empty());
        assert_eq!(r.tier(b(1)), Some(BlockTier::SpilledLocal), "view untouched");
    }

    #[test]
    fn forget_reverts_to_plain_memory_rules() {
        let mut r = GroupRestorer::new(&cfg(RestorePolicy::GroupPromote));
        r.note_dropped(b(1));
        r.forget(b(1));
        assert_eq!(r.tier(b(1)), None);
        assert!(r.plan_restore(&[b(1)]).is_empty());
    }
}
