//! The spill tier: LERC-coordinated memory → local-disk block demotion
//! with pre-dispatch group restore (DESIGN.md §5).
//!
//! LERC's core argument is all-or-nothing: caching part of a task's peer
//! group buys nothing, so evicting one member wastes the memory spent on
//! the rest. The spill tier extends that argument to the *demotion*
//! decision: instead of dropping a victim's bytes, the store demotes the
//! victim's entire remaining local peer group to a budget-bounded,
//! per-worker spill area ([`SpillManager`]) under the §2 disk cost model
//! — all-or-nothing, mirroring `pin_group` — and records residency in
//! [`BlockTier`](crate::cache::store::BlockTier) on the sharded store.
//! On the dispatch path a [`GroupRestorer`] promotes a task's spilled
//! input group back to memory as a whole, so the task still counts a
//! (separately reported) *restored* hit. A block whose bytes leave both
//! tiers is **Dropped**; if a pending task still needs it the driver
//! re-plans it through the lineage machinery
//! ([`crate::recovery::plan_dropped_blocks`]), which is what makes the
//! coordinated discipline measurable: budget spent on dead bytes is
//! budget that later forces a recompute.
//!
//! Everything decision-shaped lives here, shared verbatim by the
//! threaded engine and the simulator so both agree on which groups spill
//! and restore; the engines supply only the byte movement (real files vs
//! modeled cost).

pub mod manager;
pub mod restore;

pub use manager::{OfferOutcome, SpillManager};
pub use restore::GroupRestorer;

use crate::cache::sharded::ShardedStore;
use crate::cache::store::{BlockData, BlockTier, MemoryStore};
use crate::common::config::SpillMode;
use crate::common::ids::BlockId;
use crate::peer::WorkerPeerTracker;

/// Classify one task input read for attribution (DESIGN.md §8): which
/// tier served the bytes. `mem_hit` is "served from some worker's memory
/// store"; `home_tier` is the home store's tier record at read time (the
/// spill read-through path passes it so a spill-area serve is named);
/// `local` is "the home is the reading worker". Shared by both engines
/// so `metrics::attribution` sees identical categories.
pub fn served_from(
    mem_hit: bool,
    home_tier: Option<crate::cache::store::BlockTier>,
    local: bool,
) -> crate::metrics::ServedFrom {
    use crate::metrics::ServedFrom as SF;
    if mem_hit {
        if local {
            SF::LocalMem
        } else {
            SF::RemoteMem
        }
    } else if home_tier == Some(BlockTier::SpilledLocal) {
        SF::Spilled
    } else if local {
        SF::LocalDisk
    } else {
        SF::RemoteDisk
    }
}

/// Stable `u64` encoding of a [`BlockId`] for the tier decision logs
/// (`TierStats::spilled_log` / `restored_log`), which the sim ≡ threaded
/// equivalence tests compare.
pub fn block_key(b: BlockId) -> u64 {
    ((b.dataset.0 as u64) << 32) | b.index as u64
}

/// Does member `m` break a group being registered — materialized
/// somewhere, but neither cached nor restorably spilled at its home
/// store? A SpilledLocal member does **not** break the group: the
/// pre-dispatch restore will promote it. With the spill tier off the
/// tier record is always absent, so this is exactly the pre-spill
/// `materialized && !cached` check. Every group-registration site in
/// both engines (admission, kill recompute, drop recompute) routes
/// through this one predicate so the tier exemption cannot drift.
pub fn member_breaks_group(store: &ShardedStore, materialized: bool, m: BlockId) -> bool {
    materialized && !store.contains(m) && store.tier_of(m) != Some(BlockTier::SpilledLocal)
}

/// What one demotion pass did with a batch of memory evictions.
#[derive(Debug, Default)]
pub struct DemotionOutcome {
    /// Blocks (with payloads) demoted to the spill area — the engine
    /// persists these bytes, charges the spill-write cost, and **only
    /// then** marks each block `BlockTier::SpilledLocal` on the store.
    /// Publishing the tier mark after the bytes are durable is what
    /// keeps remote read-through safe: a reader can never see the mark
    /// while the spill file is missing or half-written (in the window a
    /// miss falls back to the synchronous write-through durable copy).
    pub spilled: Vec<(BlockId, BlockData)>,
    /// Transform victims whose bytes dropped (admission refused or dead):
    /// tier → Dropped; still-needed ones are re-planned by the driver.
    pub dropped: Vec<BlockId>,
    /// Ingest victims: their durable external copies survive, so they
    /// drop exactly as in the spill-less engine (no tier record).
    pub dropped_plain: Vec<BlockId>,
    /// Spill residents reclaimed for budget room: tier → Dropped, same
    /// re-planning rules as `dropped`.
    pub spill_evicted: Vec<BlockId>,
    /// Coordinated demotion sets admitted whole.
    pub groups_demoted: u64,
    pub bytes_spilled: u64,
}

impl DemotionOutcome {
    /// Every block whose bytes are gone — the eviction-report path runs
    /// over these (never over `spilled`: a demotion is a tier transition,
    /// not an eviction, so the peer group stays complete).
    pub fn all_dropped(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.dropped
            .iter()
            .chain(self.dropped_plain.iter())
            .chain(self.spill_evicted.iter())
            .copied()
    }
}

/// Decide the fate of a batch of memory evictions (one insert's victims,
/// with their payloads): demote to the spill tier or drop. Shared by
/// both engines. `Dropped` records are written here; `SpilledLocal`
/// marks are the **caller's** job, after it has persisted the spilled
/// payloads (see [`DemotionOutcome::spilled`] for why the order
/// matters).
///
/// Coordinated mode gathers each victim's locally-resident live-group
/// co-members (unpinned transform blocks only) and offers the set
/// all-or-nothing, refusing blocks no pending task will read again;
/// per-block mode offers each victim alone and lets the manager reclaim
/// oldest-first. See the module docs for why the two differ on recompute
/// counts.
pub fn demote_evicted(
    store: &ShardedStore,
    peers: &WorkerPeerTracker,
    mgr: &mut SpillManager,
    is_transform: impl Fn(BlockId) -> bool,
    evicted: Vec<(BlockId, BlockData)>,
) -> DemotionOutcome {
    let mut out = DemotionOutcome::default();
    for (victim, data) in evicted {
        if !is_transform(victim) {
            out.dropped_plain.push(victim);
            continue;
        }
        let bytes = MemoryStore::bytes_of(&data);
        match mgr.mode() {
            SpillMode::Coordinated => {
                if !peers.unconsumed(victim) {
                    // Dead bytes (consumed intermediate, delivered
                    // result): never spend budget on them.
                    store.set_tier(victim, BlockTier::Dropped);
                    out.dropped.push(victim);
                    continue;
                }
                // The victim's remaining local peer group: live-group
                // co-members still resident here, unpinned, transform.
                let co: Vec<(BlockId, u64)> = peers
                    .live_co_members(victim)
                    .into_iter()
                    .filter(|m| is_transform(*m) && !store.is_pinned(*m))
                    .filter_map(|m| store.peek_bytes(m).map(|by| (m, by)))
                    .collect();
                let mut set = vec![(victim, bytes)];
                set.extend(co.iter().copied());
                let offer = mgr.offer(&set, |b| !peers.unconsumed(b));
                for e in &offer.evicted {
                    store.set_tier(*e, BlockTier::Dropped);
                    out.spill_evicted.push(*e);
                }
                if offer.admitted {
                    out.bytes_spilled += bytes;
                    out.spilled.push((victim, data));
                    for (m, by) in co {
                        match store.remove(m) {
                            Some(payload) => {
                                out.bytes_spilled += by;
                                out.spilled.push((m, payload));
                            }
                            // Pinned or gone since the peek (cannot
                            // happen on the home thread, but stay safe):
                            // back out its share of the admission.
                            None => {
                                mgr.release(m);
                            }
                        }
                    }
                    out.groups_demoted += 1;
                } else {
                    store.set_tier(victim, BlockTier::Dropped);
                    out.dropped.push(victim);
                }
            }
            SpillMode::PerBlock => {
                let offer = mgr.offer(&[(victim, bytes)], |_| false);
                for e in &offer.evicted {
                    store.set_tier(*e, BlockTier::Dropped);
                    out.spill_evicted.push(*e);
                }
                if offer.admitted {
                    out.bytes_spilled += bytes;
                    out.spilled.push((victim, data));
                } else {
                    store.set_tier(victim, BlockTier::Dropped);
                    out.dropped.push(victim);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{PolicyKind, SpillConfig};
    use crate::common::ids::{DatasetId, GroupId, TaskId};
    use crate::dag::analysis::PeerGroup;
    use std::sync::Arc;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(1), i)
    }

    fn ingest(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn payload(words: usize) -> BlockData {
        Arc::from(vec![0.5f32; words])
    }

    fn peers_with(groups: &[(u64, Vec<BlockId>)]) -> WorkerPeerTracker {
        let mut t = WorkerPeerTracker::default();
        let gs: Vec<PeerGroup> = groups
            .iter()
            .map(|(id, members)| PeerGroup {
                id: GroupId(*id),
                task: TaskId(*id),
                members: members.clone(),
                output: b(1000 + *id as u32),
            })
            .collect();
        t.register(&gs, &[]);
        t
    }

    #[test]
    fn member_breaks_group_exempts_spilled_members() {
        let store = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 1);
        // Unmaterialized members never break a group.
        assert!(!member_breaks_group(&store, false, b(1)));
        // Materialized + gone = broken (the pre-spill check).
        assert!(member_breaks_group(&store, true, b(1)));
        // Cached = fine.
        store.insert(b(1), payload(4));
        assert!(!member_breaks_group(&store, true, b(1)));
        // Spilled = restorable, not broken; dropped = broken.
        let _ = store.remove(b(1));
        store.set_tier(b(1), BlockTier::SpilledLocal);
        assert!(!member_breaks_group(&store, true, b(1)));
        store.set_tier(b(1), BlockTier::Dropped);
        assert!(member_breaks_group(&store, true, b(1)));
    }

    #[test]
    fn served_from_covers_the_tier_matrix() {
        use crate::metrics::ServedFrom as SF;
        assert_eq!(served_from(true, None, true), SF::LocalMem);
        assert_eq!(served_from(true, None, false), SF::RemoteMem);
        assert_eq!(
            served_from(false, Some(BlockTier::SpilledLocal), false),
            SF::Spilled
        );
        assert_eq!(served_from(false, None, true), SF::LocalDisk);
        assert_eq!(served_from(false, Some(BlockTier::Dropped), false), SF::RemoteDisk);
    }

    #[test]
    fn block_key_is_injective_over_dataset_and_index() {
        assert_ne!(block_key(b(1)), block_key(ingest(1)));
        assert_eq!(block_key(BlockId::new(DatasetId(2), 3)), (2u64 << 32) | 3);
    }

    #[test]
    fn coordinated_demotes_whole_local_group() {
        let store = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 2);
        let peers = peers_with(&[(0, vec![b(1), b(2), b(3)])]);
        let mut mgr = SpillManager::new(SpillConfig::coordinated(1024));
        // b2 and b3 are resident co-members; b1 was just evicted.
        store.insert(b(2), payload(8));
        store.insert(b(3), payload(8));
        let out = demote_evicted(
            &store,
            &peers,
            &mut mgr,
            |x| x.dataset == DatasetId(1),
            vec![(b(1), payload(8))],
        );
        assert_eq!(out.spilled.len(), 3, "victim + both co-members");
        assert_eq!(out.groups_demoted, 1);
        assert_eq!(out.bytes_spilled, 96);
        assert!(out.dropped.is_empty());
        assert!(!store.contains(b(2)) && !store.contains(b(3)), "co-members left memory");
        for blk in [b(1), b(2), b(3)] {
            assert!(mgr.contains(blk));
            // SpilledLocal marks are published by the caller only after
            // it persisted the bytes (the engines' demote hooks do this).
            assert_eq!(store.tier_of(blk), None);
            store.set_tier(blk, BlockTier::SpilledLocal);
        }
        store.check_invariants().unwrap();
        mgr.check_invariants().unwrap();
    }

    #[test]
    fn coordinated_refusal_drops_victim_only() {
        let store = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 1);
        let peers = peers_with(&[(0, vec![b(1), b(2)])]);
        // Budget too small for the pair: all-or-nothing refuses the set.
        let mut mgr = SpillManager::new(SpillConfig::coordinated(40));
        store.insert(b(2), payload(8));
        let out = demote_evicted(
            &store,
            &peers,
            &mut mgr,
            |_| true,
            vec![(b(1), payload(8))],
        );
        assert!(out.spilled.is_empty());
        assert_eq!(out.dropped, vec![b(1)]);
        assert_eq!(store.tier_of(b(1)), Some(BlockTier::Dropped));
        assert!(store.contains(b(2)), "co-member stays in memory on refusal");
        assert_eq!(store.tier_of(b(2)), None);
        assert_eq!(mgr.used(), 0);
    }

    #[test]
    fn coordinated_never_spills_dead_bytes() {
        let store = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 1);
        let mut peers = peers_with(&[(0, vec![b(1)])]);
        peers.retire_task(TaskId(0)); // consumed: b1 is dead weight
        let mut mgr = SpillManager::new(SpillConfig::coordinated(1024));
        let out = demote_evicted(&store, &peers, &mut mgr, |_| true, vec![(b(1), payload(8))]);
        assert!(out.spilled.is_empty());
        assert_eq!(out.dropped, vec![b(1)]);
        assert_eq!(mgr.used(), 0, "no budget spent on dead bytes");
    }

    #[test]
    fn per_block_spills_everything_and_churns_oldest() {
        let store = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 1);
        let mut peers = peers_with(&[(0, vec![b(1), b(2)]), (1, vec![b(3)])]);
        peers.retire_task(TaskId(1)); // b3 dead — naive mode spills it anyway
        let mut mgr = SpillManager::new(SpillConfig::per_block(64));
        store.insert(b(2), payload(8));
        let out = demote_evicted(
            &store,
            &peers,
            &mut mgr,
            |_| true,
            vec![(b(1), payload(8)), (b(3), payload(8))],
        );
        assert_eq!(out.spilled.len(), 2, "no group gathering, no dead filter");
        assert!(store.contains(b(2)), "per-block never touches co-members");
        assert_eq!(out.groups_demoted, 0);
        // A third victim forces FIFO reclamation of the (needed!) b1.
        let out2 =
            demote_evicted(&store, &peers, &mut mgr, |_| true, vec![(b(4), payload(8))]);
        assert_eq!(out2.spill_evicted, vec![b(1)]);
        assert_eq!(store.tier_of(b(1)), Some(BlockTier::Dropped));
        assert_eq!(out2.spilled.len(), 1, "b4 admitted; caller will mark it");
        assert!(mgr.contains(b(4)));
    }

    #[test]
    fn ingest_victims_drop_plain_without_tier_records() {
        let store = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 1);
        let peers = peers_with(&[(0, vec![ingest(1), b(1)])]);
        let mut mgr = SpillManager::new(SpillConfig::coordinated(1024));
        let out = demote_evicted(
            &store,
            &peers,
            &mut mgr,
            |x| x.dataset == DatasetId(1),
            vec![(ingest(1), payload(8))],
        );
        assert_eq!(out.dropped_plain, vec![ingest(1)]);
        assert!(out.spilled.is_empty());
        assert_eq!(store.tier_of(ingest(1)), None);
        assert_eq!(mgr.used(), 0);
        assert_eq!(out.all_dropped().count(), 1);
    }

    #[test]
    fn pinned_co_members_stay_in_memory() {
        let store = ShardedStore::new(u64::MAX / 2, PolicyKind::Lerc, 1);
        let peers = peers_with(&[(0, vec![b(1), b(2)])]);
        let mut mgr = SpillManager::new(SpillConfig::coordinated(1024));
        store.insert(b(2), payload(8));
        store.pin(b(2));
        let out = demote_evicted(&store, &peers, &mut mgr, |_| true, vec![(b(1), payload(8))]);
        assert_eq!(out.spilled.len(), 1, "only the victim moves");
        assert!(store.contains(b(2)));
        assert_eq!(store.tier_of(b(2)), None);
        mgr.check_invariants().unwrap();
        assert_eq!(mgr.used(), 32, "pinned co-member's bytes not accounted");
    }
}
