//! Control-plane message vocabulary between driver and workers.

use crate::common::ids::{BlockId, GroupId, TaskId};
use crate::dag::analysis::PeerGroup;
use crate::dag::task::Task;
use std::sync::Arc;

/// Driver → worker. Delivered through the two-priority
/// [`EventQueue`](crate::driver::queue::EventQueue): `Ingest`, `RunTask`
/// and `Shutdown` ride the data lane, everything else the control lane.
#[derive(Debug, Clone)]
pub enum WorkerMsg {
    /// Install a peer-group profile (whole profile per worker in
    /// broadcast mode; the member-home subset in home-routed mode).
    /// `incomplete` lists groups the master already knows are broken —
    /// empty at job submission, populated when recovery re-registers a
    /// revived worker so its fresh replica does not resurrect them.
    RegisterPeers {
        groups: Arc<Vec<PeerGroup>>,
        incomplete: Arc<Vec<GroupId>>,
    },
    /// Reference-count updates: absolute `(block, count)` pairs (initial
    /// profile or post-completion deltas; home-routed mode coalesces a
    /// whole drain cycle per destination worker into one message).
    RefCounts(Arc<Vec<(BlockId, u32)>>),
    /// Ingest one input block: generate payload, write to disk, and (when
    /// `cache`) insert into memory. `pin` additionally exempts the block
    /// from eviction (Fig-3 controlled-cache experiments).
    Ingest {
        block: BlockId,
        len: usize,
        cache: bool,
        pin: bool,
    },
    /// Execute a task (the receiving worker is home to the output block).
    RunTask(Arc<Task>),
    /// A block somewhere was evicted out of a complete peer-group.
    EvictionBroadcast(BlockId),
    /// A task completed; retire its peer-group (and release any restore
    /// pins held for it).
    RetireTask(TaskId),
    /// Pre-dispatch group restore (DESIGN.md §5): promote these spilled
    /// blocks — all homed at the receiving worker — back to memory and
    /// pin them until `task` retires. Rides the control lane, so it
    /// lands before any task dispatched behind it on the same worker.
    RestoreGroup {
        task: TaskId,
        blocks: Arc<Vec<BlockId>>,
    },
    /// Drain and exit.
    Shutdown,
}

/// Worker → driver.
#[derive(Debug, Clone)]
pub enum DriverMsg {
    IngestDone {
        block: BlockId,
    },
    /// Local eviction of a block that sat in ≥1 complete peer-group.
    EvictionReport {
        block: BlockId,
    },
    TaskDone {
        task: TaskId,
        /// Worker-measured modeled busy time for this task (I/O + compute).
        busy_nanos: u64,
    },
    /// Home-routed spill-tier transitions at the sending worker (only a
    /// block's home worker ever demotes, drops or restores it, and only
    /// the driver consumes the report — no broadcasts). The driver folds
    /// these into its pre-dispatch tier view and re-plans still-needed
    /// `dropped` blocks through lineage.
    TierReport {
        spilled: Vec<BlockId>,
        /// Transform blocks whose bytes left both tiers.
        dropped: Vec<BlockId>,
        restored: Vec<BlockId>,
    },
    /// A worker hit an unrecoverable error.
    Fatal(String),
}
