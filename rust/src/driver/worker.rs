//! Worker thread: executes ingests and tasks against its own sharded
//! block store, pays modeled I/O costs, reports evictions and completions.
//!
//! Concurrency layout: each worker owns a lock-striped
//! [`ShardedStore`] that peers read *directly* (remote memory hits no
//! longer serialize on the home worker's state lock), plus a small
//! [`WorkerState`] mutex covering only the peer tracker and the access
//! counters. Only the home worker thread ever inserts into (and therefore
//! evicts from) its own store; remote readers do record policy Access
//! events on the home shard, so recency state interleaves as on a real
//! cluster — exact replay is the simulator's job ([`crate::sim`]).

use crate::cache::policy::PolicyEvent;
use crate::cache::sharded::ShardedStore;
use crate::common::config::EngineConfig;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, GroupId, JobId, WorkerId};
use crate::common::rng::block_payload;
use crate::dag::task::Task;
use crate::driver::messages::{DriverMsg, WorkerMsg};
use crate::driver::queue::EventQueue;
use crate::metrics::AccessStats;
use crate::peer::WorkerPeerTracker;
use crate::runtime::pjrt::ComputeHandle;
use crate::scheduler::AliveSet;
use crate::storage::DiskStore;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Mutable per-worker bookkeeping (peer tracker + counters). Block data
/// lives outside this lock, in [`WorkerNode::store`].
pub struct WorkerState {
    pub peers: WorkerPeerTracker,
    pub access: AccessStats,
    /// Access accounting attributed to the job whose task did the read
    /// (multi-job runs report per-job hit/effective ratios from this;
    /// ingest traffic has no job attribution and is not counted here).
    pub per_job_access: FxHashMap<JobId, AccessStats>,
    /// Modeled busy time accumulated by this worker (nanoseconds).
    pub busy_nanos: u64,
}

impl WorkerState {
    pub fn new() -> Self {
        Self {
            peers: WorkerPeerTracker::default(),
            access: AccessStats::default(),
            per_job_access: FxHashMap::default(),
            busy_nanos: 0,
        }
    }
}

impl Default for WorkerState {
    fn default() -> Self {
        Self::new()
    }
}

/// One worker's shareable surface: the lock-striped block store (read
/// directly by peers) and the state mutex (tracker + counters).
pub struct WorkerNode {
    pub state: Mutex<WorkerState>,
    pub store: ShardedStore,
}

impl WorkerNode {
    pub fn new(cfg: &EngineConfig) -> Self {
        Self {
            state: Mutex::new(WorkerState::new()),
            store: ShardedStore::new(cfg.cache_capacity_per_worker, cfg.policy, cfg.cache_shards),
        }
    }
}

pub type SharedWorkers = Arc<Vec<WorkerNode>>;

/// Everything a worker thread needs.
pub struct WorkerContext {
    pub id: WorkerId,
    pub cfg: EngineConfig,
    pub shared: SharedWorkers,
    pub disk: Arc<DiskStore>,
    pub compute: ComputeHandle,
    pub driver_tx: Sender<DriverMsg>,
    /// Global modeled-time counter for net-latency accounting (nanos).
    pub net_nanos: Arc<AtomicU64>,
    /// The driver's failure-aware worker-liveness view: block lookups
    /// must follow re-homing after a kill/restart. The driver only
    /// mutates it at quiescent points (no task in flight anywhere).
    pub alive: Arc<RwLock<AliveSet>>,
}

impl WorkerContext {
    fn me(&self) -> &WorkerNode {
        &self.shared[self.id.0 as usize]
    }

    /// Failure-aware home of `b` (equals `scheduler::home_worker` until a
    /// worker dies).
    fn home_of(&self, b: BlockId) -> WorkerId {
        self.alive.read().expect("alive lock poisoned").home_of(b)
    }

    /// Pay a modeled cost: sleep scaled, record modeled nanos.
    fn pay(&self, cost: Duration) -> u64 {
        if !cost.is_zero() {
            let scaled = cost.mul_f64(self.cfg.time_scale);
            if !scaled.is_zero() {
                std::thread::sleep(scaled);
            }
        }
        cost.as_nanos() as u64
    }

    /// After evictions, consult the peer tracker and report if required.
    /// Only peer-aware policies run the §III-C protocol (the paper's
    /// overhead accounting applies to LERC/Sticky runs only).
    fn report_evictions(&self, evicted: &[BlockId]) {
        if !self.cfg.policy.peer_aware() || evicted.is_empty() {
            return;
        }
        let st = self.me().state.lock().unwrap();
        for &b in evicted {
            if st.peers.should_report_eviction(b) {
                let _ = self.driver_tx.send(DriverMsg::EvictionReport { block: b });
            }
        }
    }

    fn handle_ingest(&self, block: BlockId, len: usize, cache: bool, pin: bool) {
        let payload = Arc::new(block_payload(
            self.cfg.seed,
            block.dataset.0 as u64,
            block.index,
            len,
        ));
        // Write-through to the disk tier (the durable copy), then cache.
        let cost = match self.disk.write(block, &payload) {
            Ok(c) => c,
            Err(e) => {
                let _ = self.driver_tx.send(DriverMsg::Fatal(e.to_string()));
                return;
            }
        };
        let busy = self.pay(cost);
        let node = self.me();
        node.state.lock().unwrap().busy_nanos += busy;
        if cache {
            if pin {
                node.store.pin(block);
            }
            let outcome = node.store.insert(block, payload);
            self.report_evictions(&outcome.evicted);
        }
        let _ = self.driver_tx.send(DriverMsg::IngestDone { block });
    }

    /// Fetch one input block: local memory → remote memory → disk.
    /// Returns (payload, served_from_memory, modeled_cost, home). The
    /// cost is NOT paid here — input streams are concurrent (HDFS-style),
    /// so the caller pays the max over all inputs. This is what produces
    /// the paper's Fig 3 staircase: caching one of two peers does not
    /// shorten the task. The resolved home rides along so the caller
    /// does not re-acquire the alive lock on the hot path.
    fn fetch_input(
        &self,
        block: BlockId,
        job: JobId,
    ) -> Result<(Arc<Vec<f32>>, bool, Duration, WorkerId), String> {
        let home = self.home_of(block);
        // Memory tier: hit the home worker's sharded store directly —
        // no worker-level lock, remote or local.
        let hit = self.shared[home.0 as usize].store.get(block);
        {
            let mut st = self.me().state.lock().unwrap();
            st.access.accesses += 1;
            let ja = st.per_job_access.entry(job).or_default();
            ja.accesses += 1;
            if hit.is_some() {
                st.access.mem_hits += 1;
                ja.mem_hits += 1;
                if home != self.id {
                    st.access.remote_hits += 1;
                    ja.remote_hits += 1;
                }
            }
        }
        if let Some(data) = hit {
            // Memory path is deserialization-bound (see MemConfig);
            // remote hits additionally pay one network latency.
            let mut cost = self.cfg.mem.read_cost((data.len() * 4) as u64);
            if home != self.id {
                cost = cost.max(self.cfg.net.per_message_latency);
            }
            return Ok((data, true, cost, home));
        }
        // Disk tier.
        let (data, cost) = self.disk.read(block).map_err(|e| e.to_string())?;
        {
            let mut st = self.me().state.lock().unwrap();
            let bytes = (data.len() * 4) as u64;
            st.access.disk_reads += 1;
            st.access.disk_bytes += bytes;
            let ja = st.per_job_access.entry(job).or_default();
            ja.disk_reads += 1;
            ja.disk_bytes += bytes;
        }
        // NOTE: no re-promotion to memory on disk read (Spark 1.6
        // semantics for evicted blocks) — re-caching would fight the
        // experiment; see DESIGN.md.
        Ok((Arc::new(data), false, cost, home))
    }

    fn handle_task(&self, task: &Task) {
        let mut busy = 0u64;
        let mut inputs: Vec<Arc<Vec<f32>>> = Vec::with_capacity(task.inputs.len());
        let mut from_mem = Vec::with_capacity(task.inputs.len());
        // Local in-memory inputs to pin while the task is in flight.
        let mut local_mem: Vec<BlockId> = Vec::new();
        let mut fetch_cost = Duration::ZERO;
        for &b in &task.inputs {
            match self.fetch_input(b, task.job) {
                Ok((data, mem, cost, home)) => {
                    fetch_cost = fetch_cost.max(cost);
                    if mem && home == self.id {
                        local_mem.push(b);
                    }
                    inputs.push(data);
                    from_mem.push(mem);
                }
                Err(e) => {
                    let _ = self.driver_tx.send(DriverMsg::Fatal(format!(
                        "task {}: fetch {b}: {e}",
                        task.id
                    )));
                    return;
                }
            }
        }
        // Pin the locally-cached slice of this task's peer-group as one
        // atomic sticky set (all-or-nothing across shards). Group ids
        // reuse the task id value (see dag::analysis::peer_groups).
        let gid = GroupId(task.id.0);
        let group_pinned = !local_mem.is_empty() && self.me().store.pin_group(gid, &local_mem);
        // Pay the concurrent-stream fetch cost once (max over inputs).
        busy += self.pay(fetch_cost);
        // Effective-hit accounting (Def. 1): hits are effective iff every
        // peer was served from memory.
        let all_mem = from_mem.iter().all(|&m| m);
        if all_mem {
            let mut st = self.me().state.lock().unwrap();
            let arity = task.inputs.len() as u64;
            st.access.effective_hits += arity;
            st.per_job_access.entry(task.job).or_default().effective_hits += arity;
        }

        // Compute through the (PJRT or synthetic) service.
        let t0 = std::time::Instant::now();
        let result = self.compute.execute(&task.kind, task.input_len, inputs);
        let compute_wall = t0.elapsed();
        busy += compute_wall.as_nanos() as u64;

        let output = match result {
            Ok(out) => out,
            Err(e) => {
                let _ = self
                    .driver_tx
                    .send(DriverMsg::Fatal(format!("task {}: {e}", task.id)));
                return;
            }
        };
        debug_assert_eq!(output.payload.len(), task.output_len);

        // Unpin inputs, persist + cache the output. The disk copy always
        // happens (durability / downstream disk reads) but its cost is on
        // the critical path only in sync mode (Spark uses an async writer).
        let payload = Arc::new(output.payload);
        let cost = match self.disk.write(task.output, &payload) {
            Ok(c) => c,
            Err(e) => {
                let _ = self.driver_tx.send(DriverMsg::Fatal(e.to_string()));
                return;
            }
        };
        if self.cfg.sync_output_writes {
            busy += self.pay(cost);
        }
        let node = self.me();
        if group_pinned {
            node.store.unpin_group(gid);
        }
        let outcome = node.store.insert(task.output, payload);
        self.report_evictions(&outcome.evicted);
        node.state.lock().unwrap().busy_nanos += busy;
        let _ = self.driver_tx.send(DriverMsg::TaskDone {
            task: task.id,
            busy_nanos: busy,
        });
    }

    fn apply_eviction_broadcast(&self, block: BlockId) {
        // Delivery latency of the broadcast.
        let busy = self.pay(self.cfg.net.per_message_latency);
        let node = self.me();
        let (deltas, broken) = {
            let mut st = node.state.lock().unwrap();
            st.busy_nanos += busy;
            st.peers.apply_eviction_broadcast(block)
        };
        for (b, count) in deltas {
            node.store
                .policy_event(PolicyEvent::EffectiveCount { block: b, count });
        }
        if !broken.is_empty() {
            node.store
                .policy_event(PolicyEvent::GroupBroken { members: &broken });
        }
    }

    fn retire(&self, task: crate::common::ids::TaskId) {
        let node = self.me();
        let deltas = node.state.lock().unwrap().peers.retire_task(task);
        for (b, count) in deltas {
            node.store
                .policy_event(PolicyEvent::EffectiveCount { block: b, count });
        }
    }
}

/// Handle one control-plane message (peer/DAG bookkeeping). The event
/// queue dequeues these with strict priority over the data lane,
/// mirroring Spark's separate block-manager dispatcher — an eviction
/// broadcast must not queue behind pending ingests/tasks or LERC's
/// effective counts go stale exactly when eviction pressure is highest.
fn handle_ctrl(ctx: &WorkerContext, msg: WorkerMsg) {
    let peer_aware = ctx.cfg.policy.peer_aware();
    let dag_aware = ctx.cfg.policy.dag_aware();
    match msg {
        WorkerMsg::RegisterPeers { groups, incomplete } => {
            let node = ctx.me();
            let seeds: Vec<(BlockId, u32)> = {
                let mut st = node.state.lock().unwrap();
                st.peers.register(&groups, &incomplete);
                if peer_aware {
                    // Seed effective counts so the policy starts informed.
                    let blocks: FxHashSet<BlockId> = groups
                        .iter()
                        .flat_map(|g| g.members.iter().copied())
                        .collect();
                    blocks
                        .into_iter()
                        .map(|b| (b, st.peers.effective_count(b)))
                        .collect()
                } else {
                    Vec::new()
                }
            };
            for (b, count) in seeds {
                node.store
                    .policy_event(PolicyEvent::EffectiveCount { block: b, count });
            }
        }
        WorkerMsg::RefCounts(updates) => {
            if dag_aware {
                let node = ctx.me();
                for &(b, count) in updates.iter() {
                    node.store.policy_event(PolicyEvent::RefCount { block: b, count });
                }
            }
        }
        WorkerMsg::EvictionBroadcast(block) => {
            if peer_aware {
                ctx.apply_eviction_broadcast(block);
            } else {
                // Trackers still maintain state for metrics parity.
                let mut st = ctx.me().state.lock().unwrap();
                st.peers.apply_eviction_broadcast(block);
            }
        }
        WorkerMsg::RetireTask(task) => ctx.retire(task),
        WorkerMsg::Ingest { .. } | WorkerMsg::RunTask(_) | WorkerMsg::Shutdown => {
            unreachable!("data-plane message in the control handler")
        }
    }
}

/// Worker thread main loop over the two-priority event queue: control
/// messages always drain before the next data op (so a task dequeued for
/// execution has every already-delivered count applied), and an idle
/// worker sleeps on the queue's condvar instead of polling.
///
/// A panic anywhere in message handling is reported to the driver as
/// [`DriverMsg::Fatal`] before the thread dies — queue sends are
/// infallible, so without this the driver would wait forever on a
/// completion that can no longer arrive (the mpsc engine surfaced the
/// same condition as a channel disconnect).
pub fn worker_loop(ctx: WorkerContext, queue: Arc<EventQueue>) {
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        while let Some(msg) = queue.recv() {
            match msg {
                WorkerMsg::Ingest {
                    block,
                    len,
                    cache,
                    pin,
                } => ctx.handle_ingest(block, len, cache, pin),
                WorkerMsg::RunTask(task) => ctx.handle_task(&task),
                WorkerMsg::Shutdown => break,
                other => handle_ctrl(&ctx, other),
            }
        }
    }));
    if let Err(panic) = run {
        let what = panic
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| panic.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "opaque panic payload".into());
        let _ = ctx
            .driver_tx
            .send(DriverMsg::Fatal(format!("worker {} panicked: {what}", ctx.id.0)));
    }
}
