//! Worker thread: executes ingests and tasks against its own block
//! manager, pays modeled I/O costs, reports evictions and completions.

use crate::block::manager::BlockManager;
use crate::cache::policy::PolicyEvent;
use crate::common::config::EngineConfig;
use crate::common::ids::{BlockId, WorkerId};
use crate::common::rng::block_payload;
use crate::dag::task::Task;
use crate::driver::messages::{DriverMsg, WorkerMsg};
use crate::metrics::AccessStats;
use crate::peer::WorkerPeerTracker;
use crate::runtime::pjrt::ComputeHandle;
use crate::scheduler::home_worker;
use crate::storage::DiskStore;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Mutable per-worker state, lockable by peers for remote reads.
pub struct WorkerState {
    pub bm: BlockManager,
    pub peers: WorkerPeerTracker,
    pub access: AccessStats,
    /// Modeled busy time accumulated by this worker (nanoseconds).
    pub busy_nanos: u64,
}

impl WorkerState {
    pub fn new(cfg: &EngineConfig) -> Self {
        Self {
            bm: BlockManager::new(cfg.cache_capacity_per_worker, cfg.policy),
            peers: WorkerPeerTracker::default(),
            access: AccessStats::default(),
            busy_nanos: 0,
        }
    }
}

pub type SharedWorkers = Arc<Vec<Mutex<WorkerState>>>;

/// Everything a worker thread needs.
pub struct WorkerContext {
    pub id: WorkerId,
    pub cfg: EngineConfig,
    pub shared: SharedWorkers,
    pub disk: Arc<DiskStore>,
    pub compute: ComputeHandle,
    pub driver_tx: Sender<DriverMsg>,
    /// Global modeled-time counter for net-latency accounting (nanos).
    pub net_nanos: Arc<AtomicU64>,
}

impl WorkerContext {
    fn me(&self) -> &Mutex<WorkerState> {
        &self.shared[self.id.0 as usize]
    }

    /// Pay a modeled cost: sleep scaled, record modeled nanos.
    fn pay(&self, cost: Duration) -> u64 {
        if !cost.is_zero() {
            let scaled = cost.mul_f64(self.cfg.time_scale);
            if !scaled.is_zero() {
                std::thread::sleep(scaled);
            }
        }
        cost.as_nanos() as u64
    }

    /// After evictions, consult the peer tracker and report if required.
    /// Only peer-aware policies run the §III-C protocol (the paper's
    /// overhead accounting applies to LERC/Sticky runs only).
    fn report_evictions(&self, st: &mut WorkerState, evicted: &[BlockId]) {
        if !self.cfg.policy.peer_aware() {
            return;
        }
        for &b in evicted {
            if st.peers.should_report_eviction(b) {
                let _ = self.driver_tx.send(DriverMsg::EvictionReport { block: b });
            }
        }
    }

    fn handle_ingest(&self, block: BlockId, len: usize, cache: bool, pin: bool) {
        let payload = Arc::new(block_payload(
            self.cfg.seed,
            block.dataset.0 as u64,
            block.index,
            len,
        ));
        // Write-through to the disk tier (the durable copy), then cache.
        let cost = match self.disk.write(block, &payload) {
            Ok(c) => c,
            Err(e) => {
                let _ = self.driver_tx.send(DriverMsg::Fatal(e.to_string()));
                return;
            }
        };
        let busy = self.pay(cost);
        {
            let mut st = self.me().lock().unwrap();
            st.busy_nanos += busy;
            if cache {
                if pin {
                    st.bm.pin(block);
                }
                let outcome = st.bm.insert(block, payload);
                self.report_evictions(&mut st, &outcome.evicted);
            }
        }
        let _ = self.driver_tx.send(DriverMsg::IngestDone { block });
    }

    /// Fetch one input block: local memory → remote memory → disk.
    /// Returns (payload, served_from_memory, modeled_cost). The cost is
    /// NOT paid here — input streams are concurrent (HDFS-style), so the
    /// caller pays the max over all inputs. This is what produces the
    /// paper's Fig 3 staircase: caching one of two peers does not shorten
    /// the task.
    fn fetch_input(&self, block: BlockId) -> Result<(Arc<Vec<f32>>, bool, Duration), String> {
        let home = home_worker(block, self.cfg.num_workers);
        if home == self.id {
            let hit = {
                let mut st = self.me().lock().unwrap();
                st.access.accesses += 1;
                st.bm.get(block)
            };
            if let Some(data) = hit {
                let mut st = self.me().lock().unwrap();
                st.access.mem_hits += 1;
                // Memory path is deserialization-bound (see MemConfig).
                let cost = self.cfg.mem.read_cost((data.len() * 4) as u64);
                return Ok((data, true, cost));
            }
        } else {
            // Remote read: lock the home worker's state briefly.
            let hit = {
                let mut st = self.shared[home.0 as usize].lock().unwrap();
                st.bm.get(block)
            };
            {
                let mut st = self.me().lock().unwrap();
                st.access.accesses += 1;
            }
            if let Some(data) = hit {
                let mut st = self.me().lock().unwrap();
                st.access.mem_hits += 1;
                st.access.remote_hits += 1;
                let cost = self
                    .cfg
                    .mem
                    .read_cost((data.len() * 4) as u64)
                    .max(self.cfg.net.per_message_latency);
                return Ok((data, true, cost));
            }
        }
        // Disk tier.
        let (data, cost) = self.disk.read(block).map_err(|e| e.to_string())?;
        {
            let mut st = self.me().lock().unwrap();
            st.access.disk_reads += 1;
            st.access.disk_bytes += (data.len() * 4) as u64;
        }
        // NOTE: no re-promotion to memory on disk read (Spark 1.6
        // semantics for evicted blocks) — re-caching would fight the
        // experiment; see DESIGN.md.
        Ok((Arc::new(data), false, cost))
    }

    fn handle_task(&self, task: &Task) {
        let mut busy = 0u64;
        let mut inputs: Vec<Arc<Vec<f32>>> = Vec::with_capacity(task.inputs.len());
        let mut from_mem = Vec::with_capacity(task.inputs.len());
        // Pin local inputs while the task is in flight.
        let mut pinned: Vec<BlockId> = Vec::new();
        let mut fetch_cost = Duration::ZERO;
        for &b in &task.inputs {
            match self.fetch_input(b) {
                Ok((data, mem, cost)) => {
                    fetch_cost = fetch_cost.max(cost);
                    if mem && home_worker(b, self.cfg.num_workers) == self.id {
                        let mut st = self.me().lock().unwrap();
                        st.bm.pin(b);
                        pinned.push(b);
                    }
                    inputs.push(data);
                    from_mem.push(mem);
                }
                Err(e) => {
                    let _ = self.driver_tx.send(DriverMsg::Fatal(format!(
                        "task {}: fetch {b}: {e}",
                        task.id
                    )));
                    return;
                }
            }
        }
        // Pay the concurrent-stream fetch cost once (max over inputs).
        busy += self.pay(fetch_cost);
        // Effective-hit accounting (Def. 1): hits are effective iff every
        // peer was served from memory.
        let all_mem = from_mem.iter().all(|&m| m);
        if all_mem {
            let mut st = self.me().lock().unwrap();
            st.access.effective_hits += task.inputs.len() as u64;
        }

        // Compute through the (PJRT or synthetic) service.
        let t0 = std::time::Instant::now();
        let result = self
            .compute
            .execute(&task.kind, task.input_len, inputs);
        let compute_wall = t0.elapsed();
        busy += compute_wall.as_nanos() as u64;

        let output = match result {
            Ok(out) => out,
            Err(e) => {
                let _ = self
                    .driver_tx
                    .send(DriverMsg::Fatal(format!("task {}: {e}", task.id)));
                return;
            }
        };
        debug_assert_eq!(output.payload.len(), task.output_len);

        // Unpin inputs, persist + cache the output. The disk copy always
        // happens (durability / downstream disk reads) but its cost is on
        // the critical path only in sync mode (Spark uses an async writer).
        let payload = Arc::new(output.payload);
        let cost = match self.disk.write(task.output, &payload) {
            Ok(c) => c,
            Err(e) => {
                let _ = self.driver_tx.send(DriverMsg::Fatal(e.to_string()));
                return;
            }
        };
        if self.cfg.sync_output_writes {
            busy += self.pay(cost);
        }
        {
            let mut st = self.me().lock().unwrap();
            for b in pinned {
                st.bm.unpin(b);
            }
            let outcome = st.bm.insert(task.output, payload);
            self.report_evictions(&mut st, &outcome.evicted);
            st.busy_nanos += busy;
        }
        let _ = self.driver_tx.send(DriverMsg::TaskDone {
            task: task.id,
            busy_nanos: busy,
        });
    }

    fn apply_eviction_broadcast(&self, block: BlockId) {
        // Delivery latency of the broadcast.
        let busy = self.pay(self.cfg.net.per_message_latency);
        let mut st = self.me().lock().unwrap();
        st.busy_nanos += busy;
        let (deltas, broken) = st.peers.apply_eviction_broadcast(block);
        for (b, count) in deltas {
            st.bm
                .policy_event(PolicyEvent::EffectiveCount { block: b, count });
        }
        if !broken.is_empty() {
            st.bm
                .policy_event(PolicyEvent::GroupBroken { members: &broken });
        }
    }

    fn retire(&self, task: crate::common::ids::TaskId) {
        let mut st = self.me().lock().unwrap();
        let deltas = st.peers.retire_task(task);
        for (b, count) in deltas {
            st.bm
                .policy_event(PolicyEvent::EffectiveCount { block: b, count });
        }
    }
}

/// Handle one control-plane message (peer/DAG bookkeeping). These run on
/// a dedicated channel with priority over the data plane, mirroring
/// Spark's separate block-manager dispatcher — an eviction broadcast must
/// not queue behind pending ingests/tasks or LERC's effective counts go
/// stale exactly when eviction pressure is highest.
fn handle_ctrl(ctx: &WorkerContext, msg: WorkerMsg) {
    let peer_aware = ctx.cfg.policy.peer_aware();
    let dag_aware = ctx.cfg.policy.dag_aware();
    match msg {
        WorkerMsg::RegisterPeers(groups) => {
            let mut st = ctx.me().lock().unwrap();
            st.peers.register(&groups, &[]);
            if peer_aware {
                // Seed effective counts so the policy starts informed.
                let blocks: std::collections::HashSet<BlockId> = groups
                    .iter()
                    .flat_map(|g| g.members.iter().copied())
                    .collect();
                for b in blocks {
                    let count = st.peers.effective_count(b);
                    st.bm
                        .policy_event(PolicyEvent::EffectiveCount { block: b, count });
                }
            }
        }
        WorkerMsg::RefCounts(updates) => {
            if dag_aware {
                let mut st = ctx.me().lock().unwrap();
                for &(b, count) in updates.iter() {
                    st.bm.policy_event(PolicyEvent::RefCount { block: b, count });
                }
            }
        }
        WorkerMsg::EvictionBroadcast(block) => {
            if peer_aware {
                ctx.apply_eviction_broadcast(block);
            } else {
                // Trackers still maintain state for metrics parity.
                let mut st = ctx.me().lock().unwrap();
                st.peers.apply_eviction_broadcast(block);
            }
        }
        WorkerMsg::RetireTask(task) => ctx.retire(task),
        WorkerMsg::Ingest { .. } | WorkerMsg::RunTask(_) | WorkerMsg::Shutdown => {
            unreachable!("data-plane message on control channel")
        }
    }
}

/// Drain all pending control messages (non-blocking).
fn drain_ctrl(ctx: &WorkerContext, ctrl_rx: &Receiver<WorkerMsg>) {
    while let Ok(msg) = ctrl_rx.try_recv() {
        handle_ctrl(ctx, msg);
    }
}

/// Worker thread main loop: control channel has strict priority over the
/// data channel.
pub fn worker_loop(ctx: WorkerContext, data_rx: Receiver<WorkerMsg>, ctrl_rx: Receiver<WorkerMsg>) {
    loop {
        drain_ctrl(&ctx, &ctrl_rx);
        // Grab the next data op without blocking so freshly arrived
        // control traffic is never starved; park briefly when idle.
        match data_rx.try_recv() {
            Ok(WorkerMsg::Ingest {
                block,
                len,
                cache,
                pin,
            }) => {
                ctx.handle_ingest(block, len, cache, pin);
            }
            Ok(WorkerMsg::RunTask(task)) => {
                // Apply any control updates that raced in while we were
                // dequeuing — eviction decisions see fresh counts.
                drain_ctrl(&ctx, &ctrl_rx);
                ctx.handle_task(&task);
            }
            Ok(WorkerMsg::Shutdown) => break,
            Ok(other) => handle_ctrl(&ctx, other), // tolerated misroute
            Err(std::sync::mpsc::TryRecvError::Empty) => {
                // Idle: block on the control channel with a short timeout
                // so either channel wakes us.
                match ctrl_rx.recv_timeout(std::time::Duration::from_micros(200)) {
                    Ok(msg) => handle_ctrl(&ctx, msg),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        // Control side gone; keep serving data until
                        // Shutdown arrives or the data side disconnects.
                        match data_rx.recv() {
                            Ok(WorkerMsg::Shutdown) | Err(_) => break,
                            Ok(WorkerMsg::Ingest {
                                block,
                                len,
                                cache,
                                pin,
                            }) => ctx.handle_ingest(block, len, cache, pin),
                            Ok(WorkerMsg::RunTask(task)) => ctx.handle_task(&task),
                            Ok(other) => handle_ctrl(&ctx, other),
                        }
                    }
                }
            }
            Err(std::sync::mpsc::TryRecvError::Disconnected) => break,
        }
    }
}
