//! Worker thread: executes ingests and tasks against its own sharded
//! block store, pays modeled I/O costs, reports evictions and completions.
//!
//! Concurrency layout: each worker owns a lock-striped
//! [`ShardedStore`] that peers read *directly* (remote memory hits no
//! longer serialize on the home worker's state lock), plus a small
//! [`WorkerState`] mutex covering only the peer tracker and the access
//! counters. Only the home worker thread ever inserts into (and therefore
//! evicts from) its own store; remote readers do record policy Access
//! events on the home shard, so recency state interleaves as on a real
//! cluster — exact replay is the simulator's job ([`crate::sim`]).

use crate::cache::policy::PolicyEvent;
use crate::cache::sharded::ShardedStore;
use crate::cache::store::{BlockData, BlockTier};
use crate::common::config::EngineConfig;
use crate::common::error::Result;
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, GroupId, JobId, TaskId, WorkerId};
use crate::common::rng::block_payload;
use crate::dag::task::Task;
use crate::driver::messages::{DriverMsg, WorkerMsg};
use crate::driver::queue::EventQueue;
use crate::metrics::attribution::{attribute_group, ServedFrom};
use crate::metrics::{AccessStats, AttributionStats, TierStats};
use crate::peer::WorkerPeerTracker;
use crate::recovery::RecomputeSet;
use crate::runtime::pjrt::ComputeHandle;
use crate::scheduler::AliveSet;
use crate::spill::{block_key, demote_evicted, served_from, SpillManager};
use crate::trace::TraceEvent;
use crate::storage::tiered::{self, TierSource};
use crate::storage::DiskStore;
use std::path::PathBuf;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// Mutable per-worker bookkeeping (peer tracker + counters). Block data
/// lives outside this lock, in [`WorkerNode::store`].
pub struct WorkerState {
    pub peers: WorkerPeerTracker,
    pub access: AccessStats,
    /// Access accounting attributed to the job whose task did the read
    /// (multi-job runs report per-job hit/effective ratios from this;
    /// ingest traffic has no job attribution and is not counted here).
    pub per_job_access: FxHashMap<JobId, AccessStats>,
    /// Spill-tier counters for this worker (DESIGN.md §5).
    pub tier: TierStats,
    /// Blocks pinned by a pre-dispatch group restore, released when the
    /// pinning task retires.
    pub restore_pins: FxHashMap<TaskId, Vec<BlockId>>,
    /// Ineffective-hit attribution for reads done by this worker's tasks
    /// (merged into `RunReport::attribution` at teardown).
    pub attribution: AttributionStats,
    /// Modeled busy time accumulated by this worker (nanoseconds).
    pub busy_nanos: u64,
}

impl WorkerState {
    pub fn new() -> Self {
        Self {
            peers: WorkerPeerTracker::default(),
            access: AccessStats::default(),
            per_job_access: FxHashMap::default(),
            tier: TierStats::default(),
            restore_pins: FxHashMap::default(),
            attribution: AttributionStats::default(),
            busy_nanos: 0,
        }
    }
}

impl Default for WorkerState {
    fn default() -> Self {
        Self::new()
    }
}

/// One worker's shareable surface: the lock-striped block store (read
/// directly by peers), the state mutex (tracker + counters), and — when
/// the spill tier is on — the spill accounting plus its file store
/// (readable by peers for read-through, like the memory store).
pub struct WorkerNode {
    pub state: Mutex<WorkerState>,
    pub store: ShardedStore,
    /// Spill-area byte accounting (None unless `EngineConfig::spill`).
    pub spill: Option<Mutex<SpillManager>>,
    /// Real files backing this worker's spill area.
    pub spill_files: Option<DiskStore>,
}

impl WorkerNode {
    /// `spill_dir` is this worker's private spill directory (Some iff
    /// `cfg.spill` is set); creating its file store is the only fallible
    /// step.
    pub fn new(cfg: &EngineConfig, spill_dir: Option<PathBuf>) -> Result<Self> {
        let spill_files = match &spill_dir {
            Some(dir) => Some(DiskStore::new(dir, cfg.disk)?),
            None => None,
        };
        Ok(Self {
            state: Mutex::new(WorkerState::new()),
            store: ShardedStore::with_read_path(
                cfg.cache_capacity_per_worker,
                cfg.policy,
                cfg.cache_shards,
                cfg.read_path,
                cfg.read_touch_buffer,
            ),
            spill: cfg.spill.map(|s| Mutex::new(SpillManager::new(s))),
            spill_files,
        })
    }
}

pub type SharedWorkers = Arc<Vec<WorkerNode>>;

/// Everything a worker thread needs.
pub struct WorkerContext {
    pub id: WorkerId,
    pub cfg: EngineConfig,
    pub shared: SharedWorkers,
    pub disk: Arc<DiskStore>,
    pub compute: ComputeHandle,
    pub driver_tx: Sender<DriverMsg>,
    /// Global modeled-time counter for net-latency accounting (nanos).
    pub net_nanos: Arc<AtomicU64>,
    /// The driver's failure-aware worker-liveness view: block lookups
    /// must follow re-homing after a kill/restart. The driver only
    /// mutates it at quiescent points (no task in flight anywhere).
    pub alive: Arc<RwLock<AliveSet>>,
    /// Dataset ids of ingest datasets (grown at each job admission,
    /// before any of the job's blocks reach a worker): everything else
    /// is a transform block, the only kind the spill tier manages.
    pub ingest_datasets: Arc<RwLock<FxHashSet<u32>>>,
    /// Blocks with a recompute task planned but not yet re-materialized
    /// (driver-maintained, read on the attribution path only when a
    /// task's group is already broken).
    pub recompute_planned: Arc<RwLock<RecomputeSet>>,
}

impl WorkerContext {
    fn me(&self) -> &WorkerNode {
        &self.shared[self.id.0 as usize]
    }

    /// Record one flight-recorder event on this worker's track. A no-op
    /// branch when tracing is off (`TraceConfig::Off` allocates nothing).
    fn trace(&self, ev: impl FnOnce() -> TraceEvent) {
        self.cfg.trace.emit(self.id.0 as usize + 1, None, ev);
    }

    /// Failure-aware home of `b` (equals `scheduler::home_worker` until a
    /// worker dies).
    fn home_of(&self, b: BlockId) -> WorkerId {
        self.alive.read().expect("alive lock poisoned").home_of(b)
    }

    /// Pay a modeled cost: sleep scaled, record modeled nanos.
    fn pay(&self, cost: Duration) -> u64 {
        if !cost.is_zero() {
            let scaled = cost.mul_f64(self.cfg.time_scale);
            if !scaled.is_zero() {
                std::thread::sleep(scaled);
            }
        }
        cost.as_nanos() as u64
    }

    /// After evictions, consult the peer tracker and report if required.
    /// Only peer-aware policies run the §III-C protocol (the paper's
    /// overhead accounting applies to LERC/Sticky runs only).
    fn report_evictions(&self, evicted: &[BlockId]) {
        if !self.cfg.policy.peer_aware() || evicted.is_empty() {
            return;
        }
        let st = self.me().state.lock().unwrap();
        for &b in evicted {
            if st.peers.should_report_eviction(b) {
                let _ = self.driver_tx.send(DriverMsg::EvictionReport { block: b });
            }
        }
    }

    /// Insert at this worker's store. With the spill tier on, the
    /// insert's victims demote instead of dropping (DESIGN.md §5): the
    /// shared planner decides, this method persists the spilled payloads
    /// as real files, pays the demote write cost, deletes reclaimed spill
    /// files, and reports both the evictions (dropped blocks only — a
    /// demotion is a tier transition, not an eviction) and the tier
    /// transitions to the driver. Returns the modeled nanos paid here.
    fn insert_and_demote(&self, b: BlockId, data: BlockData) -> u64 {
        let node = self.me();
        self.trace(|| TraceEvent::BlockInserted { block: b, worker: self.id });
        let Some(mgr) = node.spill.as_ref() else {
            let outcome = node.store.insert(b, data);
            for &v in &outcome.evicted {
                self.trace(|| TraceEvent::BlockEvicted { block: v, worker: self.id });
            }
            self.report_evictions(&outcome.evicted);
            return 0;
        };
        let (outcome, payloads) = node.store.insert_retaining(b, data);
        if outcome.evicted.is_empty() {
            return 0;
        }
        for &v in &outcome.evicted {
            self.trace(|| TraceEvent::BlockEvicted { block: v, worker: self.id });
        }
        let evicted: Vec<(BlockId, BlockData)> =
            outcome.evicted.iter().copied().zip(payloads).collect();
        let plan = {
            let ingest = self.ingest_datasets.read().expect("ingest set poisoned");
            let st = node.state.lock().unwrap();
            let mut mgr = mgr.lock().unwrap();
            demote_evicted(
                &node.store,
                &st.peers,
                &mut mgr,
                |bb: BlockId| !ingest.contains(&bb.dataset.0),
                evicted,
            )
        };
        let mut busy = 0u64;
        let files = node.spill_files.as_ref().expect("spill files with spill on");
        for (bb, payload) in &plan.spilled {
            if let Err(e) = files.write(*bb, payload) {
                let _ = self.driver_tx.send(DriverMsg::Fatal(e.to_string()));
                return busy;
            }
        }
        if !plan.spilled.is_empty() {
            busy += self.pay(tiered::spill_write_cost(&self.cfg, plan.bytes_spilled));
        }
        // Publish the SpilledLocal marks only now that the bytes are on
        // disk: a remote read-through that sees the mark can never find a
        // missing or half-written spill file.
        for (bb, _) in &plan.spilled {
            node.store.set_tier(*bb, BlockTier::SpilledLocal);
            self.trace(|| TraceEvent::BlockDemoted { block: *bb, worker: self.id });
        }
        for bb in &plan.spill_evicted {
            let _ = files.delete(*bb);
        }
        for bb in plan.all_dropped() {
            self.trace(|| TraceEvent::BlockDropped { block: bb, worker: self.id });
        }
        {
            let mut st = node.state.lock().unwrap();
            st.tier.spilled_blocks += plan.spilled.len() as u64;
            st.tier.spilled_bytes += plan.bytes_spilled;
            st.tier.groups_demoted += plan.groups_demoted;
            st.tier.demotions_refused += plan.dropped.len() as u64;
            st.tier.spill_evictions += plan.spill_evicted.len() as u64;
            for (bb, _) in &plan.spilled {
                st.tier.spilled_log.push(block_key(*bb));
            }
        }
        let report: Vec<BlockId> = plan.all_dropped().collect();
        self.report_evictions(&report);
        let spilled: Vec<BlockId> = plan.spilled.iter().map(|(bb, _)| *bb).collect();
        let dropped: Vec<BlockId> =
            plan.dropped.iter().chain(plan.spill_evicted.iter()).copied().collect();
        if !spilled.is_empty() || !dropped.is_empty() {
            let _ = self.driver_tx.send(DriverMsg::TierReport {
                spilled,
                dropped,
                restored: vec![],
            });
        }
        busy
    }

    /// Pre-dispatch group restore: promote each still-spilled block back
    /// to memory (a real spill-file read + pin held until `task`
    /// retires), release its spill residency, report. Stale entries —
    /// already restored, dropped, or never here — are skipped; the fetch
    /// path's read-through and durable fallbacks cover any race.
    fn handle_restore(&self, task: TaskId, blocks: &[BlockId]) {
        let node = self.me();
        let (Some(mgr), Some(files)) = (node.spill.as_ref(), node.spill_files.as_ref()) else {
            return;
        };
        let mut busy = 0u64;
        let mut restored: Vec<BlockId> = Vec::new();
        let mut dropped: Vec<BlockId> = Vec::new();
        for &b in blocks {
            let Some(bytes) = mgr.lock().unwrap().release(b) else {
                continue;
            };
            let data = match files.read(b) {
                Ok((data, _)) => Arc::from(data),
                // The spill file is gone (e.g. a kill wiped the area
                // while this restore was in flight): the bytes are
                // dropped — record and report it so the driver's tier
                // view stays honest and lineage can re-plan the block if
                // a pending task still needs it.
                Err(_) => {
                    node.store.set_tier(b, BlockTier::Dropped);
                    self.trace(|| TraceEvent::BlockDropped { block: b, worker: self.id });
                    dropped.push(b);
                    continue;
                }
            };
            let _ = files.delete(b);
            busy += self.pay(tiered::read_cost(&self.cfg, TierSource::SpilledLocal, bytes));
            // Pin first so the promotion's own eviction cascade can never
            // pick the restored block.
            node.store.pin(b);
            busy += self.insert_and_demote(b, data);
            node.store.set_tier(b, BlockTier::Memory);
            self.trace(|| TraceEvent::BlockRestored { block: b, worker: self.id });
            {
                let mut st = node.state.lock().unwrap();
                st.tier.restored_blocks += 1;
                st.tier.restored_bytes += bytes;
                st.tier.restored_log.push(block_key(b));
                st.restore_pins.entry(task).or_default().push(b);
            }
            restored.push(b);
        }
        if !restored.is_empty() || !dropped.is_empty() {
            node.state.lock().unwrap().busy_nanos += busy;
            let _ = self.driver_tx.send(DriverMsg::TierReport {
                spilled: vec![],
                dropped,
                restored,
            });
        }
    }

    fn handle_ingest(&self, block: BlockId, len: usize, cache: bool, pin: bool) {
        let payload: BlockData = Arc::from(block_payload(
            self.cfg.seed,
            block.dataset.0 as u64,
            block.index,
            len,
        ));
        // Write-through to the disk tier (the durable copy), then cache.
        let cost = match self.disk.write(block, &payload) {
            Ok(c) => c,
            Err(e) => {
                let _ = self.driver_tx.send(DriverMsg::Fatal(e.to_string()));
                return;
            }
        };
        let mut busy = self.pay(cost);
        let node = self.me();
        if cache {
            if pin {
                node.store.pin(block);
            }
            busy += self.insert_and_demote(block, payload);
        }
        node.state.lock().unwrap().busy_nanos += busy;
        let _ = self.driver_tx.send(DriverMsg::IngestDone { block });
    }

    /// Fetch one input block: local memory → remote memory → disk.
    /// Returns (payload, serving class, modeled_cost, home). The
    /// cost is NOT paid here — input streams are concurrent (HDFS-style),
    /// so the caller pays the max over all inputs. This is what produces
    /// the paper's Fig 3 staircase: caching one of two peers does not
    /// shorten the task. The resolved home rides along so the caller
    /// does not re-acquire the alive lock on the hot path. The serving
    /// class (which tier actually produced the bytes) feeds effective-hit
    /// accounting and ineffective-hit attribution in the caller.
    fn fetch_input(
        &self,
        block: BlockId,
        job: JobId,
    ) -> std::result::Result<(BlockData, ServedFrom, Duration, WorkerId), String> {
        let home = self.home_of(block);
        let home_node = &self.shared[home.0 as usize];
        // Memory tier: hit the home worker's sharded store directly —
        // no worker-level lock, remote or local. With spill on, the tier
        // record rides along under the same shard lock.
        let spill_on = self.cfg.spill.is_some();
        let (hit, home_tier) = if spill_on {
            home_node.store.get_with_tier(block)
        } else {
            (home_node.store.get(block), None)
        };
        // A read served by a restored resident is a memory hit like any
        // other (it keeps `mem_hits >= effective_hits` and the
        // conventional hit ratio honest) — and is *additionally*
        // reported as a restored hit in TierStats, which is what the
        // group restore bought.
        let restored = hit.is_some() && home_tier == Some(BlockTier::Memory);
        {
            let mut st = self.me().state.lock().unwrap();
            st.access.accesses += 1;
            let ja = st.per_job_access.entry(job).or_default();
            ja.accesses += 1;
            if hit.is_some() {
                if restored {
                    st.tier.restored_hits += 1;
                }
                st.access.mem_hits += 1;
                ja.mem_hits += 1;
                if home != self.id {
                    st.access.remote_hits += 1;
                    ja.remote_hits += 1;
                }
            }
        }
        if let Some(data) = hit {
            let src = if home == self.id {
                TierSource::LocalMemory
            } else {
                TierSource::RemoteMemory
            };
            let cost = tiered::read_cost(&self.cfg, src, (data.len() * 4) as u64);
            return Ok((data, served_from(true, home_tier, home == self.id), cost, home));
        }
        // Spill tier: read through from the home worker's spill area
        // (RestorePolicy::ReadThrough, or a restore still in flight).
        // Disk-priced, so it does not count as memory-served.
        if home_tier == Some(BlockTier::SpilledLocal) {
            if let Some(files) = home_node.spill_files.as_ref() {
                if let Ok((data, _)) = files.read(block) {
                    let bytes = (data.len() * 4) as u64;
                    let cost = tiered::read_cost(&self.cfg, TierSource::SpilledLocal, bytes);
                    self.me().state.lock().unwrap().tier.spill_reads += 1;
                    return Ok((Arc::from(data), ServedFrom::Spilled, cost, home));
                }
                // Raced with a restore or a budget drop: fall through to
                // the durable tier.
            }
        }
        // Durable tier: replicated external storage for ingest blocks,
        // the async-flush copy for transform blocks.
        let (data, _) = self.disk.read(block).map_err(|e| e.to_string())?;
        let bytes = (data.len() * 4) as u64;
        let cost = tiered::read_cost(&self.cfg, TierSource::Durable, bytes);
        {
            let mut st = self.me().state.lock().unwrap();
            st.access.disk_reads += 1;
            st.access.disk_bytes += bytes;
            let ja = st.per_job_access.entry(job).or_default();
            ja.disk_reads += 1;
            ja.disk_bytes += bytes;
            if home_tier == Some(BlockTier::Dropped) {
                // The consumer was dispatched before the drop landed:
                // served from the durable async-flush copy instead of a
                // (too-late) lineage recompute.
                st.tier.fallback_durable_reads += 1;
            }
        }
        // NOTE: no re-promotion to memory on disk read (Spark 1.6
        // semantics for evicted blocks) — re-caching would fight the
        // experiment; see DESIGN.md.
        Ok((Arc::from(data), served_from(false, None, home == self.id), cost, home))
    }

    fn handle_task(&self, task: &Task) {
        let mut busy = 0u64;
        let mut inputs: Vec<BlockData> = Vec::with_capacity(task.inputs.len());
        let mut served: Vec<(BlockId, ServedFrom)> = Vec::with_capacity(task.inputs.len());
        // Local in-memory inputs to pin while the task is in flight.
        let mut local_mem: Vec<BlockId> = Vec::new();
        let mut fetch_cost = Duration::ZERO;
        for &b in &task.inputs {
            match self.fetch_input(b, task.job) {
                Ok((data, sf, cost, home)) => {
                    fetch_cost = fetch_cost.max(cost);
                    if sf.memory() && home == self.id {
                        local_mem.push(b);
                    }
                    inputs.push(data);
                    served.push((b, sf));
                }
                Err(e) => {
                    let _ = self.driver_tx.send(DriverMsg::Fatal(format!(
                        "task {}: fetch {b}: {e}",
                        task.id
                    )));
                    return;
                }
            }
        }
        // Pin the locally-cached slice of this task's peer-group as one
        // atomic sticky set (all-or-nothing across shards). Group ids
        // reuse the task id value (see dag::analysis::peer_groups).
        let gid = GroupId(task.id.0);
        let group_pinned = !local_mem.is_empty() && self.me().store.pin_group(gid, &local_mem);
        // Pay the concurrent-stream fetch cost once (max over inputs).
        busy += self.pay(fetch_cost);
        // Effective-hit accounting (Def. 1): hits are effective iff every
        // peer was served from memory. A broken group attributes each of
        // its accesses to the blocking co-member that kept the group out
        // of memory (one ineffective_hit trace event per attributed
        // access), so attribution totals reconcile exactly with
        // `accesses - effective_hits`.
        let all_mem = served.iter().all(|&(_, s)| s.memory());
        if all_mem {
            let mut st = self.me().state.lock().unwrap();
            let arity = task.inputs.len() as u64;
            st.access.effective_hits += arity;
            st.per_job_access.entry(task.job).or_default().effective_hits += arity;
        } else {
            let rp = self.recompute_planned.read().expect("recompute set poisoned");
            let mut st = self.me().state.lock().unwrap();
            attribute_group(
                &served,
                |b| rp.contains(b),
                &mut st.attribution,
                |member, blocking, cause| {
                    self.trace(|| TraceEvent::IneffectiveHit {
                        task: task.id,
                        worker: self.id,
                        block: member,
                        blocking,
                        cause,
                    });
                },
            );
        }
        self.trace(|| TraceEvent::InputsPinned { task: task.id, worker: self.id });

        // Compute through the (PJRT or synthetic) service.
        let t0 = std::time::Instant::now();
        let result = self.compute.execute(&task.kind, task.input_len, inputs);
        let compute_wall = t0.elapsed();
        busy += compute_wall.as_nanos() as u64;

        let output = match result {
            Ok(out) => out,
            Err(e) => {
                let _ = self
                    .driver_tx
                    .send(DriverMsg::Fatal(format!("task {}: {e}", task.id)));
                return;
            }
        };
        debug_assert_eq!(output.payload.len(), task.output_len);
        self.trace(|| TraceEvent::TaskComputed { task: task.id, worker: self.id });

        // Unpin inputs, persist + cache the output. The disk copy always
        // happens (durability / downstream disk reads) but its cost is on
        // the critical path only in sync mode (Spark uses an async writer).
        let payload: BlockData = Arc::from(output.payload);
        let cost = match self.disk.write(task.output, &payload) {
            Ok(c) => c,
            Err(e) => {
                let _ = self.driver_tx.send(DriverMsg::Fatal(e.to_string()));
                return;
            }
        };
        if self.cfg.sync_output_writes {
            busy += self.pay(cost);
        }
        let node = self.me();
        if group_pinned {
            node.store.unpin_group(gid);
        }
        busy += self.insert_and_demote(task.output, payload);
        self.trace(|| TraceEvent::TaskPublished {
            task: task.id,
            worker: self.id,
            block: task.output,
        });
        node.state.lock().unwrap().busy_nanos += busy;
        let _ = self.driver_tx.send(DriverMsg::TaskDone {
            task: task.id,
            busy_nanos: busy,
        });
    }

    fn apply_eviction_broadcast(&self, block: BlockId) {
        // Delivery latency of the broadcast.
        let busy = self.pay(self.cfg.net.per_message_latency);
        let node = self.me();
        let (deltas, broken) = {
            let mut st = node.state.lock().unwrap();
            st.busy_nanos += busy;
            st.peers.apply_eviction_broadcast(block)
        };
        // The ctrl-plane drain applied at this replica: the group record
        // for `block` is updated before any queued data work runs.
        self.trace(|| TraceEvent::CtrlDrained { worker: self.id, applied: 1 });
        self.trace(|| TraceEvent::BlockInvalidated { block, worker: self.id });
        for (b, count) in deltas {
            node.store
                .policy_event(PolicyEvent::EffectiveCount { block: b, count });
        }
        if !broken.is_empty() {
            node.store
                .policy_event(PolicyEvent::GroupBroken { members: &broken });
        }
    }

    fn retire(&self, task: TaskId) {
        let node = self.me();
        let (deltas, pins) = {
            let mut st = node.state.lock().unwrap();
            (st.peers.retire_task(task), st.restore_pins.remove(&task))
        };
        // The retiring task's restore pins release here — after its
        // output insert, same order as the simulator.
        if let Some(pins) = pins {
            for b in pins {
                node.store.unpin(b);
            }
        }
        for (b, count) in deltas {
            node.store
                .policy_event(PolicyEvent::EffectiveCount { block: b, count });
        }
    }
}

/// Handle one control-plane message (peer/DAG bookkeeping). The event
/// queue dequeues these with strict priority over the data lane,
/// mirroring Spark's separate block-manager dispatcher — an eviction
/// broadcast must not queue behind pending ingests/tasks or LERC's
/// effective counts go stale exactly when eviction pressure is highest.
fn handle_ctrl(ctx: &WorkerContext, msg: WorkerMsg) {
    let peer_aware = ctx.cfg.policy.peer_aware();
    let dag_aware = ctx.cfg.policy.dag_aware();
    match msg {
        WorkerMsg::RegisterPeers { groups, incomplete } => {
            let node = ctx.me();
            let seeds: Vec<(BlockId, u32)> = {
                let mut st = node.state.lock().unwrap();
                st.peers.register(&groups, &incomplete);
                if peer_aware {
                    // Seed effective counts so the policy starts informed.
                    let blocks: FxHashSet<BlockId> = groups
                        .iter()
                        .flat_map(|g| g.members.iter().copied())
                        .collect();
                    blocks
                        .into_iter()
                        .map(|b| (b, st.peers.effective_count(b)))
                        .collect()
                } else {
                    Vec::new()
                }
            };
            for (b, count) in seeds {
                node.store
                    .policy_event(PolicyEvent::EffectiveCount { block: b, count });
            }
        }
        WorkerMsg::RefCounts(updates) => {
            if dag_aware {
                let node = ctx.me();
                for &(b, count) in updates.iter() {
                    node.store.policy_event(PolicyEvent::RefCount { block: b, count });
                }
            }
        }
        WorkerMsg::EvictionBroadcast(block) => {
            if peer_aware {
                ctx.apply_eviction_broadcast(block);
            } else {
                // Trackers still maintain state for metrics parity.
                let mut st = ctx.me().state.lock().unwrap();
                st.peers.apply_eviction_broadcast(block);
            }
        }
        WorkerMsg::RetireTask(task) => ctx.retire(task),
        WorkerMsg::RestoreGroup { task, blocks } => ctx.handle_restore(task, &blocks),
        WorkerMsg::Ingest { .. } | WorkerMsg::RunTask(_) | WorkerMsg::Shutdown => {
            unreachable!("data-plane message in the control handler")
        }
    }
}

/// Worker thread main loop over the two-priority event queue: control
/// messages always drain before the next data op (so a task dequeued for
/// execution has every already-delivered count applied), and an idle
/// worker sleeps on the queue's condvar instead of polling.
///
/// A panic anywhere in message handling is reported to the driver as
/// [`DriverMsg::Fatal`] before the thread dies — queue sends are
/// infallible, so without this the driver would wait forever on a
/// completion that can no longer arrive (the mpsc engine surfaced the
/// same condition as a channel disconnect).
pub fn worker_loop(ctx: WorkerContext, queue: Arc<EventQueue>) {
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        while let Some(msg) = queue.recv() {
            match msg {
                WorkerMsg::Ingest {
                    block,
                    len,
                    cache,
                    pin,
                } => ctx.handle_ingest(block, len, cache, pin),
                WorkerMsg::RunTask(task) => ctx.handle_task(&task),
                WorkerMsg::Shutdown => break,
                other => handle_ctrl(&ctx, other),
            }
        }
    }));
    if let Err(panic) = run {
        let what = panic
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| panic.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "opaque panic payload".into());
        let _ = ctx
            .driver_tx
            .send(DriverMsg::Fatal(format!("worker {} panicked: {what}", ctx.id.0)));
    }
}
