//! Two-priority worker event queue (Mutex + Condvar, no extra deps).
//!
//! Replaces the old pair of mpsc channels plus a 200µs `recv_timeout`
//! poll loop: control messages always dequeue before data messages, and
//! an idle worker truly sleeps on the condvar until the driver enqueues
//! something. One `notify_one` per send is the entire wake protocol —
//! there is exactly one consumer (the worker thread) per queue.

use crate::driver::messages::WorkerMsg;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner {
    ctrl: VecDeque<WorkerMsg>,
    data: VecDeque<WorkerMsg>,
    closed: bool,
}

/// A worker's inbox: a control lane with strict dequeue priority over the
/// data lane. An eviction invalidation must never queue behind pending
/// ingests/tasks or LERC's effective counts go stale exactly when
/// eviction pressure is highest.
pub struct EventQueue {
    inner: Mutex<Inner>,
    ready: Condvar,
}

impl EventQueue {
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(Inner {
                ctrl: VecDeque::new(),
                data: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue on the control lane (peer/DAG bookkeeping).
    pub fn send_ctrl(&self, msg: WorkerMsg) {
        let mut g = self.inner.lock().expect("queue lock poisoned");
        g.ctrl.push_back(msg);
        drop(g);
        self.ready.notify_one();
    }

    /// Enqueue on the data lane (ingests, tasks, shutdown).
    pub fn send_data(&self, msg: WorkerMsg) {
        let mut g = self.inner.lock().expect("queue lock poisoned");
        g.data.push_back(msg);
        drop(g);
        self.ready.notify_one();
    }

    /// Close the queue: receivers drain what remains, then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue lock poisoned");
        g.closed = true;
        drop(g);
        self.ready.notify_one();
    }

    /// Blocking receive: the next control message if any, else the next
    /// data message, else sleep. Returns `None` once closed and drained.
    pub fn recv(&self) -> Option<WorkerMsg> {
        let mut g = self.inner.lock().expect("queue lock poisoned");
        loop {
            if let Some(m) = g.ctrl.pop_front() {
                return Some(m);
            }
            if let Some(m) = g.data.pop_front() {
                return Some(m);
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).expect("queue lock poisoned");
        }
    }

    /// Queued messages (ctrl + data); diagnostics only.
    pub fn len(&self) -> usize {
        let g = self.inner.lock().expect("queue lock poisoned");
        g.ctrl.len() + g.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::TaskId;
    use std::sync::Arc;
    use std::time::Duration;

    fn ctrl(i: u64) -> WorkerMsg {
        WorkerMsg::RetireTask(TaskId(i))
    }

    fn data() -> WorkerMsg {
        WorkerMsg::Shutdown
    }

    #[test]
    fn ctrl_dequeues_before_data() {
        let q = EventQueue::new();
        q.send_data(data());
        q.send_ctrl(ctrl(1));
        q.send_ctrl(ctrl(2));
        assert!(matches!(q.recv(), Some(WorkerMsg::RetireTask(TaskId(1)))));
        assert!(matches!(q.recv(), Some(WorkerMsg::RetireTask(TaskId(2)))));
        assert!(matches!(q.recv(), Some(WorkerMsg::Shutdown)));
    }

    #[test]
    fn close_drains_then_none() {
        let q = EventQueue::new();
        q.send_ctrl(ctrl(7));
        q.close();
        assert!(q.recv().is_some());
        assert!(q.recv().is_none());
        assert!(q.recv().is_none());
    }

    #[test]
    fn blocked_receiver_wakes_on_send() {
        let q = Arc::new(EventQueue::new());
        let q2 = q.clone();
        let j = std::thread::spawn(move || q2.recv());
        std::thread::sleep(Duration::from_millis(20));
        q.send_ctrl(ctrl(9));
        let got = j.join().unwrap();
        assert!(matches!(got, Some(WorkerMsg::RetireTask(TaskId(9)))));
    }

    #[test]
    fn blocked_receiver_wakes_on_close() {
        let q = Arc::new(EventQueue::new());
        let q2 = q.clone();
        let j = std::thread::spawn(move || q2.recv());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(j.join().unwrap().is_none());
    }
}
