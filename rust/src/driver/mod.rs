//! The threaded cluster engine: a driver plus N worker threads exchanging
//! control messages over channels, with per-worker block managers and the
//! peer-tracking protocol — the paper's Fig 4 architecture in-process.
//!
//! Real work happens here: payloads are genuine f32 blocks, the disk tier
//! is real files, compute runs through the PJRT CPU client (or the
//! synthetic reference), and disk/network costs are paid as (scaled)
//! sleeps per the configured models.
//!
//! For exact modeled-time figures at large scale, use the discrete-event
//! twin in [`crate::sim`].

pub mod engine;
pub mod messages;
pub mod worker;

pub use engine::ClusterEngine;
