//! The threaded cluster engine: a driver plus N worker threads exchanging
//! messages over per-worker two-priority event queues, with per-worker
//! block managers and the peer-tracking protocol — the paper's Fig 4
//! architecture in-process. The control plane is either broadcast (the
//! paper's accounting model) or home-routed and batched (the default;
//! see `DESIGN.md` §1).
//!
//! Real work happens here: payloads are genuine f32 blocks, the disk tier
//! is real files, compute runs through the PJRT CPU client (or the
//! synthetic reference), and disk/network costs are paid as (scaled)
//! sleeps per the configured models.
//!
//! For exact modeled-time figures at large scale, use the discrete-event
//! twin in [`crate::sim`].

pub mod ctrl;
pub mod engine;
pub mod messages;
pub mod queue;
pub mod worker;

pub use engine::ClusterEngine;
