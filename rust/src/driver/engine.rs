//! ClusterEngine: assemble the cluster, run a job queue, produce reports.
//!
//! Online multi-job execution ([`crate::engine::Engine::run`]): jobs
//! arrive at **dispatch index** boundaries (the same deterministic
//! logical clock the topology plan uses), interleave dispatch under
//! per-job priorities, and share the block cache — reference counts and
//! peer-group effective counts aggregate over every admitted job, and
//! shared ingest datasets (content-keyed by `BlockId`) are ingested once
//! for the whole queue. Each job runs behind its *own* ingest barrier
//! (its tasks are gated until its ingest completes) while other jobs
//! keep computing; a queue of one job arriving at 0 is exactly the
//! classic offline run, which is how `run_workload` is implemented.
//! DESIGN.md §4.
//!
//! Topology injection (`EngineConfig::topology`; legacy `failures`
//! plans upgrade losslessly): each planned kill, restart, or join fires
//! at a dispatch-count boundary — the driver stops dispatching at the
//! trigger, drains the in-flight tasks (fail-stop detected at a
//! scheduling barrier, so the completed-task prefix is deterministic),
//! then applies the step. A kill wipes the dead worker's store and peer
//! replica, deletes the durable copies of transform blocks homed at it
//! (executor-local spill; ingest blocks reload from the replicated
//! [`DiskStore`]), re-homes lost blocks over the survivors ([`AliveSet`]
//! stable probing), recomputes the minimal lineage closure *for the
//! jobs that still need the lost blocks*, and repairs peer/ref metadata
//! at the new homes — DESIGN.md §3. A join brings a pending slot online
//! and warm-up-migrates exactly the blocks whose stable probe home is
//! now the newcomer, whole peer groups at a time; an autoscale plan
//! turns ready-queue depth and memory pressure into join/retire
//! decisions at the same boundaries — DESIGN.md §9.

use crate::cache::policy::PolicyEvent;
use crate::cache::store::BlockTier;
use crate::common::config::{ComputeMode, CtrlPlane, EngineConfig};
use crate::common::error::{EngineError, Result};
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, GroupId, JobId, TaskId, WorkerId};
use crate::common::tempdir::TempDir;
use crate::dag::analysis::{peer_groups, PeerGroup, RefCounts};
use crate::dag::task::{enumerate_tasks, Task};
use crate::driver::ctrl::DeltaCoalescer;
use crate::driver::messages::{DriverMsg, WorkerMsg};
use crate::driver::queue::EventQueue;
use crate::driver::worker::{worker_loop, SharedWorkers, WorkerContext, WorkerNode};
use crate::metrics::{
    AccessStats, AttributionStats, FleetReport, JobStats, LatencyHistogram, MessageStats,
    RecoveryStats, RunReport, ScaleStats, TierStats,
};
use crate::peer::{PeerTrackerMaster, WorkerPeerTracker};
use crate::recovery::{
    plan_dropped_blocks, plan_worker_loss, LineageIndex, RecomputeSet, RepairAction,
};
use crate::trace::{ClockDomain, TraceConfig, TraceEvent};
use crate::runtime::pjrt::{ComputeHandle, PjrtEngine};
use crate::runtime::SyntheticEngine;
use crate::scheduler::{AliveSet, TaskTracker};
use crate::spill::GroupRestorer;
use crate::storage::DiskStore;
use crate::workload::JobQueue;
use std::collections::BTreeMap;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::channel;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// The threaded cluster engine. Construct with a config, `run` workloads.
pub struct ClusterEngine {
    cfg: EngineConfig,
}

/// Send a control message to every alive worker.
fn ctrl_to_alive(queues: &[Arc<EventQueue>], alive: &AliveSet, msg: WorkerMsg) {
    for w in alive.alive_workers() {
        queues[w.0 as usize].send_ctrl(msg.clone());
    }
}

/// Deliver one invalidation broadcast for `block`: to the interested
/// alive workers in home-routed mode, to every alive worker in broadcast
/// mode, updating the fan-out accounting either way.
fn broadcast_invalidation(
    block: BlockId,
    routed: bool,
    master: &PeerTrackerMaster,
    alive: &AliveSet,
    queues: &[Arc<EventQueue>],
    msgs: &mut MessageStats,
    trace: &TraceConfig,
) {
    trace.emit(0, None, || TraceEvent::InvalidationBroadcast { block });
    msgs.invalidation_broadcasts += 1;
    if routed {
        let interested: Vec<WorkerId> = master
            .interested_workers(block)
            .iter()
            .copied()
            .filter(|w| alive.is_alive(*w))
            .collect();
        msgs.broadcast_deliveries += interested.len() as u64;
        for w in interested {
            queues[w.0 as usize].send_ctrl(WorkerMsg::EvictionBroadcast(block));
        }
    } else {
        msgs.broadcast_deliveries += alive.alive_count() as u64;
        ctrl_to_alive(queues, alive, WorkerMsg::EvictionBroadcast(block));
    }
}

/// Closes every worker queue when dropped, so worker threads parked on
/// their condvar wake and exit even when `run` returns early with an
/// error (the mpsc-based engine got this for free from channel
/// disconnection).
struct CloseQueuesOnDrop(Vec<Arc<EventQueue>>);

impl Drop for CloseQueuesOnDrop {
    fn drop(&mut self) {
        for q in &self.0 {
            q.close();
        }
    }
}

impl ClusterEngine {
    pub fn new(cfg: EngineConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Run an online multi-job queue to completion: jobs are admitted at
    /// their arrival dispatch indices (or as soon as the cluster would
    /// otherwise quiesce), interleave dispatch by priority, and share the
    /// cache with cross-job effective reference counting.
    ///
    /// The threaded engine keeps real wall-clock semantics: the
    /// fair-share network model is simulation-only, so
    /// `EngineConfig::net_model` is ignored here (real thread contention
    /// plays that role) and `RunReport::net` stays zeroed.
    fn execute(&self, queue: &JobQueue) -> Result<FleetReport> {
        queue.validate()?;
        self.cfg.validate()?;
        let cfg = &self.cfg;

        // Topology ceiling (DESIGN.md §9): everything worker-indexed —
        // nodes, queues, threads, trace tracks, placement modulus — is
        // sized to the highest worker id the plan can ever bring online,
        // so a join is the placement analogue of a revive and a pure
        // kill/restart plan (ceiling == num_workers) is bit-for-bit the
        // old failure path.
        let topo = cfg.effective_topology();
        let ceiling = cfg.worker_ceiling();

        // --- flight recorder (DESIGN.md §8) ---------------------------
        // Track 0 is the driver, track 1+w is worker w. Wall-clock
        // domain: logical timestamps are monotonic nanos since run start.
        let trace = cfg.trace.clone();
        if let Some(rec) = trace.recorder() {
            rec.begin(ceiling as usize + 1, ClockDomain::Wall);
        }

        // --- storage -------------------------------------------------
        let _tmp; // keeps the tempdir alive for the run
        let disk_dir = match &cfg.disk_dir {
            Some(d) => d.clone(),
            None => {
                let t = TempDir::new("engine")?;
                let p = t.path().to_path_buf();
                _tmp = t;
                p
            }
        };
        let disk = Arc::new(DiskStore::new(&disk_dir, cfg.disk)?);

        // --- compute service ------------------------------------------
        let (compute, service) = match &cfg.compute {
            ComputeMode::Pjrt { artifacts_dir } => {
                let dir = artifacts_dir.clone();
                ComputeHandle::spawn(move || {
                    let e = PjrtEngine::load(dir)?;
                    e.warmup()?;
                    Ok(e)
                })?
            }
            ComputeMode::Synthetic => ComputeHandle::spawn(|| Ok(SyntheticEngine::new()))?,
        };
        let _service = service.with_handle(compute.clone());

        // --- online job state (grows at each admission) ------------------
        // Admission order: by arrival index, submission order breaking
        // ties. `next_spec` walks `order`.
        let mut order: Vec<usize> = (0..queue.jobs.len()).collect();
        order.sort_by_key(|&i| (queue.jobs[i].arrival, i));
        let mut next_spec = 0usize;

        let mut next_task_id = 0u64;
        let mut all_tasks: Vec<Task> = Vec::new();
        let mut refcounts = RefCounts::default();
        // Arc'd task index: dispatch hands workers a refcount bump, not a
        // fresh deep clone of the task per dispatch. Mutable: admission
        // and recovery add tasks mid-run.
        let mut task_index: FxHashMap<TaskId, Arc<Task>> = FxHashMap::default();
        let mut master = PeerTrackerMaster::default();
        let mut msgs = MessageStats::default();
        let routed = cfg.ctrl_plane == CtrlPlane::HomeRouted;

        // Per-spec bookkeeping.
        let n_specs = queue.jobs.len();
        let mut spec_pending: Vec<usize> = vec![0; n_specs];
        let mut spec_gated: Vec<bool> = vec![false; n_specs];
        let mut admitted_at: Vec<u64> = vec![0; n_specs];
        let mut admit_instants: Vec<Option<Instant>> = vec![None; n_specs];
        let mut spec_of_job: FxHashMap<JobId, usize> = FxHashMap::default();
        let mut ingest_owner: FxHashMap<BlockId, usize> = FxHashMap::default();
        let mut pending_total = 0usize;
        let mut tasks_run_per_job: BTreeMap<u32, u64> = BTreeMap::new();
        let mut recompute_per_job: BTreeMap<u32, u64> = BTreeMap::new();
        let mut job_jct: BTreeMap<u32, Duration> = BTreeMap::new();

        // --- topology plan -----------------------------------------------
        let mut lineage = LineageIndex::default();
        // Slots past `num_workers` start pending (dead) and come online
        // through Join actions.
        let mut alive = AliveSet::with_pending(cfg.num_workers, ceiling);
        let alive_shared = Arc::new(RwLock::new(alive.clone()));
        // Due-ordered repair queue; kills and joins come from the plan,
        // revives are scheduled when their kill is applied, and autoscale
        // decisions are inserted at their checkpoint.
        let mut actions: Vec<(u64, RepairAction)> = topo.action_queue(ceiling);
        let auto_cfg = topo.autoscale_config().cloned();
        let mut next_check: u64 = auto_cfg.as_ref().map(|a| a.check_every).unwrap_or(u64::MAX);
        let mut scale = ScaleStats::default();
        let mut recovery = RecoveryStats::default();
        let mut recompute_pending: FxHashSet<TaskId> = FxHashSet::default();
        let mut recovery_t0: Option<Instant> = None;
        // Blocks with a planned-but-not-yet-rematerialized recompute:
        // workers consult this on the attribution path (a blocked group
        // member in recompute is a "recomputing" cause, not "evicted").
        let recompute_planned: Arc<RwLock<RecomputeSet>> =
            Arc::new(RwLock::new(RecomputeSet::default()));

        // --- spill tier (DESIGN.md §5; None = pre-spill behavior) --------
        let spill_on = cfg.spill.is_some();
        // The spill tier's demotion planner asks the worker peer replicas
        // which blocks pending tasks still read (`unconsumed`,
        // `live_co_members`), so group registration and retirement must
        // flow even under policies that do not consume them.
        let track_groups = cfg.policy.peer_aware() || spill_on;
        let mut restorer: Option<GroupRestorer> = cfg.spill.as_ref().map(GroupRestorer::new);
        // Drop → recompute is planned at most once per block; a
        // re-dropped recompute output is served from the durable
        // async-flush copy instead of looping recompute forever.
        let mut spill_recomputed: FxHashSet<BlockId> = FxHashSet::default();
        let mut tier_global = TierStats::default();
        // Ingest dataset ids, grown at admission before any of the job's
        // blocks reach a worker (workers read it on the demote path).
        let ingest_datasets: Arc<RwLock<FxHashSet<u32>>> =
            Arc::new(RwLock::new(FxHashSet::default()));

        // --- workers ----------------------------------------------------
        // Sized to the topology ceiling: pending slots get a node, a
        // queue, and a parked thread up front, and stay idle until a
        // Join action brings them online.
        let shared: SharedWorkers = Arc::new(
            (0..ceiling)
                .map(|w| {
                    WorkerNode::new(cfg, cfg.spill.map(|_| disk_dir.join(format!("spill_w{w}"))))
                })
                .collect::<Result<Vec<_>>>()?,
        );
        let (driver_tx, driver_rx) = channel::<DriverMsg>();
        let net_nanos = Arc::new(AtomicU64::new(0));
        let queues: Vec<Arc<EventQueue>> =
            (0..ceiling).map(|_| Arc::new(EventQueue::new())).collect();
        let _close_on_drop = CloseQueuesOnDrop(queues.clone());
        let mut joins = Vec::new();
        for w in 0..ceiling {
            let ctx = WorkerContext {
                id: WorkerId(w),
                cfg: cfg.clone(),
                shared: shared.clone(),
                disk: disk.clone(),
                compute: compute.clone(),
                driver_tx: driver_tx.clone(),
                net_nanos: net_nanos.clone(),
                alive: alive_shared.clone(),
                ingest_datasets: ingest_datasets.clone(),
                recompute_planned: recompute_planned.clone(),
            };
            let queue = queues[w as usize].clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("lerc-worker-{w}"))
                    .spawn(move || worker_loop(ctx, queue))?,
            );
        }

        // All groups ever registered, in registration order — recovery's
        // re-registration source (kill re-homing, worker restart). Only
        // repair branches read it, so fault-free / non-peer-aware runs
        // skip the clones entirely.
        let keep_groups = track_groups && !topo.is_empty();
        let mut registered_groups: Vec<PeerGroup> = Vec::new();
        let mut coalescer = DeltaCoalescer::new(ceiling);
        // Adopt the pending-slot liveness so staging never routes to a
        // worker that has not joined yet.
        coalescer.set_alive(&alive);
        let mut block_len_of: FxHashMap<BlockId, usize> = FxHashMap::default();
        let mut tracker = TaskTracker::default();
        let mut in_flight = 0usize;
        let mut dispatched: u64 = 0;
        let mut job_done_at: BTreeMap<u32, Duration> = BTreeMap::new();
        // Per-job latency histograms (always on — they are metrics, not
        // tracing): task latency is dispatch → publish, queue wait is
        // ready → dispatch, both driver-side and unscaled to modeled time.
        let mut lat_per_job: BTreeMap<u32, LatencyHistogram> = BTreeMap::new();
        let mut wait_per_job: BTreeMap<u32, LatencyHistogram> = BTreeMap::new();
        let mut ready_at: FxHashMap<TaskId, Instant> = FxHashMap::default();
        let mut disp_at: FxHashMap<TaskId, Instant> = FxHashMap::default();
        let t0 = Instant::now();

        // Telemetry sampler (DESIGN.md §10): the same dispatch-boundary
        // sampling points as the simulator, with wall-clock timestamps
        // (raw trace domain, not unscaled, so Perfetto counter tracks
        // line up with the trace spans). `Timeline::new(0)` equals the
        // default empty timeline, preserving Off-vs-Collect report
        // byte-identity.
        let tl_every = cfg.timeline.map(|t| t.every_dispatches).unwrap_or(0);
        let mut timeline = crate::metrics::Timeline::new(tl_every);
        macro_rules! tl_sample {
            () => {{
                let mut s = crate::metrics::TimelineSample {
                    ts: t0.elapsed().as_nanos().min(u64::MAX as u128) as u64,
                    dispatched,
                    ready_depth: tracker.ready_len() as u64,
                    alive_workers: alive.alive_count(),
                    ..Default::default()
                };
                for wid in alive.alive_workers() {
                    let node = &shared[wid.0 as usize];
                    s.mem_blocks += node.store.len() as u64;
                    s.mem_bytes += node.store.used();
                    if let Some(sp) = node.spill.as_ref() {
                        let sp = sp.lock().unwrap();
                        s.spill_blocks += sp.len() as u64;
                        s.spill_bytes += sp.used();
                    }
                    let st = node.state.lock().unwrap();
                    s.accesses += st.access.accesses;
                    s.mem_hits += st.access.mem_hits;
                    s.effective_hits += st.access.effective_hits;
                }
                for node in shared.iter() {
                    s.worker_busy.push(node.state.lock().unwrap().busy_nanos);
                }
                timeline.push(s);
            }};
        }

        // Admit one job: enumerate its tasks, register its peer groups at
        // the current homes, aggregate its references into the shared
        // profile (seeding workers with the new absolute counts), enqueue
        // its not-yet-ingested input blocks, and gate its tasks behind
        // its own ingest barrier. Home-routed mode installs each group
        // only at the home workers of its members: those are the only
        // replicas whose stores can hold a member, and for any home block
        // every group containing it lands at that worker (the block is
        // itself a member), so eviction reporting and effective counts
        // stay exact — including counts aggregated across jobs.
        macro_rules! admit {
            ($si:expr) => {{
                let si: usize = $si;
                let spec = &queue.jobs[si];
                admitted_at[si] = dispatched;
                admit_instants[si] = Some(Instant::now());
                let mut spec_tasks: Vec<Task> = Vec::new();
                for dag in &spec.workload.dags {
                    spec_of_job.insert(dag.job, si);
                    tracker.set_priority(dag.job, spec.priority);
                    let tasks = enumerate_tasks(dag, &mut next_task_id);
                    if track_groups {
                        let groups = peer_groups(&tasks);
                        // A late job's group may reference a shared block
                        // that is already materialized but no longer
                        // cached anywhere (evicted, or ingested with
                        // cache=false): register it broken, or the fresh
                        // replicas would hold an all-memory promise no
                        // disk read can keep (same check as recovery's
                        // recompute registration). At dispatch 0 nothing
                        // is materialized, so the offline path is
                        // unchanged.
                        let incomplete: Vec<GroupId> = groups
                            .iter()
                            .filter(|g| {
                                g.members.iter().any(|m| {
                                    // A spilled member does not break the
                                    // group (spill::member_breaks_group).
                                    crate::spill::member_breaks_group(
                                        &shared[alive.home_of(*m).0 as usize].store,
                                        tracker.is_materialized(*m),
                                        *m,
                                    )
                                })
                            })
                            .map(|g| g.id)
                            .collect();
                        let incomplete = Arc::new(incomplete);
                        if routed {
                            master.register_routed_in(&groups, &alive);
                            master.mark_incomplete(&incomplete);
                            // One bucketing pass: each group lands at the
                            // home workers of its members.
                            let mut per_worker: Vec<Vec<PeerGroup>> =
                                vec![Vec::new(); ceiling as usize];
                            for g in &groups {
                                for w in alive.homes_of(&g.members) {
                                    per_worker[w.0 as usize].push(g.clone());
                                }
                            }
                            for (w, subset) in per_worker.into_iter().enumerate() {
                                if !subset.is_empty() {
                                    queues[w].send_ctrl(WorkerMsg::RegisterPeers {
                                        groups: Arc::new(subset),
                                        incomplete: incomplete.clone(),
                                    });
                                }
                            }
                        } else {
                            master.register(&groups);
                            master.mark_incomplete(&incomplete);
                            ctrl_to_alive(
                                &queues,
                                &alive,
                                WorkerMsg::RegisterPeers {
                                    groups: Arc::new(groups.clone()),
                                    incomplete: incomplete.clone(),
                                },
                            );
                        }
                        if keep_groups {
                            registered_groups.extend(groups);
                        }
                    }
                    spec_tasks.extend(tasks);
                }
                lineage.add_tasks(&spec_tasks, all_tasks.len());
                for t in &spec_tasks {
                    task_index.insert(t.id, Arc::new(t.clone()));
                }
                // Cross-job reference aggregation: the new tasks' input
                // references stack on top of whatever other live jobs
                // already hold; workers are (re-)seeded with the new
                // absolute counts for every block this job touches.
                let changed = refcounts.add_tasks(&spec_tasks);
                if cfg.policy.dag_aware() {
                    let mut seed = changed;
                    let seeded: FxHashSet<BlockId> = seed.iter().map(|(b, _)| *b).collect();
                    for t in &spec_tasks {
                        if !seeded.contains(&t.output) {
                            seed.push((t.output, refcounts.get(t.output)));
                        }
                    }
                    if routed {
                        coalescer.stage(&seed);
                        msgs.refcount_updates += coalescer
                            .flush(|w, batch| queues[w].send_ctrl(WorkerMsg::RefCounts(batch)));
                    } else {
                        ctrl_to_alive(&queues, &alive, WorkerMsg::RefCounts(Arc::new(seed)));
                        msgs.refcount_updates += alive.alive_count() as u64;
                    }
                }
                // Ingest, deduplicated by content key: a block another
                // job already enqueued (shared dataset) is not re-read —
                // its references were aggregated above and its
                // materialization gates this job's tasks via readiness.
                {
                    let mut ing = ingest_datasets.write().expect("ingest set poisoned");
                    for d in &spec.workload.dags {
                        for ds in d.inputs() {
                            ing.insert(ds.id.0);
                            for b in ds.blocks() {
                                block_len_of.insert(b, ds.block_len);
                            }
                        }
                    }
                }
                let pinned_set: Option<FxHashSet<BlockId>> =
                    spec.workload.pinned_cache.as_ref().map(|v| v.iter().copied().collect());
                for &b in &spec.workload.ingest_order {
                    if ingest_owner.contains_key(&b) {
                        continue;
                    }
                    ingest_owner.insert(b, si);
                    let w = alive.home_of(b);
                    let (cache, pin) = match &pinned_set {
                        Some(set) => (set.contains(&b), set.contains(&b)),
                        None => (true, false),
                    };
                    queues[w.0 as usize].send_data(WorkerMsg::Ingest {
                        block: b,
                        len: block_len_of[&b],
                        cache,
                        pin,
                    });
                    spec_pending[si] += 1;
                    pending_total += 1;
                }
                // Per-job ingest barrier (the offline run's global
                // barrier, now job-scoped): gate before adding tasks so
                // already-satisfiable tasks buffer instead of dispatching.
                if !cfg.overlap_ingest && spec_pending[si] > 0 {
                    spec_gated[si] = true;
                    for dag in &spec.workload.dags {
                        tracker.gate_job(dag.job);
                    }
                }
                for t in &spec_tasks {
                    trace.emit(0, None, || TraceEvent::TaskAdmitted { job: t.job, task: t.id });
                }
                all_tasks.extend(spec_tasks.iter().cloned());
                tracker.add_tasks(spec_tasks);
            }};
        }

        // Admit every due job and dispatch ready tasks, holding dispatch
        // at the next failure/arrival boundary so the admission point —
        // and therefore the multi-job interleaving prefix — is a
        // deterministic function of the dispatch order (the property the
        // simulator replays). If the cluster would quiesce with jobs
        // still waiting on an unreachable arrival index, the next job is
        // admitted immediately (arrival is "no earlier than").
        macro_rules! admit_and_dispatch {
            () => {{
                loop {
                    let mut admitted_any = false;
                    while next_spec < order.len()
                        && queue.jobs[order[next_spec]].arrival <= dispatched
                    {
                        admit!(order[next_spec]);
                        next_spec += 1;
                        admitted_any = true;
                    }
                    // Stall clamp: nothing pending, in flight, or ready,
                    // but jobs remain — their arrival index can never be
                    // reached, so pull the next one in now.
                    if !admitted_any
                        && next_spec < order.len()
                        && pending_total == 0
                        && in_flight == 0
                        && tracker.ready_len() == 0
                    {
                        admit!(order[next_spec]);
                        next_spec += 1;
                    }
                    let fail_limit = actions.first().map(|(t, _)| *t);
                    let auto_limit = auto_cfg.as_ref().map(|_| next_check);
                    let arr_limit = if next_spec < order.len() {
                        Some(queue.jobs[order[next_spec]].arrival)
                    } else {
                        None
                    };
                    let limit =
                        [fail_limit, auto_limit, arr_limit].into_iter().flatten().min();
                    // Stamp newly-ready tasks before any pop: queue-wait
                    // starts here, and the ready events land on the
                    // driver track ahead of their dispatches.
                    for rid in tracker.take_newly_ready() {
                        ready_at.insert(rid, Instant::now());
                        trace.emit(0, None, || TraceEvent::TaskReady { task: rid });
                    }
                    while limit.map_or(true, |t| dispatched < t) {
                        let Some(tid) = tracker.pop_ready() else {
                            break;
                        };
                        let task = task_index[&tid].clone();
                        // Pre-dispatch group restore (DESIGN.md §5): one
                        // ctrl message per home worker holding spilled
                        // members, sent before the task — each home's
                        // control lane drains the restore ahead of any
                        // task queued behind it.
                        if let Some(rst) = restorer.as_mut() {
                            let set = rst.plan_restore(&task.inputs);
                            if !set.is_empty() {
                                tier_global.groups_restored += 1;
                                let mut per_worker: FxHashMap<WorkerId, Vec<BlockId>> =
                                    FxHashMap::default();
                                for b in set {
                                    per_worker.entry(alive.home_of(b)).or_default().push(b);
                                }
                                for (w, blocks) in per_worker {
                                    queues[w.0 as usize].send_ctrl(WorkerMsg::RestoreGroup {
                                        task: tid,
                                        blocks: Arc::new(blocks),
                                    });
                                }
                            }
                        }
                        *tasks_run_per_job.entry(task.job.0).or_default() += 1;
                        let w = alive.home_of(task.output);
                        if let Some(r) = ready_at.remove(&tid) {
                            wait_per_job
                                .entry(task.job.0)
                                .or_default()
                                .record_duration(cfg.unscale(r.elapsed()));
                        }
                        disp_at.insert(tid, Instant::now());
                        trace.emit(0, None, || TraceEvent::TaskDispatched { task: tid, worker: w });
                        queues[w.0 as usize].send_data(WorkerMsg::RunTask(task));
                        in_flight += 1;
                        dispatched += 1;
                        if tl_every != 0 && dispatched % tl_every == 0 {
                            tl_sample!();
                        }
                    }
                    // Dispatching may have reached the next arrival
                    // boundary, or quiesced with jobs left: go again.
                    if next_spec < order.len()
                        && (queue.jobs[order[next_spec]].arrival <= dispatched
                            || (pending_total == 0
                                && in_flight == 0
                                && tracker.ready_len() == 0))
                    {
                        continue;
                    }
                    break;
                }
            }};
        }

        // Register a recompute closure's peer groups at the current homes
        // of their members — one protocol sequence shared by the kill
        // path and the spill drop path, so the incomplete-group rule and
        // the routed/broadcast delivery cannot drift between them.
        // Members that are materialized but neither cached nor restorably
        // spilled make their group broken from birth: registering it
        // complete would inflate effective counts.
        macro_rules! register_recompute_groups {
            ($recompute:expr) => {{
                let groups = peer_groups($recompute);
                let incomplete: Vec<GroupId> = groups
                    .iter()
                    .filter(|g| {
                        g.members.iter().any(|m| {
                            crate::spill::member_breaks_group(
                                &shared[alive.home_of(*m).0 as usize].store,
                                tracker.is_materialized(*m),
                                *m,
                            )
                        })
                    })
                    .map(|g| g.id)
                    .collect();
                let incomplete = Arc::new(incomplete);
                if routed {
                    master.register_routed_in(&groups, &alive);
                    master.mark_incomplete(&incomplete);
                    let mut per_worker: Vec<Vec<PeerGroup>> =
                        vec![Vec::new(); ceiling as usize];
                    for g in &groups {
                        for w in alive.homes_of(&g.members) {
                            per_worker[w.0 as usize].push(g.clone());
                        }
                    }
                    for (w, subset) in per_worker.into_iter().enumerate() {
                        if !subset.is_empty() {
                            queues[w].send_ctrl(WorkerMsg::RegisterPeers {
                                groups: Arc::new(subset),
                                incomplete: incomplete.clone(),
                            });
                        }
                    }
                } else {
                    master.register(&groups);
                    master.mark_incomplete(&incomplete);
                    ctrl_to_alive(
                        &queues,
                        &alive,
                        WorkerMsg::RegisterPeers {
                            groups: Arc::new(groups.clone()),
                            incomplete: incomplete.clone(),
                        },
                    );
                }
                if keep_groups {
                    registered_groups.extend(groups);
                }
            }};
        }

        // Jobs arriving at dispatch 0 (or pulled in by the stall clamp if
        // the first arrival is later) start the run.
        admit_and_dispatch!();

        // Unified event loop. Non-overlapped (paper) mode gates dispatch
        // behind the ingest barrier; overlapped mode (ablation knob)
        // dispatches tasks as their inputs materialize mid-ingest.
        //
        // Batching: after the blocking recv, the loop drains everything
        // already queued and processes it as one cycle. In home-routed
        // mode the cycle's ref-count deltas coalesce per destination
        // worker (one RefCounts message per affected worker, last write
        // wins per block — counts are absolute) and flush before any new
        // task is dispatched, so a dispatched task's worker always has
        // every count the driver knew at dispatch (control messages
        // dequeue first). Broadcast mode keeps the paper's one send per
        // event per worker so §IV message accounting is unchanged.
        let mut compute_started: Option<Instant> = None;
        let mut cycle: Vec<DriverMsg> = Vec::new();
        while next_spec < order.len() || pending_total > 0 || !tracker.all_done() {
            cycle.clear();
            let first = driver_rx.recv().map_err(|_| EngineError::ChannelClosed("driver rx"))?;
            cycle.push(first);
            while let Ok(more) = driver_rx.try_recv() {
                cycle.push(more);
            }
            let mut dispatch_after = false;
            for msg in cycle.drain(..) {
                match msg {
                    DriverMsg::IngestDone { block } => {
                        if pending_total == 0 {
                            return Err(EngineError::Invariant("ingest after ingest phase".into()));
                        }
                        let si = *ingest_owner
                            .get(&block)
                            .ok_or_else(|| EngineError::Invariant("unowned ingest".into()))?;
                        pending_total -= 1;
                        spec_pending[si] -= 1;
                        tracker.on_block_materialized(block);
                        if spec_pending[si] == 0 && spec_gated[si] {
                            spec_gated[si] = false;
                            for dag in &queue.jobs[si].workload.dags {
                                tracker.ungate_job(dag.job);
                            }
                        }
                        if cfg.overlap_ingest || spec_pending[si] == 0 {
                            if compute_started.is_none() {
                                compute_started = Some(Instant::now());
                            }
                            dispatch_after = true;
                        }
                    }
                    DriverMsg::TaskDone { task, .. } => {
                        in_flight -= 1;
                        let t = task_index[&task].clone();
                        if let Some(d) = disp_at.remove(&task) {
                            lat_per_job
                                .entry(t.job.0)
                                .or_default()
                                .record_duration(cfg.unscale(d.elapsed()));
                        }
                        {
                            // The output (re-)materialized: a pending
                            // recompute for it is no longer "recomputing".
                            let planned = recompute_planned.read().expect("recompute set");
                            if planned.contains(t.output) {
                                drop(planned);
                                recompute_planned
                                    .write()
                                    .expect("recompute set")
                                    .materialized(t.output);
                            }
                        }
                        if spec_gated[spec_of_job[&t.job]] {
                            return Err(EngineError::Invariant(
                                "task completed behind its job's ingest barrier".into(),
                            ));
                        }
                        // Reference counts decrement. Always maintained
                        // (recovery's "still needed" test reads them);
                        // only DAG-aware policies are told.
                        let changed = refcounts.on_task_complete(&t);
                        if cfg.policy.dag_aware() {
                            if routed {
                                coalescer.stage(&changed);
                            } else {
                                let batch = WorkerMsg::RefCounts(Arc::new(changed));
                                ctrl_to_alive(&queues, &alive, batch);
                                msgs.refcount_updates += alive.alive_count() as u64;
                            }
                        }
                        if let Some(rst) = restorer.as_mut() {
                            // The output (re-)materialized through the
                            // normal insert path: plain memory rules.
                            rst.forget(t.output);
                        }
                        // RetireTask also releases restore pins at the
                        // input homes, so the spill tier needs it even
                        // for non-peer-aware policies.
                        if track_groups {
                            master.retire_task(task);
                            if routed || !cfg.policy.peer_aware() {
                                // The group's replicas live at its members'
                                // home workers only (and so do any restore
                                // pins).
                                for w in alive.homes_of(&t.inputs) {
                                    queues[w.0 as usize].send_ctrl(WorkerMsg::RetireTask(task));
                                }
                            } else {
                                ctrl_to_alive(&queues, &alive, WorkerMsg::RetireTask(task));
                            }
                        }
                        let (_ready, job_finished) = tracker.on_task_complete(task)?;
                        if job_finished {
                            let base = compute_started.unwrap_or(t0);
                            job_done_at.insert(t.job.0, cfg.unscale(base.elapsed()));
                            if let Some(at) = admit_instants[spec_of_job[&t.job]] {
                                job_jct.insert(t.job.0, cfg.unscale(at.elapsed()));
                            }
                        }
                        if recompute_pending.remove(&task) && recompute_pending.is_empty() {
                            if let Some(rt0) = recovery_t0.take() {
                                recovery.recovery_nanos +=
                                    cfg.unscale(rt0.elapsed()).as_nanos() as u64;
                            }
                        }
                        dispatch_after = true;
                    }
                    DriverMsg::EvictionReport { block } => {
                        trace.emit(0, None, || TraceEvent::EvictionReported { block });
                        msgs.eviction_reports += 1;
                        if let Some(b) = master.on_eviction_report(block) {
                            broadcast_invalidation(
                                b, routed, &master, &alive, &queues, &mut msgs, &trace,
                            );
                        }
                    }
                    DriverMsg::TierReport {
                        spilled,
                        dropped,
                        restored,
                    } => {
                        if let Some(rst) = restorer.as_mut() {
                            for b in &spilled {
                                rst.note_spilled(*b);
                            }
                            for b in &restored {
                                rst.note_restored(*b);
                            }
                            for b in &dropped {
                                rst.note_dropped(*b);
                            }
                        }
                        // A transform block's bytes left both tiers:
                        // re-plan the still-needed ones through lineage —
                        // the same registration steps as a kill's
                        // recompute closure.
                        let to_plan: Vec<BlockId> = dropped
                            .into_iter()
                            .filter(|b| !spill_recomputed.contains(b))
                            .collect();
                        if !to_plan.is_empty() {
                            let plan = plan_dropped_blocks(
                                &to_plan,
                                &lineage,
                                &all_tasks,
                                &mut tracker,
                                &mut refcounts,
                                &mut next_task_id,
                            );
                            spill_recomputed.extend(plan.lost_durable.iter().copied());
                            if !plan.recompute.is_empty() {
                                tier_global.spill_recompute_tasks += plan.recompute.len() as u64;
                                recompute_planned
                                    .write()
                                    .expect("recompute set")
                                    .plan(&plan.recompute);
                                for t in &plan.recompute {
                                    trace.emit(0, None, || TraceEvent::RecomputePlanned {
                                        block: t.output,
                                        task: t.id,
                                    });
                                }
                                if cfg.policy.dag_aware() {
                                    if routed {
                                        coalescer.stage(&plan.refcount_changes);
                                    } else {
                                        let batch = WorkerMsg::RefCounts(Arc::new(
                                            plan.refcount_changes.clone(),
                                        ));
                                        ctrl_to_alive(&queues, &alive, batch);
                                        msgs.refcount_updates += alive.alive_count() as u64;
                                    }
                                }
                                if track_groups {
                                    register_recompute_groups!(&plan.recompute);
                                }
                                for t in &plan.recompute {
                                    task_index.insert(t.id, Arc::new(t.clone()));
                                    *recompute_per_job.entry(t.job.0).or_default() += 1;
                                }
                                tracker.add_tasks(plan.recompute);
                                dispatch_after = true;
                            }
                        }
                    }
                    DriverMsg::Fatal(e) => return Err(EngineError::Invariant(e)),
                }
            }
            // Flush coalesced deltas BEFORE dispatching: the worker queue
            // dequeues control before data, so every task dispatched below
            // runs against these counts, never stale ones.
            msgs.refcount_updates +=
                coalescer.flush(|w, batch| queues[w].send_ctrl(WorkerMsg::RefCounts(batch)));

            // Apply due topology-plan steps, each at a quiescent point:
            // dispatch is held at the trigger boundary (below) and the
            // step lands only once nothing is in flight, so the completed
            // prefix — and therefore the lost or migrated block set — is
            // exactly the first `at_dispatch` tasks of the dispatch order.
            let mut repaired = false;
            loop {
                let due = match actions.first() {
                    Some(&(t, _)) => dispatched >= t,
                    None => false,
                };
                let auto_due = auto_cfg.is_some() && dispatched >= next_check;
                if (!due && !auto_due) || in_flight > 0 || pending_total > 0 {
                    break;
                }
                if !due {
                    // Autoscale checkpoint. Dispatch was held at
                    // `next_check`, so the ready-queue depth is the
                    // genuine backlog; decisions become Join / Kill
                    // actions consumed by the arms below.
                    let a = auto_cfg.as_ref().expect("autoscale gate");
                    while next_check <= dispatched {
                        next_check += a.check_every;
                    }
                    repaired = true;
                    let ready = tracker.ready_len() as u64;
                    let alive_n = alive.alive_count();
                    let mut used = 0u64;
                    for wid in alive.alive_workers() {
                        used += shared[wid.0 as usize].store.used();
                    }
                    let cap = alive_n as u64 * cfg.cache_capacity_per_worker;
                    let mem_frac = if cap == 0 { 0.0 } else { used as f64 / cap as f64 };
                    let want_up = (ready >= a.scale_up_ready as u64 || mem_frac >= a.mem_high)
                        && alive_n < a.max_workers.min(ceiling);
                    let want_down = !want_up
                        && ready <= a.scale_down_ready as u64
                        && mem_frac <= a.mem_low
                        && alive_n > a.min_workers;
                    if want_up {
                        // Lowest-indexed pending slot comes online.
                        let joiner = (0..ceiling).map(WorkerId).find(|w| !alive.is_alive(*w));
                        if let Some(j) = joiner {
                            trace.emit(0, None, || TraceEvent::ScaleDecision {
                                action: "up",
                                worker: j,
                                ready,
                                mem_used: used,
                            });
                            actions.insert(0, (dispatched, RepairAction::Join { worker: j }));
                        }
                    } else if want_down {
                        // Highest-indexed alive worker retires; its state
                        // tears down through the shared Kill arm (no
                        // restart scheduled).
                        if let Some(v) = alive.alive_workers().last() {
                            trace.emit(0, None, || TraceEvent::ScaleDecision {
                                action: "down",
                                worker: v,
                                ready,
                                mem_used: used,
                            });
                            scale.workers_retired += 1;
                            actions.insert(
                                0,
                                (dispatched, RepairAction::Kill { worker: v, restart_after: None }),
                            );
                        }
                    }
                    continue;
                }
                let (_, action) = actions.remove(0);
                // Quiescent drain (DESIGN.md §8): nothing is in flight
                // anywhere, so catch up the stores' deferred read touches
                // and empty the trace rings — both without ever touching
                // the lock-free read hot path mid-task.
                for node in shared.iter() {
                    node.store.quiesce();
                }
                if let Some(rec) = trace.recorder() {
                    rec.drain();
                }
                match action {
                    RepairAction::Kill {
                        worker,
                        restart_after,
                    } => {
                        trace.emit(0, None, || TraceEvent::WorkerKilled { worker });
                        // (a) Memory loss: wipe the store, the peer
                        // replica, and — crash semantics — the local
                        // spill area, which dies with its worker.
                        let node = &shared[worker.0 as usize];
                        let lost_cached = node.store.clear();
                        let lost_spilled: Vec<BlockId> = node
                            .spill
                            .as_ref()
                            .map(|m| m.lock().unwrap().clear())
                            .unwrap_or_default();
                        if let Some(files) = node.spill_files.as_ref() {
                            files.wipe()?;
                        }
                        if let Some(rst) = restorer.as_mut() {
                            for b in lost_cached.iter().chain(lost_spilled.iter()) {
                                rst.forget(*b);
                            }
                        }
                        node.state.lock().unwrap().peers = WorkerPeerTracker::default();
                        // (b) Durable loss + minimal recompute closure
                        // (uses the pre-kill placement).
                        let plan = plan_worker_loss(
                            worker,
                            &alive,
                            &lineage,
                            &all_tasks,
                            &mut tracker,
                            &mut refcounts,
                            &mut next_task_id,
                        );
                        for &b in &plan.lost_durable {
                            disk.delete(b)?;
                        }
                        // (c) Re-home orphans over the survivors.
                        let alive_before = alive.clone();
                        alive.kill(worker);
                        if alive.alive_count() == 0 {
                            return Err(EngineError::Invariant(
                                "failure plan killed every worker; nothing can run the job"
                                    .into(),
                            ));
                        }
                        *alive_shared.write().expect("alive lock poisoned") = alive.clone();
                        coalescer.set_alive(&alive);
                        // (d) Metadata repair, step 1: every block cached
                        // at the dead worker is a mass eviction — the
                        // master invalidates its complete groups and
                        // broadcasts to the survivors.
                        if cfg.policy.peer_aware() {
                            // Spilled blocks kept their groups whole;
                            // losing the spill area breaks them like any
                            // other mass eviction.
                            for &b in lost_cached.iter().chain(lost_spilled.iter()) {
                                if let Some(bb) = master.fail_member(b) {
                                    broadcast_invalidation(
                                        bb, routed, &master, &alive, &queues, &mut msgs, &trace,
                                    );
                                }
                            }
                            // (d2) Step 2, home-routed only: live groups
                            // whose members re-homed must exist at the new
                            // homes, or future inserts there would evict
                            // silently (the §1 invariant). Broadcast mode
                            // already has every group everywhere.
                            if routed {
                                let mut per_worker: Vec<Vec<PeerGroup>> =
                                    vec![Vec::new(); ceiling as usize];
                                for g in &registered_groups {
                                    if master.task_retired(g.task) != Some(false) {
                                        continue;
                                    }
                                    for m in &g.members {
                                        let new_home = alive.home_of(*m);
                                        if alive_before.home_of(*m) != new_home {
                                            per_worker[new_home.0 as usize].push(g.clone());
                                        }
                                    }
                                }
                                for (w, mut subset) in per_worker.into_iter().enumerate() {
                                    if subset.is_empty() {
                                        continue;
                                    }
                                    subset.sort_by_key(|g| g.id);
                                    subset.dedup_by_key(|g| g.id);
                                    let incomplete: Vec<GroupId> = subset
                                        .iter()
                                        .filter(|g| master.group_complete(g.task) == Some(false))
                                        .map(|g| g.id)
                                        .collect();
                                    master.add_interest(&subset, WorkerId(w as u32));
                                    queues[w].send_ctrl(WorkerMsg::RegisterPeers {
                                        groups: Arc::new(subset),
                                        incomplete: Arc::new(incomplete),
                                    });
                                }
                            }
                        }
                        // (d3) Re-homed blocks' ref counts must exist at
                        // their new homes — the initial routed seed went
                        // only to the dead worker. Stage together with
                        // the recompute closure's reference bumps and
                        // flush now, ahead of this cycle's dispatch.
                        if cfg.policy.dag_aware() {
                            if routed {
                                let moved: Vec<(BlockId, u32)> = refcounts
                                    .iter()
                                    .filter(|(b, _)| {
                                        alive_before.home_of(**b) != alive.home_of(**b)
                                    })
                                    .map(|(b, c)| (*b, *c))
                                    .collect();
                                coalescer.stage(&moved);
                                coalescer.stage(&plan.refcount_changes);
                                msgs.refcount_updates += coalescer.flush(|w, batch| {
                                    queues[w].send_ctrl(WorkerMsg::RefCounts(batch))
                                });
                            } else if !plan.refcount_changes.is_empty() {
                                // Broadcast replicas already hold every
                                // count; only the recompute bumps are new.
                                let batch = WorkerMsg::RefCounts(Arc::new(
                                    plan.refcount_changes.clone(),
                                ));
                                ctrl_to_alive(&queues, &alive, batch);
                                msgs.refcount_updates += alive.alive_count() as u64;
                            }
                        }
                        // (e) Schedule the lineage recompute.
                        recovery.workers_killed += 1;
                        recovery.blocks_lost_cached += lost_cached.len() as u64;
                        recovery.blocks_lost_spilled += lost_spilled.len() as u64;
                        recovery.blocks_lost_durable += plan.lost_durable.len() as u64;
                        recovery.recompute_tasks += plan.recompute.len() as u64;
                        recovery.recompute_bytes += plan.recompute_bytes();
                        if !plan.recompute.is_empty() {
                            recompute_planned.write().expect("recompute set").plan(&plan.recompute);
                            if track_groups {
                                register_recompute_groups!(&plan.recompute);
                            }
                            for t in &plan.recompute {
                                trace.emit(0, None, || TraceEvent::RecomputePlanned {
                                    block: t.output,
                                    task: t.id,
                                });
                                recompute_pending.insert(t.id);
                                task_index.insert(t.id, Arc::new(t.clone()));
                                *recompute_per_job.entry(t.job.0).or_default() += 1;
                            }
                            tracker.add_tasks(plan.recompute);
                            if recovery_t0.is_none() {
                                recovery_t0 = Some(Instant::now());
                            }
                        }
                        if let Some(after) = restart_after {
                            let trigger = dispatched + after;
                            let pos = actions.partition_point(|(t, _)| *t <= trigger);
                            actions.insert(pos, (trigger, RepairAction::Revive { worker }));
                        }
                    }
                    RepairAction::Revive { worker } => {
                        trace.emit(0, None, || TraceEvent::WorkerRevived { worker });
                        alive.revive(worker);
                        *alive_shared.write().expect("alive lock poisoned") = alive.clone();
                        coalescer.set_alive(&alive);
                        // Blocks whose home reverts to the revived worker
                        // are unreachable at their kill-era probe homes:
                        // purge them (their durable copies remain) and
                        // break their groups.
                        for v in alive.alive_workers() {
                            if v == worker {
                                continue;
                            }
                            let vnode = &shared[v.0 as usize];
                            let vstore = &vnode.store;
                            for b in vstore.cached_blocks() {
                                if alive.home_of(b) != v && vstore.remove(b).is_some() {
                                    // A purged restored resident must not
                                    // leave its Memory tier record behind.
                                    vstore.clear_tier(b);
                                    if let Some(rst) = restorer.as_mut() {
                                        rst.forget(b);
                                    }
                                    if cfg.policy.peer_aware() {
                                        if let Some(bb) = master.fail_member(b) {
                                            broadcast_invalidation(
                                                bb, routed, &master, &alive, &queues, &mut msgs,
                                                &trace,
                                            );
                                        }
                                    }
                                }
                            }
                            // Spill copies whose home reverts to the
                            // revived worker are unreachable under the
                            // restored mapping: purge them (readers fall
                            // back to the durable copies, like the purged
                            // memory blocks above).
                            if spill_on {
                                let stale: Vec<BlockId> = vnode
                                    .spill
                                    .as_ref()
                                    .map(|m| {
                                        m.lock()
                                            .unwrap()
                                            .resident_blocks()
                                            .into_iter()
                                            .filter(|b| alive.home_of(*b) != v)
                                            .collect()
                                    })
                                    .unwrap_or_default();
                                for b in stale {
                                    if let Some(m) = vnode.spill.as_ref() {
                                        m.lock().unwrap().release(b);
                                    }
                                    if let Some(files) = vnode.spill_files.as_ref() {
                                        let _ = files.delete(b);
                                    }
                                    vstore.clear_tier(b);
                                    if let Some(rst) = restorer.as_mut() {
                                        rst.forget(b);
                                    }
                                    if cfg.policy.peer_aware() {
                                        if let Some(bb) = master.fail_member(b) {
                                            broadcast_invalidation(
                                                bb, routed, &master, &alive, &queues, &mut msgs,
                                                &trace,
                                            );
                                        }
                                    }
                                }
                            }
                        }
                        // Re-seed metadata at the cold replica: current
                        // ref counts and the unretired groups it homes.
                        if cfg.policy.dag_aware() {
                            let counts: Vec<(BlockId, u32)> = refcounts
                                .iter()
                                .filter(|(b, _)| !routed || alive.home_of(**b) == worker)
                                .map(|(b, c)| (*b, *c))
                                .collect();
                            if !counts.is_empty() {
                                queues[worker.0 as usize]
                                    .send_ctrl(WorkerMsg::RefCounts(Arc::new(counts)));
                                msgs.refcount_updates += 1;
                            }
                        }
                        if track_groups {
                            let subset: Vec<PeerGroup> = registered_groups
                                .iter()
                                .filter(|g| master.task_retired(g.task) == Some(false))
                                .filter(|g| {
                                    !routed
                                        || g.members.iter().any(|m| alive.home_of(*m) == worker)
                                })
                                .cloned()
                                .collect();
                            if !subset.is_empty() {
                                let incomplete: Vec<GroupId> = subset
                                    .iter()
                                    .filter(|g| master.group_complete(g.task) == Some(false))
                                    .map(|g| g.id)
                                    .collect();
                                if routed {
                                    master.add_interest(&subset, worker);
                                }
                                queues[worker.0 as usize].send_ctrl(WorkerMsg::RegisterPeers {
                                    groups: Arc::new(subset),
                                    incomplete: Arc::new(incomplete),
                                });
                            }
                        }
                        recovery.workers_restarted += 1;
                    }
                    RepairAction::Join { worker } => {
                        trace.emit(0, None, || TraceEvent::WorkerJoined { worker });
                        alive.revive(worker);
                        *alive_shared.write().expect("alive lock poisoned") = alive.clone();
                        coalescer.set_alive(&alive);
                        let ji = worker.0 as usize;
                        let jnode = &shared[ji];
                        // Re-seed the newcomer's metadata BEFORE any
                        // payload moves, so migration inserts land on
                        // live policy state. Direct store/replica access
                        // is the Kill arm's precedent: the cluster is
                        // quiescent, the newcomer's thread is parked.
                        if cfg.policy.dag_aware() {
                            let counts: Vec<(BlockId, u32)> = refcounts
                                .iter()
                                .filter(|(b, _)| !routed || alive.home_of(**b) == worker)
                                .map(|(b, c)| (*b, *c))
                                .collect();
                            if !counts.is_empty() {
                                for &(b, count) in &counts {
                                    jnode
                                        .store
                                        .policy_event(PolicyEvent::RefCount { block: b, count });
                                }
                                msgs.refcount_updates += 1;
                            }
                        }
                        if track_groups {
                            let subset: Vec<PeerGroup> = registered_groups
                                .iter()
                                .filter(|g| master.task_retired(g.task) == Some(false))
                                .filter(|g| {
                                    !routed
                                        || g.members.iter().any(|m| alive.home_of(*m) == worker)
                                })
                                .cloned()
                                .collect();
                            if !subset.is_empty() {
                                let incomplete: Vec<GroupId> = subset
                                    .iter()
                                    .filter(|g| master.group_complete(g.task) == Some(false))
                                    .map(|g| g.id)
                                    .collect();
                                if routed {
                                    master.add_interest(&subset, worker);
                                }
                                let mut st = jnode.state.lock().unwrap();
                                st.peers.register(&subset, &incomplete);
                                for g in &subset {
                                    for &b in &g.members {
                                        let count = st.peers.effective_count(b);
                                        jnode.store.policy_event(PolicyEvent::EffectiveCount {
                                            block: b,
                                            count,
                                        });
                                    }
                                }
                            }
                        }
                        // Incremental re-homing: ONLY blocks whose stable
                        // probe home is now the newcomer move (the
                        // placement analogue of a revive). Group fragments
                        // migrate as pinned batches — every member is
                        // pinned at the newcomer before the first insert,
                        // so no migration insert can evict a co-member
                        // mid-batch and a group is never split by its own
                        // warm-up.
                        let donors: Vec<WorkerId> =
                            alive.alive_workers().filter(|v| *v != worker).collect();
                        for v in donors {
                            let vi = v.0 as usize;
                            let vnode = &shared[vi];
                            let moving: Vec<BlockId> = vnode
                                .store
                                .cached_blocks()
                                .into_iter()
                                .filter(|b| alive.home_of(*b) == worker)
                                .collect();
                            let mut batches: Vec<(GroupId, Vec<BlockId>)> = Vec::new();
                            let mut single: Vec<BlockId> = moving.clone();
                            if track_groups {
                                let mset: FxHashSet<BlockId> = moving.iter().copied().collect();
                                let mut batched: FxHashSet<BlockId> = FxHashSet::default();
                                for g in registered_groups
                                    .iter()
                                    .filter(|g| master.task_retired(g.task) == Some(false))
                                {
                                    let frag: Vec<BlockId> = g
                                        .members
                                        .iter()
                                        .copied()
                                        .filter(|m| mset.contains(m) && !batched.contains(m))
                                        .collect();
                                    if !frag.is_empty() {
                                        batched.extend(frag.iter().copied());
                                        batches.push((g.id, frag));
                                    }
                                }
                                single.retain(|b| !batched.contains(b));
                            }
                            for b in single.iter() {
                                batches.push((GroupId(u64::MAX), vec![*b]));
                            }
                            for (gid, frag) in batches {
                                let grouped = gid != GroupId(u64::MAX);
                                if grouped {
                                    for &b in &frag {
                                        jnode.store.pin(b);
                                    }
                                }
                                let mut moved = 0u64;
                                for &b in &frag {
                                    // A donor-pinned block stays put (same
                                    // rule as the revive purge).
                                    let Some(data) = vnode.store.remove(b) else {
                                        continue;
                                    };
                                    vnode.store.clear_tier(b);
                                    let bytes = (data.len() * 4) as u64;
                                    trace.emit(ji + 1, None, || TraceEvent::BlockInserted {
                                        block: b,
                                        worker,
                                    });
                                    // Plain insert (no demotion cascade):
                                    // a migration victim is dropped, not
                                    // spilled — both engines share this
                                    // simplification so their decision
                                    // streams stay identical.
                                    let outcome = jnode.store.insert(b, data);
                                    for &ev in &outcome.evicted {
                                        trace.emit(ji + 1, None, || TraceEvent::BlockEvicted {
                                            block: ev,
                                            worker,
                                        });
                                        if spill_on {
                                            jnode.store.clear_tier(ev);
                                        }
                                    }
                                    if cfg.policy.peer_aware() && !outcome.evicted.is_empty() {
                                        let report: Vec<BlockId> = {
                                            let st = jnode.state.lock().unwrap();
                                            outcome
                                                .evicted
                                                .iter()
                                                .copied()
                                                .filter(|bb| st.peers.should_report_eviction(*bb))
                                                .collect()
                                        };
                                        for rb in report {
                                            trace.emit(0, None, || {
                                                TraceEvent::EvictionReported { block: rb }
                                            });
                                            msgs.eviction_reports += 1;
                                            if let Some(bb) = master.on_eviction_report(rb) {
                                                broadcast_invalidation(
                                                    bb, routed, &master, &alive, &queues,
                                                    &mut msgs, &trace,
                                                );
                                            }
                                        }
                                    }
                                    scale.blocks_migrated += 1;
                                    scale.migration_bytes += bytes;
                                    moved += 1;
                                }
                                if grouped {
                                    for &b in &frag {
                                        jnode.store.unpin(b);
                                    }
                                    if moved > 0 {
                                        scale.groups_migrated += 1;
                                        trace.emit(0, None, || TraceEvent::GroupMigrated {
                                            group: gid,
                                            from: v,
                                            to: worker,
                                            blocks: moved,
                                        });
                                    }
                                }
                            }
                            // Spilled copies whose home probes to the
                            // newcomer move with their accounting: each
                            // group fragment is offered to the newcomer's
                            // spill area all-or-nothing — adopted whole
                            // (the backing file changes host), or purged
                            // whole (Revive-style; readers fall back to
                            // the durable copies). Never a partial move.
                            if spill_on {
                                let moving_spill: Vec<BlockId> = vnode
                                    .spill
                                    .as_ref()
                                    .map(|m| {
                                        m.lock()
                                            .unwrap()
                                            .resident_blocks()
                                            .into_iter()
                                            .filter(|b| alive.home_of(*b) == worker)
                                            .collect()
                                    })
                                    .unwrap_or_default();
                                let mut sbatches: Vec<(Option<GroupId>, Vec<BlockId>)> =
                                    Vec::new();
                                let mset: FxHashSet<BlockId> =
                                    moving_spill.iter().copied().collect();
                                let mut batched: FxHashSet<BlockId> = FxHashSet::default();
                                if track_groups {
                                    for g in registered_groups
                                        .iter()
                                        .filter(|g| master.task_retired(g.task) == Some(false))
                                    {
                                        let frag: Vec<BlockId> = g
                                            .members
                                            .iter()
                                            .copied()
                                            .filter(|m| mset.contains(m) && !batched.contains(m))
                                            .collect();
                                        if !frag.is_empty() {
                                            batched.extend(frag.iter().copied());
                                            sbatches.push((Some(g.id), frag));
                                        }
                                    }
                                }
                                for b in moving_spill
                                    .iter()
                                    .copied()
                                    .filter(|b| !batched.contains(b))
                                {
                                    sbatches.push((None, vec![b]));
                                }
                                for (gid, frag) in sbatches {
                                    let set: Vec<(BlockId, u64)> = {
                                        let mut vm = vnode
                                            .spill
                                            .as_ref()
                                            .expect("spill on")
                                            .lock()
                                            .unwrap();
                                        frag.iter()
                                            .filter_map(|&b| vm.release(b).map(|by| (b, by)))
                                            .collect()
                                    };
                                    if set.is_empty() {
                                        continue;
                                    }
                                    // The `dead` predicate consults the
                                    // newcomer's freshly re-seeded peer
                                    // replica, mirroring demote_evicted.
                                    // Locks taken one at a time (worker
                                    // threads order them differently).
                                    let jresidents: Vec<BlockId> = jnode
                                        .spill
                                        .as_ref()
                                        .expect("spill on")
                                        .lock()
                                        .unwrap()
                                        .resident_blocks();
                                    let dead_set: FxHashSet<BlockId> = {
                                        let st = jnode.state.lock().unwrap();
                                        jresidents
                                            .into_iter()
                                            .filter(|&b| !st.peers.unconsumed(b))
                                            .collect()
                                    };
                                    let outcome = jnode
                                        .spill
                                        .as_ref()
                                        .expect("spill on")
                                        .lock()
                                        .unwrap()
                                        .offer(&set, |bb| dead_set.contains(&bb));
                                    if outcome.admitted {
                                        for &(b, _) in &set {
                                            // The payload follows the
                                            // accounting: the spill file
                                            // changes host.
                                            if let (Some(vf), Some(jf)) = (
                                                vnode.spill_files.as_ref(),
                                                jnode.spill_files.as_ref(),
                                            ) {
                                                let (data, _) = vf.read(b)?;
                                                jf.write(b, &data)?;
                                                vf.delete(b)?;
                                            }
                                            vnode.store.clear_tier(b);
                                            jnode.store.set_tier(b, BlockTier::SpilledLocal);
                                        }
                                        if !outcome.evicted.is_empty() {
                                            jnode.state.lock().unwrap().tier.spill_evictions +=
                                                outcome.evicted.len() as u64;
                                            for &ev in &outcome.evicted {
                                                jnode.store.clear_tier(ev);
                                                if let Some(jf) = jnode.spill_files.as_ref() {
                                                    let _ = jf.delete(ev);
                                                }
                                                trace.emit(ji + 1, None, || {
                                                    TraceEvent::BlockDropped {
                                                        block: ev,
                                                        worker,
                                                    }
                                                });
                                                if let Some(rst) = restorer.as_mut() {
                                                    rst.note_dropped(ev);
                                                }
                                            }
                                            // Re-plan the still-needed
                                            // dropped blocks — the
                                            // TierReport drop path inline.
                                            let to_plan: Vec<BlockId> = outcome
                                                .evicted
                                                .iter()
                                                .copied()
                                                .filter(|bb| !spill_recomputed.contains(bb))
                                                .collect();
                                            if !to_plan.is_empty() {
                                                let plan = plan_dropped_blocks(
                                                    &to_plan,
                                                    &lineage,
                                                    &all_tasks,
                                                    &mut tracker,
                                                    &mut refcounts,
                                                    &mut next_task_id,
                                                );
                                                spill_recomputed
                                                    .extend(plan.lost_durable.iter().copied());
                                                if !plan.recompute.is_empty() {
                                                    tier_global.spill_recompute_tasks +=
                                                        plan.recompute.len() as u64;
                                                    recompute_planned
                                                        .write()
                                                        .expect("recompute set")
                                                        .plan(&plan.recompute);
                                                    for t in &plan.recompute {
                                                        trace.emit(0, None, || {
                                                            TraceEvent::RecomputePlanned {
                                                                block: t.output,
                                                                task: t.id,
                                                            }
                                                        });
                                                    }
                                                    if cfg.policy.dag_aware() {
                                                        if routed {
                                                            coalescer
                                                                .stage(&plan.refcount_changes);
                                                            msgs.refcount_updates +=
                                                                coalescer.flush(|w, batch| {
                                                                    queues[w].send_ctrl(
                                                                        WorkerMsg::RefCounts(
                                                                            batch,
                                                                        ),
                                                                    )
                                                                });
                                                        } else {
                                                            let batch = WorkerMsg::RefCounts(
                                                                Arc::new(
                                                                    plan.refcount_changes
                                                                        .clone(),
                                                                ),
                                                            );
                                                            ctrl_to_alive(
                                                                &queues, &alive, batch,
                                                            );
                                                            msgs.refcount_updates +=
                                                                alive.alive_count() as u64;
                                                        }
                                                    }
                                                    if track_groups {
                                                        register_recompute_groups!(
                                                            &plan.recompute
                                                        );
                                                    }
                                                    for t in &plan.recompute {
                                                        task_index
                                                            .insert(t.id, Arc::new(t.clone()));
                                                        *recompute_per_job
                                                            .entry(t.job.0)
                                                            .or_default() += 1;
                                                    }
                                                    tracker.add_tasks(plan.recompute);
                                                }
                                            }
                                        }
                                        scale.blocks_migrated += set.len() as u64;
                                        scale.migration_bytes +=
                                            set.iter().map(|(_, by)| *by).sum::<u64>();
                                        if let Some(g) = gid {
                                            scale.groups_migrated += 1;
                                            let blocks = set.len() as u64;
                                            trace.emit(0, None, || TraceEvent::GroupMigrated {
                                                group: g,
                                                from: v,
                                                to: worker,
                                                blocks,
                                            });
                                        }
                                    } else {
                                        // Refused whole: purge Revive-style
                                        // (readers fall back to the durable
                                        // copies).
                                        for &(b, _) in &set {
                                            if let Some(vf) = vnode.spill_files.as_ref() {
                                                let _ = vf.delete(b);
                                            }
                                            vnode.store.clear_tier(b);
                                            if let Some(rst) = restorer.as_mut() {
                                                rst.forget(b);
                                            }
                                            if cfg.policy.peer_aware() {
                                                if let Some(bb) = master.fail_member(b) {
                                                    broadcast_invalidation(
                                                        bb, routed, &master, &alive, &queues,
                                                        &mut msgs, &trace,
                                                    );
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                        scale.workers_joined += 1;
                    }
                }
                repaired = true;
            }

            // Admit due/overdue jobs and dispatch, held at the next
            // failure or arrival boundary so both stay deterministic.
            if dispatch_after || repaired {
                admit_and_dispatch!();
            }
        }
        debug_assert_eq!(in_flight, 0);
        debug_assert!(coalescer.is_empty());
        let compute_started_at = compute_started.unwrap_or(t0);

        // --- teardown + report ---------------------------------------------
        // Queue closing is owned by `_close_on_drop`; Shutdown alone ends
        // each worker loop once its data lane drains.
        for q in &queues {
            q.send_data(WorkerMsg::Shutdown);
        }
        for j in joins {
            let _ = j.join();
        }
        let wall = t0.elapsed();
        let makespan = cfg.unscale(wall);
        let compute_makespan = cfg.unscale(compute_started_at.elapsed());

        // Final trace drain: every worker has exited, so the rings hold
        // the tail of the run.
        if let Some(rec) = trace.recorder() {
            rec.drain();
        }

        // Final teardown sample: workers have exited, so the counters
        // are their end-of-run values.
        if tl_every != 0 {
            tl_sample!();
        }

        let mut access = AccessStats::default();
        let mut per_job_access: FxHashMap<JobId, AccessStats> = FxHashMap::default();
        let mut attribution = AttributionStats::default();
        let mut evictions = 0u64;
        let mut rejected = 0u64;
        let mut tier = tier_global;
        for node in shared.iter() {
            // Catch up any deferred read touches before reading policy-
            // side counters (no-op on the Locked read path).
            node.store.flush_touches();
            let st = node.state.lock().unwrap();
            access.merge(&st.access);
            attribution.merge(&st.attribution);
            tier.merge(&st.tier);
            for (j, a) in st.per_job_access.iter() {
                per_job_access.entry(*j).or_default().merge(a);
            }
            let cache_stats = node.store.stats();
            evictions += cache_stats.evictions;
            rejected += cache_stats.rejected;
        }
        tier.finalize();
        msgs.profile_broadcasts = master.stats.profile_broadcasts;

        let mut jobs: Vec<JobStats> = Vec::new();
        for (si, spec) in queue.jobs.iter().enumerate() {
            for dag in &spec.workload.dags {
                jobs.push(JobStats {
                    job: dag.job.0,
                    priority: spec.priority,
                    arrival: spec.arrival,
                    admitted_at_dispatch: admitted_at[si],
                    tasks_run: tasks_run_per_job.get(&dag.job.0).copied().unwrap_or(0),
                    recompute_tasks: recompute_per_job.get(&dag.job.0).copied().unwrap_or(0),
                    access: per_job_access.get(&dag.job).copied().unwrap_or_default(),
                    jct: job_jct.get(&dag.job.0).copied().unwrap_or_default(),
                    task_latency: lat_per_job.get(&dag.job.0).cloned().unwrap_or_default(),
                    queue_wait: wait_per_job.get(&dag.job.0).cloned().unwrap_or_default(),
                });
            }
        }

        Ok(FleetReport {
            aggregate: RunReport {
                policy: cfg.policy.name().to_string(),
                makespan,
                compute_makespan,
                job_times: job_done_at,
                access,
                messages: msgs,
                tasks_run: dispatched,
                evictions,
                rejected_inserts: rejected,
                cache_capacity: cfg.total_cache(),
                recovery,
                scale,
                tier,
                net: Default::default(),
                attribution,
                timeline,
            },
            jobs,
        })
    }
}

impl crate::engine::Engine for ClusterEngine {
    fn run(&self, queue: &JobQueue) -> Result<FleetReport> {
        self.execute(queue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{DiskConfig, PolicyKind};
    use crate::engine::Engine;
    use crate::workload;

    fn fast_cfg(policy: PolicyKind, cache_blocks: u64) -> EngineConfig {
        EngineConfig::builder()
            .num_workers(2)
            .block_len(4096)
            .cache_blocks(cache_blocks)
            .policy(policy)
            .disk(DiskConfig {
                unthrottled: true,
                ..Default::default()
            })
            .net(crate::common::config::NetConfig {
                per_message_latency: Duration::ZERO,
            })
            .build()
            .expect("valid config")
    }

    #[test]
    fn zip_single_runs_to_completion() {
        let cfg = fast_cfg(PolicyKind::Lru, 100);
        let w = workload::zip_single(8, 4096);
        let report = ClusterEngine::new(cfg).run_workload(&w).unwrap();
        assert_eq!(report.tasks_run, 8);
        assert_eq!(report.access.accesses, 16);
        // Plenty of cache: everything hits, all effective.
        assert_eq!(report.access.mem_hits, 16);
        assert_eq!(report.access.effective_hits, 16);
        assert_eq!(report.hit_ratio(), 1.0);
    }

    #[test]
    fn two_stage_cascades() {
        let cfg = fast_cfg(PolicyKind::Lerc, 100);
        let w = workload::two_stage_zip_agg(6, 4096);
        let report = ClusterEngine::new(cfg).run_workload(&w).unwrap();
        assert_eq!(report.tasks_run, 12);
        assert!(report.job_times.contains_key(&0));
    }

    #[test]
    fn all_policies_complete_under_pressure() {
        for policy in PolicyKind::ALL {
            let cfg = fast_cfg(policy, 3); // tiny cache
            let w = workload::multi_tenant_zip(3, 4, 4096);
            let report = ClusterEngine::new(cfg).run_workload(&w).unwrap();
            assert_eq!(report.tasks_run, 12, "{}", policy.name());
            assert!(report.access.disk_reads > 0, "{}", policy.name());
        }
    }

    #[test]
    fn lerc_beats_lru_on_effective_ratio_under_pressure() {
        // Cache sized ~2/3 of inputs: the paper's headline geometry.
        let w = workload::multi_tenant_zip(4, 6, 4096);
        let run = |policy| {
            let cfg = fast_cfg(policy, 8); // 2 workers * 8 = 16 of 48 blocks... scaled below
            ClusterEngine::new(cfg).run_workload(&w).unwrap()
        };
        let lru = run(PolicyKind::Lru);
        let lerc = run(PolicyKind::Lerc);
        assert!(
            lerc.effective_hit_ratio() >= lru.effective_hit_ratio(),
            "LERC {} < LRU {}",
            lerc.effective_hit_ratio(),
            lru.effective_hit_ratio()
        );
    }

    #[test]
    fn job_queue_interleaves_and_reports_per_job() {
        let cfg = fast_cfg(PolicyKind::Lerc, 100);
        let queue = workload::multijob_zip_shared(2, 4, 4096, true, 2);
        let fleet = Engine::run(&ClusterEngine::new(cfg), &queue).unwrap();
        assert_eq!(fleet.aggregate.tasks_run, 8);
        assert_eq!(fleet.jobs.len(), 2);
        for j in &fleet.jobs {
            assert_eq!(j.tasks_run, 4);
            assert!(j.jct > Duration::ZERO);
        }
        // Per-job access accounting covers the aggregate exactly.
        let per_job: u64 = fleet.jobs.iter().map(|j| j.access.accesses).sum();
        assert_eq!(per_job, fleet.aggregate.access.accesses);
        assert_eq!(fleet.aggregate.access.accesses, 16);
    }

    #[test]
    fn peer_messages_only_for_peer_aware_policies() {
        let w = workload::multi_tenant_zip(3, 4, 4096);
        let lru = ClusterEngine::new(fast_cfg(PolicyKind::Lru, 2)).run_workload(&w).unwrap();
        assert_eq!(lru.messages.peer_protocol_total(), 0);
        let lerc = ClusterEngine::new(fast_cfg(PolicyKind::Lerc, 2)).run_workload(&w).unwrap();
        assert!(lerc.messages.peer_protocol_total() > 0);
    }

    #[test]
    fn join_plan_completes_and_counts_migrations() {
        // A pending slot joins mid-run: the run completes, the joiner is
        // counted, and with the placement modulus at the ceiling some
        // cached blocks re-home to it and migrate.
        let mut cfg = fast_cfg(PolicyKind::Lerc, 100);
        cfg.topology = crate::recovery::TopologyPlan::join_at(2, 10);
        let w = workload::multi_tenant_zip(3, 4, 4096);
        let report = ClusterEngine::new(cfg).run_workload(&w).unwrap();
        assert_eq!(report.tasks_run, 12);
        assert_eq!(report.scale.workers_joined, 1);
        assert!(
            report.scale.blocks_migrated >= 1,
            "expected warm-up migration to move at least one re-homed block"
        );
    }

    #[test]
    fn multi_shard_store_completes_workloads() {
        // The sharded data path (several stripes per worker) still runs
        // every policy to completion with conserved accounting.
        for policy in [PolicyKind::Lru, PolicyKind::Lerc] {
            let mut cfg = fast_cfg(policy, 6);
            cfg.cache_shards = 4;
            let w = workload::multi_tenant_zip(3, 4, 4096);
            let report = ClusterEngine::new(cfg).run_workload(&w).unwrap();
            assert_eq!(report.tasks_run, 12, "{}", policy.name());
            let a = &report.access;
            assert_eq!(a.accesses, a.mem_hits + a.disk_reads, "{}", policy.name());
        }
    }
}
