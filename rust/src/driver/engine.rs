//! ClusterEngine: assemble the cluster, run a workload, produce a report.

use crate::common::config::{ComputeMode, EngineConfig};
use crate::common::error::{EngineError, Result};
use crate::common::fxhash::FxHashMap;
use crate::common::ids::{BlockId, JobId, TaskId};
use crate::common::tempdir::TempDir;
use crate::dag::analysis::{peer_groups, PeerGroup, RefCounts};
use crate::dag::task::{enumerate_tasks, Task};
use crate::driver::messages::{DriverMsg, WorkerMsg};
use crate::driver::worker::{worker_loop, SharedWorkers, WorkerContext, WorkerNode};
use crate::metrics::{MessageStats, RunReport};
use crate::peer::PeerTrackerMaster;
use crate::runtime::pjrt::{ComputeHandle, PjrtEngine};
use crate::runtime::SyntheticEngine;
use crate::scheduler::{home_worker, TaskTracker};
use crate::storage::DiskStore;
use crate::workload::Workload;
use std::collections::BTreeMap;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The threaded cluster engine. Construct with a config, `run` workloads.
pub struct ClusterEngine {
    cfg: EngineConfig,
}

impl ClusterEngine {
    pub fn new(cfg: EngineConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Run a workload to completion and report.
    pub fn run(&self, workload: &Workload) -> Result<RunReport> {
        workload.validate()?;
        let cfg = &self.cfg;

        // --- storage -------------------------------------------------
        let _tmp; // keeps the tempdir alive for the run
        let disk_dir = match &cfg.disk_dir {
            Some(d) => d.clone(),
            None => {
                let t = TempDir::new("engine")?;
                let p = t.path().to_path_buf();
                _tmp = t;
                p
            }
        };
        let disk = Arc::new(DiskStore::new(&disk_dir, cfg.disk)?);

        // --- compute service ------------------------------------------
        let (compute, service) = match &cfg.compute {
            ComputeMode::Pjrt { artifacts_dir } => {
                let dir = artifacts_dir.clone();
                ComputeHandle::spawn(move || {
                    let e = PjrtEngine::load(dir)?;
                    e.warmup()?;
                    Ok(e)
                })?
            }
            ComputeMode::Synthetic => ComputeHandle::spawn(|| Ok(SyntheticEngine::new()))?,
        };
        let _service = service.with_handle(compute.clone());

        // --- static analysis -------------------------------------------
        let mut next_task_id = 0u64;
        let mut all_tasks: Vec<Task> = Vec::new();
        let mut groups_per_job: Vec<(JobId, Vec<PeerGroup>)> = Vec::new();
        for dag in &workload.dags {
            let tasks = enumerate_tasks(dag, &mut next_task_id);
            groups_per_job.push((dag.job, peer_groups(&tasks)));
            all_tasks.extend(tasks);
        }
        let mut refcounts = RefCounts::from_tasks(&all_tasks);
        let task_index: FxHashMap<TaskId, Task> =
            all_tasks.iter().map(|t| (t.id, t.clone())).collect();
        let mut master = PeerTrackerMaster::default();
        let mut msgs = MessageStats::default();

        // --- workers ----------------------------------------------------
        let shared: SharedWorkers =
            Arc::new((0..cfg.num_workers).map(|_| WorkerNode::new(cfg)).collect());
        let (driver_tx, driver_rx) = channel::<DriverMsg>();
        let net_nanos = Arc::new(AtomicU64::new(0));
        let mut worker_txs: Vec<Sender<WorkerMsg>> = Vec::new(); // data plane
        let mut ctrl_txs: Vec<Sender<WorkerMsg>> = Vec::new(); // control plane
        let mut joins = Vec::new();
        for w in 0..cfg.num_workers {
            let (tx, rx) = channel::<WorkerMsg>();
            let (ctl_tx, ctl_rx) = channel::<WorkerMsg>();
            worker_txs.push(tx);
            ctrl_txs.push(ctl_tx);
            let ctx = WorkerContext {
                id: crate::common::ids::WorkerId(w),
                cfg: cfg.clone(),
                shared: shared.clone(),
                disk: disk.clone(),
                compute: compute.clone(),
                driver_tx: driver_tx.clone(),
                net_nanos: net_nanos.clone(),
            };
            joins.push(
                std::thread::Builder::new()
                    .name(format!("lerc-worker-{w}"))
                    .spawn(move || worker_loop(ctx, rx, ctl_rx))?,
            );
        }
        let send_all = |msg: WorkerMsg, txs: &[Sender<WorkerMsg>]| {
            for tx in txs {
                let _ = tx.send(msg.clone());
            }
        };

        // --- peer profile + initial ref counts ---------------------------
        if cfg.policy.peer_aware() {
            for (_job, groups) in &groups_per_job {
                master.register(groups);
                let arc = Arc::new(groups.clone());
                send_all(WorkerMsg::RegisterPeers(arc), &ctrl_txs);
            }
        }
        if cfg.policy.dag_aware() {
            let initial: Arc<Vec<(BlockId, u32)>> =
                Arc::new(refcounts.iter().map(|(b, c)| (*b, *c)).collect());
            send_all(WorkerMsg::RefCounts(initial), &ctrl_txs);
            msgs.refcount_updates += cfg.num_workers as u64;
        }

        // --- ingest phase -------------------------------------------------
        let block_len_of: FxHashMap<BlockId, usize> = workload
            .dags
            .iter()
            .flat_map(|d| {
                d.inputs()
                    .flat_map(|ds| ds.blocks().map(|b| (b, ds.block_len)).collect::<Vec<_>>())
            })
            .collect();
        let pinned_set: Option<std::collections::HashSet<BlockId>> = workload
            .pinned_cache
            .as_ref()
            .map(|v| v.iter().copied().collect());
        let t0 = Instant::now();
        let mut pending_ingests = 0usize;
        for &b in &workload.ingest_order {
            let w = home_worker(b, cfg.num_workers);
            let (cache, pin) = match &pinned_set {
                Some(set) => (set.contains(&b), set.contains(&b)),
                None => (true, false),
            };
            worker_txs[w.0 as usize]
                .send(WorkerMsg::Ingest {
                    block: b,
                    len: block_len_of[&b],
                    cache,
                    pin,
                })
                .map_err(|_| EngineError::ChannelClosed("worker ingest"))?;
            pending_ingests += 1;
        }

        let mut tracker = TaskTracker::new(all_tasks.clone(), vec![]);
        let mut in_flight = 0usize;
        let mut dispatched: usize = 0;
        let mut job_done_at: BTreeMap<u32, Duration> = BTreeMap::new();

        let dispatch_ready =
            |tracker: &mut TaskTracker, in_flight: &mut usize, dispatched: &mut usize| {
                while let Some(tid) = tracker.pop_ready() {
                    let task = &task_index[&tid];
                    let w = home_worker(task.output, cfg.num_workers);
                    let _ =
                        worker_txs[w.0 as usize].send(WorkerMsg::RunTask(Arc::new(task.clone())));
                    *in_flight += 1;
                    *dispatched += 1;
                }
            };

        // Unified event loop. Non-overlapped (paper) mode gates dispatch
        // behind the ingest barrier; overlapped mode (ablation knob)
        // dispatches tasks as their inputs materialize mid-ingest.
        let mut compute_started: Option<Instant> = None;
        while pending_ingests > 0 || !tracker.all_done() {
            match driver_rx
                .recv()
                .map_err(|_| EngineError::ChannelClosed("driver rx"))?
            {
                DriverMsg::IngestDone { block } => {
                    if pending_ingests == 0 {
                        return Err(EngineError::Invariant("ingest after ingest phase".into()));
                    }
                    pending_ingests -= 1;
                    tracker.on_block_materialized(block);
                    let barrier_open = cfg.overlap_ingest || pending_ingests == 0;
                    if barrier_open {
                        if compute_started.is_none() {
                            compute_started = Some(Instant::now());
                        }
                        dispatch_ready(&mut tracker, &mut in_flight, &mut dispatched);
                    }
                }
                DriverMsg::TaskDone { task, .. } => {
                    if !cfg.overlap_ingest && pending_ingests > 0 {
                        return Err(EngineError::Invariant(
                            "task completed during non-overlapped ingest".into(),
                        ));
                    }
                    in_flight -= 1;
                    let t = &task_index[&task];
                    // Reference counts decrement (LRC/LERC bookkeeping).
                    if cfg.policy.dag_aware() {
                        let changed = refcounts.on_task_complete(t);
                        let arc = Arc::new(changed);
                        send_all(WorkerMsg::RefCounts(arc), &ctrl_txs);
                        msgs.refcount_updates += cfg.num_workers as u64;
                    }
                    if cfg.policy.peer_aware() {
                        master.retire_task(task);
                        send_all(WorkerMsg::RetireTask(task), &ctrl_txs);
                    }
                    let (_ready, job_finished) = tracker.on_task_complete(task)?;
                    if job_finished {
                        let base = compute_started.unwrap_or(t0);
                        job_done_at.insert(t.job.0, base.elapsed().div_f64(cfg.time_scale));
                    }
                    dispatch_ready(&mut tracker, &mut in_flight, &mut dispatched);
                }
                DriverMsg::EvictionReport { block } => {
                    msgs.eviction_reports += 1;
                    if let Some(b) = master.on_eviction_report(block) {
                        msgs.invalidation_broadcasts += 1;
                        msgs.broadcast_deliveries += cfg.num_workers as u64;
                        send_all(WorkerMsg::EvictionBroadcast(b), &ctrl_txs);
                    }
                }
                DriverMsg::Fatal(e) => return Err(EngineError::Invariant(e)),
            }
        }
        debug_assert_eq!(in_flight, 0);
        let compute_started_at = compute_started.unwrap_or(t0);

        // --- teardown + report ---------------------------------------------
        send_all(WorkerMsg::Shutdown, &worker_txs);
        for j in joins {
            let _ = j.join();
        }
        let wall = t0.elapsed();
        let makespan = wall.div_f64(cfg.time_scale);
        let compute_makespan = compute_started_at.elapsed().div_f64(cfg.time_scale);

        let mut access = crate::metrics::AccessStats::default();
        let mut evictions = 0u64;
        let mut rejected = 0u64;
        for node in shared.iter() {
            let st = node.state.lock().unwrap();
            access.merge(&st.access);
            let cache_stats = node.store.stats();
            evictions += cache_stats.evictions;
            rejected += cache_stats.rejected;
        }
        msgs.profile_broadcasts = master.stats.profile_broadcasts;

        Ok(RunReport {
            policy: cfg.policy.name().to_string(),
            makespan,
            compute_makespan,
            job_times: job_done_at,
            access,
            messages: msgs,
            tasks_run: dispatched as u64,
            evictions,
            rejected_inserts: rejected,
            cache_capacity: cfg.total_cache(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{DiskConfig, PolicyKind};
    use crate::workload;

    fn fast_cfg(policy: PolicyKind, cache_blocks: u64) -> EngineConfig {
        EngineConfig {
            num_workers: 2,
            cache_capacity_per_worker: cache_blocks * 4096 * 4,
            block_len: 4096,
            policy,
            disk: DiskConfig {
                unthrottled: true,
                ..Default::default()
            },
            net: crate::common::config::NetConfig {
                per_message_latency: Duration::ZERO,
            },
            ..Default::default()
        }
    }

    #[test]
    fn zip_single_runs_to_completion() {
        let cfg = fast_cfg(PolicyKind::Lru, 100);
        let w = workload::zip_single(8, 4096);
        let report = ClusterEngine::new(cfg).run(&w).unwrap();
        assert_eq!(report.tasks_run, 8);
        assert_eq!(report.access.accesses, 16);
        // Plenty of cache: everything hits, all effective.
        assert_eq!(report.access.mem_hits, 16);
        assert_eq!(report.access.effective_hits, 16);
        assert_eq!(report.hit_ratio(), 1.0);
    }

    #[test]
    fn two_stage_cascades() {
        let cfg = fast_cfg(PolicyKind::Lerc, 100);
        let w = workload::two_stage_zip_agg(6, 4096);
        let report = ClusterEngine::new(cfg).run(&w).unwrap();
        assert_eq!(report.tasks_run, 12);
        assert!(report.job_times.contains_key(&0));
    }

    #[test]
    fn all_policies_complete_under_pressure() {
        for policy in PolicyKind::ALL {
            let cfg = fast_cfg(policy, 3); // tiny cache
            let w = workload::multi_tenant_zip(3, 4, 4096);
            let report = ClusterEngine::new(cfg).run(&w).unwrap();
            assert_eq!(report.tasks_run, 12, "{}", policy.name());
            assert!(report.access.disk_reads > 0, "{}", policy.name());
        }
    }

    #[test]
    fn lerc_beats_lru_on_effective_ratio_under_pressure() {
        // Cache sized ~2/3 of inputs: the paper's headline geometry.
        let w = workload::multi_tenant_zip(4, 6, 4096);
        let run = |policy| {
            let cfg = fast_cfg(policy, 8); // 2 workers * 8 = 16 of 48 blocks... scaled below
            ClusterEngine::new(cfg).run(&w).unwrap()
        };
        let lru = run(PolicyKind::Lru);
        let lerc = run(PolicyKind::Lerc);
        assert!(
            lerc.effective_hit_ratio() >= lru.effective_hit_ratio(),
            "LERC {} < LRU {}",
            lerc.effective_hit_ratio(),
            lru.effective_hit_ratio()
        );
    }

    #[test]
    fn peer_messages_only_for_peer_aware_policies() {
        let w = workload::multi_tenant_zip(3, 4, 4096);
        let lru = ClusterEngine::new(fast_cfg(PolicyKind::Lru, 2)).run(&w).unwrap();
        assert_eq!(lru.messages.peer_protocol_total(), 0);
        let lerc = ClusterEngine::new(fast_cfg(PolicyKind::Lerc, 2)).run(&w).unwrap();
        assert!(lerc.messages.peer_protocol_total() > 0);
    }

    #[test]
    fn multi_shard_store_completes_workloads() {
        // The sharded data path (several stripes per worker) still runs
        // every policy to completion with conserved accounting.
        for policy in [PolicyKind::Lru, PolicyKind::Lerc] {
            let mut cfg = fast_cfg(policy, 6);
            cfg.cache_shards = 4;
            let w = workload::multi_tenant_zip(3, 4, 4096);
            let report = ClusterEngine::new(cfg).run(&w).unwrap();
            assert_eq!(report.tasks_run, 12, "{}", policy.name());
            let a = &report.access;
            assert_eq!(a.accesses, a.mem_hits + a.disk_reads, "{}", policy.name());
        }
    }
}
