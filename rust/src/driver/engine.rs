//! ClusterEngine: assemble the cluster, run a workload, produce a report.

use crate::common::config::{ComputeMode, CtrlPlane, EngineConfig};
use crate::common::error::{EngineError, Result};
use crate::common::fxhash::{FxHashMap, FxHashSet};
use crate::common::ids::{BlockId, JobId, TaskId};
use crate::common::tempdir::TempDir;
use crate::dag::analysis::{peer_groups, PeerGroup, RefCounts};
use crate::dag::task::{enumerate_tasks, Task};
use crate::driver::ctrl::DeltaCoalescer;
use crate::driver::messages::{DriverMsg, WorkerMsg};
use crate::driver::queue::EventQueue;
use crate::driver::worker::{worker_loop, SharedWorkers, WorkerContext, WorkerNode};
use crate::metrics::{MessageStats, RunReport};
use crate::peer::PeerTrackerMaster;
use crate::runtime::pjrt::{ComputeHandle, PjrtEngine};
use crate::runtime::SyntheticEngine;
use crate::scheduler::{home_worker, homes_of, TaskTracker};
use crate::storage::DiskStore;
use crate::workload::Workload;
use std::collections::BTreeMap;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The threaded cluster engine. Construct with a config, `run` workloads.
pub struct ClusterEngine {
    cfg: EngineConfig,
}

/// Closes every worker queue when dropped, so worker threads parked on
/// their condvar wake and exit even when `run` returns early with an
/// error (the mpsc-based engine got this for free from channel
/// disconnection).
struct CloseQueuesOnDrop(Vec<Arc<EventQueue>>);

impl Drop for CloseQueuesOnDrop {
    fn drop(&mut self) {
        for q in &self.0 {
            q.close();
        }
    }
}

impl ClusterEngine {
    pub fn new(cfg: EngineConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Run a workload to completion and report.
    pub fn run(&self, workload: &Workload) -> Result<RunReport> {
        workload.validate()?;
        let cfg = &self.cfg;

        // --- storage -------------------------------------------------
        let _tmp; // keeps the tempdir alive for the run
        let disk_dir = match &cfg.disk_dir {
            Some(d) => d.clone(),
            None => {
                let t = TempDir::new("engine")?;
                let p = t.path().to_path_buf();
                _tmp = t;
                p
            }
        };
        let disk = Arc::new(DiskStore::new(&disk_dir, cfg.disk)?);

        // --- compute service ------------------------------------------
        let (compute, service) = match &cfg.compute {
            ComputeMode::Pjrt { artifacts_dir } => {
                let dir = artifacts_dir.clone();
                ComputeHandle::spawn(move || {
                    let e = PjrtEngine::load(dir)?;
                    e.warmup()?;
                    Ok(e)
                })?
            }
            ComputeMode::Synthetic => ComputeHandle::spawn(|| Ok(SyntheticEngine::new()))?,
        };
        let _service = service.with_handle(compute.clone());

        // --- static analysis -------------------------------------------
        let mut next_task_id = 0u64;
        let mut all_tasks: Vec<Task> = Vec::new();
        let mut groups_per_job: Vec<(JobId, Vec<PeerGroup>)> = Vec::new();
        for dag in &workload.dags {
            let tasks = enumerate_tasks(dag, &mut next_task_id);
            groups_per_job.push((dag.job, peer_groups(&tasks)));
            all_tasks.extend(tasks);
        }
        let mut refcounts = RefCounts::from_tasks(&all_tasks);
        // Arc'd task index: dispatch hands workers a refcount bump, not a
        // fresh deep clone of the task per dispatch.
        let task_index: FxHashMap<TaskId, Arc<Task>> =
            all_tasks.iter().map(|t| (t.id, Arc::new(t.clone()))).collect();
        let mut master = PeerTrackerMaster::default();
        let mut msgs = MessageStats::default();
        let routed = cfg.ctrl_plane == CtrlPlane::HomeRouted;

        // --- workers ----------------------------------------------------
        let shared: SharedWorkers =
            Arc::new((0..cfg.num_workers).map(|_| WorkerNode::new(cfg)).collect());
        let (driver_tx, driver_rx) = channel::<DriverMsg>();
        let net_nanos = Arc::new(AtomicU64::new(0));
        let queues: Vec<Arc<EventQueue>> =
            (0..cfg.num_workers).map(|_| Arc::new(EventQueue::new())).collect();
        let _close_on_drop = CloseQueuesOnDrop(queues.clone());
        let mut joins = Vec::new();
        for w in 0..cfg.num_workers {
            let ctx = WorkerContext {
                id: crate::common::ids::WorkerId(w),
                cfg: cfg.clone(),
                shared: shared.clone(),
                disk: disk.clone(),
                compute: compute.clone(),
                driver_tx: driver_tx.clone(),
                net_nanos: net_nanos.clone(),
            };
            let queue = queues[w as usize].clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("lerc-worker-{w}"))
                    .spawn(move || worker_loop(ctx, queue))?,
            );
        }
        let ctrl_all = |msg: WorkerMsg| {
            for q in &queues {
                q.send_ctrl(msg.clone());
            }
        };

        // --- peer profile + initial ref counts ---------------------------
        // Home-routed mode installs each group only at the home workers of
        // its members: those are the only replicas whose stores can hold a
        // member, and for any home block every group containing it lands
        // at that worker (the block is itself a member), so eviction
        // reporting and effective counts stay exact.
        if cfg.policy.peer_aware() {
            for (_job, groups) in &groups_per_job {
                if routed {
                    master.register_routed(groups, cfg.num_workers);
                    // One bucketing pass: each group lands at the home
                    // workers of its members.
                    let mut per_worker: Vec<Vec<PeerGroup>> =
                        vec![Vec::new(); cfg.num_workers as usize];
                    for g in groups {
                        for w in homes_of(&g.members, cfg.num_workers) {
                            per_worker[w.0 as usize].push(g.clone());
                        }
                    }
                    for (w, subset) in per_worker.into_iter().enumerate() {
                        if !subset.is_empty() {
                            queues[w].send_ctrl(WorkerMsg::RegisterPeers(Arc::new(subset)));
                        }
                    }
                } else {
                    master.register(groups);
                    ctrl_all(WorkerMsg::RegisterPeers(Arc::new(groups.clone())));
                }
            }
        }
        let mut coalescer = DeltaCoalescer::new(cfg.num_workers);
        if cfg.policy.dag_aware() {
            if routed {
                let initial: Vec<(BlockId, u32)> =
                    refcounts.iter().map(|(b, c)| (*b, *c)).collect();
                coalescer.stage(&initial);
                msgs.refcount_updates +=
                    coalescer.flush(|w, batch| queues[w].send_ctrl(WorkerMsg::RefCounts(batch)));
            } else {
                let initial: Arc<Vec<(BlockId, u32)>> =
                    Arc::new(refcounts.iter().map(|(b, c)| (*b, *c)).collect());
                ctrl_all(WorkerMsg::RefCounts(initial));
                msgs.refcount_updates += cfg.num_workers as u64;
            }
        }

        // --- ingest phase -------------------------------------------------
        let mut block_len_of: FxHashMap<BlockId, usize> = FxHashMap::default();
        for d in &workload.dags {
            for ds in d.inputs() {
                for b in ds.blocks() {
                    block_len_of.insert(b, ds.block_len);
                }
            }
        }
        let pinned_set: Option<FxHashSet<BlockId>> =
            workload.pinned_cache.as_ref().map(|v| v.iter().copied().collect());
        let t0 = Instant::now();
        let mut pending_ingests = 0usize;
        for &b in &workload.ingest_order {
            let w = home_worker(b, cfg.num_workers);
            let (cache, pin) = match &pinned_set {
                Some(set) => (set.contains(&b), set.contains(&b)),
                None => (true, false),
            };
            queues[w.0 as usize].send_data(WorkerMsg::Ingest {
                block: b,
                len: block_len_of[&b],
                cache,
                pin,
            });
            pending_ingests += 1;
        }

        let mut tracker = TaskTracker::new(all_tasks.clone(), vec![]);
        let mut in_flight = 0usize;
        let mut dispatched: usize = 0;
        let mut job_done_at: BTreeMap<u32, Duration> = BTreeMap::new();

        let dispatch_ready =
            |tracker: &mut TaskTracker, in_flight: &mut usize, dispatched: &mut usize| {
                while let Some(tid) = tracker.pop_ready() {
                    let task = &task_index[&tid];
                    let w = home_worker(task.output, cfg.num_workers);
                    queues[w.0 as usize].send_data(WorkerMsg::RunTask(task.clone()));
                    *in_flight += 1;
                    *dispatched += 1;
                }
            };

        // Unified event loop. Non-overlapped (paper) mode gates dispatch
        // behind the ingest barrier; overlapped mode (ablation knob)
        // dispatches tasks as their inputs materialize mid-ingest.
        //
        // Batching: after the blocking recv, the loop drains everything
        // already queued and processes it as one cycle. In home-routed
        // mode the cycle's ref-count deltas coalesce per destination
        // worker (one RefCounts message per affected worker, last write
        // wins per block — counts are absolute) and flush before any new
        // task is dispatched, so a dispatched task's worker always has
        // every count the driver knew at dispatch (control messages
        // dequeue first). Broadcast mode keeps the paper's one send per
        // event per worker so §IV message accounting is unchanged.
        let mut compute_started: Option<Instant> = None;
        let mut cycle: Vec<DriverMsg> = Vec::new();
        while pending_ingests > 0 || !tracker.all_done() {
            cycle.clear();
            let first = driver_rx.recv().map_err(|_| EngineError::ChannelClosed("driver rx"))?;
            cycle.push(first);
            while let Ok(more) = driver_rx.try_recv() {
                cycle.push(more);
            }
            let mut dispatch_after = false;
            for msg in cycle.drain(..) {
                match msg {
                    DriverMsg::IngestDone { block } => {
                        if pending_ingests == 0 {
                            return Err(EngineError::Invariant("ingest after ingest phase".into()));
                        }
                        pending_ingests -= 1;
                        tracker.on_block_materialized(block);
                        if cfg.overlap_ingest || pending_ingests == 0 {
                            if compute_started.is_none() {
                                compute_started = Some(Instant::now());
                            }
                            dispatch_after = true;
                        }
                    }
                    DriverMsg::TaskDone { task, .. } => {
                        if !cfg.overlap_ingest && pending_ingests > 0 {
                            return Err(EngineError::Invariant(
                                "task completed during non-overlapped ingest".into(),
                            ));
                        }
                        in_flight -= 1;
                        let t = task_index[&task].clone();
                        // Reference counts decrement (LRC/LERC bookkeeping).
                        if cfg.policy.dag_aware() {
                            let changed = refcounts.on_task_complete(&t);
                            if routed {
                                coalescer.stage(&changed);
                            } else {
                                ctrl_all(WorkerMsg::RefCounts(Arc::new(changed)));
                                msgs.refcount_updates += cfg.num_workers as u64;
                            }
                        }
                        if cfg.policy.peer_aware() {
                            master.retire_task(task);
                            if routed {
                                // The group's replicas live at its members'
                                // home workers only.
                                for w in homes_of(&t.inputs, cfg.num_workers) {
                                    queues[w.0 as usize].send_ctrl(WorkerMsg::RetireTask(task));
                                }
                            } else {
                                ctrl_all(WorkerMsg::RetireTask(task));
                            }
                        }
                        let (_ready, job_finished) = tracker.on_task_complete(task)?;
                        if job_finished {
                            let base = compute_started.unwrap_or(t0);
                            job_done_at.insert(t.job.0, base.elapsed().div_f64(cfg.time_scale));
                        }
                        dispatch_after = true;
                    }
                    DriverMsg::EvictionReport { block } => {
                        msgs.eviction_reports += 1;
                        if let Some(b) = master.on_eviction_report(block) {
                            msgs.invalidation_broadcasts += 1;
                            if routed {
                                // Deliver only to workers whose registered
                                // peer groups contain the block.
                                let interested = master.interested_workers(b);
                                msgs.broadcast_deliveries += interested.len() as u64;
                                for w in interested {
                                    queues[w.0 as usize]
                                        .send_ctrl(WorkerMsg::EvictionBroadcast(b));
                                }
                            } else {
                                msgs.broadcast_deliveries += cfg.num_workers as u64;
                                ctrl_all(WorkerMsg::EvictionBroadcast(b));
                            }
                        }
                    }
                    DriverMsg::Fatal(e) => return Err(EngineError::Invariant(e)),
                }
            }
            // Flush coalesced deltas BEFORE dispatching: the worker queue
            // dequeues control before data, so every task dispatched below
            // runs against these counts, never stale ones.
            msgs.refcount_updates +=
                coalescer.flush(|w, batch| queues[w].send_ctrl(WorkerMsg::RefCounts(batch)));
            if dispatch_after {
                dispatch_ready(&mut tracker, &mut in_flight, &mut dispatched);
            }
        }
        debug_assert_eq!(in_flight, 0);
        debug_assert!(coalescer.is_empty());
        let compute_started_at = compute_started.unwrap_or(t0);

        // --- teardown + report ---------------------------------------------
        // Queue closing is owned by `_close_on_drop`; Shutdown alone ends
        // each worker loop once its data lane drains.
        for q in &queues {
            q.send_data(WorkerMsg::Shutdown);
        }
        for j in joins {
            let _ = j.join();
        }
        let wall = t0.elapsed();
        let makespan = wall.div_f64(cfg.time_scale);
        let compute_makespan = compute_started_at.elapsed().div_f64(cfg.time_scale);

        let mut access = crate::metrics::AccessStats::default();
        let mut evictions = 0u64;
        let mut rejected = 0u64;
        for node in shared.iter() {
            let st = node.state.lock().unwrap();
            access.merge(&st.access);
            let cache_stats = node.store.stats();
            evictions += cache_stats.evictions;
            rejected += cache_stats.rejected;
        }
        msgs.profile_broadcasts = master.stats.profile_broadcasts;

        Ok(RunReport {
            policy: cfg.policy.name().to_string(),
            makespan,
            compute_makespan,
            job_times: job_done_at,
            access,
            messages: msgs,
            tasks_run: dispatched as u64,
            evictions,
            rejected_inserts: rejected,
            cache_capacity: cfg.total_cache(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::config::{DiskConfig, PolicyKind};
    use crate::workload;

    fn fast_cfg(policy: PolicyKind, cache_blocks: u64) -> EngineConfig {
        EngineConfig {
            num_workers: 2,
            cache_capacity_per_worker: cache_blocks * 4096 * 4,
            block_len: 4096,
            policy,
            disk: DiskConfig {
                unthrottled: true,
                ..Default::default()
            },
            net: crate::common::config::NetConfig {
                per_message_latency: Duration::ZERO,
            },
            ..Default::default()
        }
    }

    #[test]
    fn zip_single_runs_to_completion() {
        let cfg = fast_cfg(PolicyKind::Lru, 100);
        let w = workload::zip_single(8, 4096);
        let report = ClusterEngine::new(cfg).run(&w).unwrap();
        assert_eq!(report.tasks_run, 8);
        assert_eq!(report.access.accesses, 16);
        // Plenty of cache: everything hits, all effective.
        assert_eq!(report.access.mem_hits, 16);
        assert_eq!(report.access.effective_hits, 16);
        assert_eq!(report.hit_ratio(), 1.0);
    }

    #[test]
    fn two_stage_cascades() {
        let cfg = fast_cfg(PolicyKind::Lerc, 100);
        let w = workload::two_stage_zip_agg(6, 4096);
        let report = ClusterEngine::new(cfg).run(&w).unwrap();
        assert_eq!(report.tasks_run, 12);
        assert!(report.job_times.contains_key(&0));
    }

    #[test]
    fn all_policies_complete_under_pressure() {
        for policy in PolicyKind::ALL {
            let cfg = fast_cfg(policy, 3); // tiny cache
            let w = workload::multi_tenant_zip(3, 4, 4096);
            let report = ClusterEngine::new(cfg).run(&w).unwrap();
            assert_eq!(report.tasks_run, 12, "{}", policy.name());
            assert!(report.access.disk_reads > 0, "{}", policy.name());
        }
    }

    #[test]
    fn lerc_beats_lru_on_effective_ratio_under_pressure() {
        // Cache sized ~2/3 of inputs: the paper's headline geometry.
        let w = workload::multi_tenant_zip(4, 6, 4096);
        let run = |policy| {
            let cfg = fast_cfg(policy, 8); // 2 workers * 8 = 16 of 48 blocks... scaled below
            ClusterEngine::new(cfg).run(&w).unwrap()
        };
        let lru = run(PolicyKind::Lru);
        let lerc = run(PolicyKind::Lerc);
        assert!(
            lerc.effective_hit_ratio() >= lru.effective_hit_ratio(),
            "LERC {} < LRU {}",
            lerc.effective_hit_ratio(),
            lru.effective_hit_ratio()
        );
    }

    #[test]
    fn peer_messages_only_for_peer_aware_policies() {
        let w = workload::multi_tenant_zip(3, 4, 4096);
        let lru = ClusterEngine::new(fast_cfg(PolicyKind::Lru, 2)).run(&w).unwrap();
        assert_eq!(lru.messages.peer_protocol_total(), 0);
        let lerc = ClusterEngine::new(fast_cfg(PolicyKind::Lerc, 2)).run(&w).unwrap();
        assert!(lerc.messages.peer_protocol_total() > 0);
    }

    #[test]
    fn multi_shard_store_completes_workloads() {
        // The sharded data path (several stripes per worker) still runs
        // every policy to completion with conserved accounting.
        for policy in [PolicyKind::Lru, PolicyKind::Lerc] {
            let mut cfg = fast_cfg(policy, 6);
            cfg.cache_shards = 4;
            let w = workload::multi_tenant_zip(3, 4, 4096);
            let report = ClusterEngine::new(cfg).run(&w).unwrap();
            assert_eq!(report.tasks_run, 12, "{}", policy.name());
            let a = &report.access;
            assert_eq!(a.accesses, a.mem_hits + a.disk_reads, "{}", policy.name());
        }
    }
}
