//! Home-routed control-plane helpers: delta coalescing and routing.
//!
//! In [`CtrlPlane::HomeRouted`](crate::common::config::CtrlPlane) mode a
//! block's policy metadata (ref count, effective count) matters only at
//! its home worker — the one store that can ever cache it, since ingests
//! and task outputs are always placed by
//! [`home_worker`](crate::scheduler::home_worker) (failure-aware via
//! [`AliveSet`] once workers die) and disk reads are never re-promoted.
//! The driver therefore routes each update to the home store instead of
//! broadcasting, and coalesces the ref-count deltas of a whole
//! `driver_rx` drain cycle into at most one message per destination
//! worker.
//!
//! Coalescing is safe because ref counts are *absolute* values, not
//! increments: staging is last-write-wins per block, so the flushed batch
//! always carries the newest count the driver knows. The engine flushes
//! before dispatching new tasks, and the worker queue gives control
//! messages strict priority, so a task never runs against counts staler
//! than the driver's state at its dispatch.

use crate::common::fxhash::FxHashMap;
use crate::common::ids::BlockId;
use crate::scheduler::AliveSet;
use std::sync::Arc;

/// Per-destination staging buffers for ref-count deltas.
#[derive(Debug)]
pub struct DeltaCoalescer {
    /// Failure-aware routing view; with every worker up this is exactly
    /// the pure `home_worker` mapping.
    alive: AliveSet,
    /// Per-worker `block → newest count` (absolute, last write wins).
    staged: Vec<FxHashMap<BlockId, u32>>,
}

impl DeltaCoalescer {
    pub fn new(num_workers: u32) -> Self {
        Self {
            alive: AliveSet::new(num_workers),
            staged: (0..num_workers).map(|_| FxHashMap::default()).collect(),
        }
    }

    /// Adopt the driver's current worker liveness so future staging
    /// routes to the failure-aware homes. Must be called with the staging
    /// buffers flushed (the engines repair at quiescent points).
    pub fn set_alive(&mut self, alive: &AliveSet) {
        debug_assert!(self.is_empty(), "re-routing with staged deltas would strand them");
        self.alive = alive.clone();
    }

    /// Stage `(block, new_count)` deltas, each routed to its block's home
    /// worker. A later delta for the same block overwrites the staged one.
    pub fn stage(&mut self, changed: &[(BlockId, u32)]) {
        for &(b, count) in changed {
            let w = self.alive.home_of(b).0 as usize;
            self.staged[w].insert(b, count);
        }
    }

    /// Drain the staged deltas: invoke `send(worker, batch)` once per
    /// worker with a non-empty buffer. Returns the number of messages
    /// emitted. Batches are `Arc`'d so callers can hand them to channel
    /// senders without re-cloning the payload.
    pub fn flush(&mut self, mut send: impl FnMut(usize, Arc<Vec<(BlockId, u32)>>)) -> u64 {
        let mut sent = 0u64;
        for (w, buf) in self.staged.iter_mut().enumerate() {
            if buf.is_empty() {
                continue;
            }
            let batch: Vec<(BlockId, u32)> = buf.drain().collect();
            send(w, Arc::new(batch));
            sent += 1;
        }
        sent
    }

    /// Deltas currently staged across all workers (tests/diagnostics).
    pub fn staged_len(&self) -> usize {
        self.staged.iter().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.staged.iter().all(|m| m.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    #[test]
    fn stage_routes_by_home() {
        let mut c = DeltaCoalescer::new(4);
        c.stage(&[(b(0), 3), (b(1), 2), (b(4), 1)]); // homes 0, 1, 0
        assert_eq!(c.staged_len(), 3);
        let mut got: Vec<(usize, Vec<(BlockId, u32)>)> = Vec::new();
        let sent = c.flush(|w, batch| got.push((w, batch.as_ref().clone())));
        assert_eq!(sent, 2);
        assert!(c.is_empty());
        got.sort_by_key(|(w, _)| *w);
        assert_eq!(got[0].0, 0);
        assert_eq!(got[0].1.len(), 2);
        assert_eq!(got[1].0, 1);
        assert_eq!(got[1].1, vec![(b(1), 2)]);
    }

    #[test]
    fn last_write_wins_per_block() {
        let mut c = DeltaCoalescer::new(2);
        c.stage(&[(b(0), 5)]);
        c.stage(&[(b(0), 4)]);
        c.stage(&[(b(0), 3)]);
        assert_eq!(c.staged_len(), 1);
        let mut batches = Vec::new();
        c.flush(|_, batch| batches.push(batch));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].as_ref(), &vec![(b(0), 3)]);
    }

    #[test]
    fn flush_on_empty_sends_nothing() {
        let mut c = DeltaCoalescer::new(3);
        assert_eq!(c.flush(|_, _| panic!("no sends expected")), 0);
    }

    #[test]
    fn staging_follows_the_alive_set() {
        use crate::common::ids::WorkerId;
        let mut c = DeltaCoalescer::new(4);
        let mut alive = AliveSet::new(4);
        alive.kill(WorkerId(1));
        c.set_alive(&alive);
        // b(1) homes at dead worker 1 -> probes to worker 2.
        c.stage(&[(b(1), 5)]);
        let mut got = Vec::new();
        c.flush(|w, batch| got.push((w, batch.as_ref().clone())));
        assert_eq!(got, vec![(2usize, vec![(b(1), 5)])]);
    }
}
