//! One runner per paper table/figure (DESIGN.md §4 experiment index).
//!
//! Each runner returns structured rows and can print the same series the
//! paper reports. Runners default to the discrete-event simulator (exact,
//! fast); the CLI and examples can run the same configs on the threaded
//! engine for validation.

use crate::block::manager::BlockManager;
use crate::cache::policy::PolicyEvent;
use crate::common::config::{CtrlPlane, EngineConfig, PolicyKind};
use crate::common::error::Result;
use crate::common::ids::{BlockId, DatasetId, GroupId, TaskId};
use crate::dag::analysis::PeerGroup;
use crate::engine::Engine;
use crate::metrics::report::SweepRow;
use crate::metrics::RunReport;
use crate::peer::WorkerPeerTracker;
use crate::sim::Simulator;
use crate::workload::{self, Workload};
use std::sync::Arc;
use std::time::Duration;

/// Shared experiment scale knobs (defaults reproduce the paper's geometry
/// scaled to this testbed: 10 tenants × 2 files × 50 blocks of 256 KiB).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub workers: u32,
    pub tenants: u32,
    pub blocks_per_file: u32,
    pub block_len: usize,
    /// Cache sizes as fractions of total input bytes (the paper's x-axis).
    pub fractions: Vec<f64>,
    pub policies: Vec<PolicyKind>,
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            workers: 8,
            tenants: 10,
            blocks_per_file: 50,
            block_len: 65536,
            fractions: vec![0.33, 0.42, 0.50, 0.58, 0.66, 0.75],
            policies: PolicyKind::PAPER.to_vec(),
            seed: 17,
        }
    }
}

impl ExpOptions {
    /// Engine config for a given cache fraction of `input_bytes`. Paper
    /// figures run the broadcast control plane: the §IV overhead numbers
    /// (`MessageStats`) are defined against per-event fan-out, and the
    /// simulator models exactly that.
    pub fn engine_config(
        &self,
        policy: PolicyKind,
        input_bytes: u64,
        fraction: f64,
    ) -> EngineConfig {
        let per_worker = ((input_bytes as f64 * fraction) / self.workers as f64) as u64;
        EngineConfig::builder()
            .num_workers(self.workers)
            .cache_capacity_per_worker(per_worker)
            .block_len(self.block_len)
            .policy(policy)
            .seed(self.seed)
            .ctrl_plane(CtrlPlane::Broadcast)
            .build()
            .expect("valid experiment config")
    }
}

// ====================================================================
// Fig 1 toy example
// ====================================================================

/// Outcome of the Fig 1 eviction decision for one policy.
#[derive(Debug, Clone)]
pub struct ToyRow {
    pub policy: String,
    /// Which block the policy evicted when `e` arrived (a/b/c/d name).
    pub evicted: String,
    /// Effective cache hit ratio over the 4 block accesses of tasks 1+2.
    pub effective_hit_ratio: f64,
    /// Plain cache hit ratio over the same accesses.
    pub hit_ratio: f64,
}

/// Reproduce Fig 1 exactly: cache holds {a, b, c} (3 entries), block d is
/// materialized but on disk, block e arrives. Which block goes?
///
/// Drives BlockManager + WorkerPeerTracker directly — the initial state
/// is *given* in the paper, not derived.
pub fn toy_fig1_table(policies: &[PolicyKind]) -> Vec<ToyRow> {
    let names = ["a", "b", "c", "d", "e"];
    let block = |i: u32| BlockId::new(DatasetId(0), i);
    let rows = policies
        .iter()
        .map(|&kind| {
            let block_bytes = 4u64 * 1024;
            let mut bm = BlockManager::new(3 * block_bytes, kind);
            let mut tracker = WorkerPeerTracker::default();
            // Task 1 coalesces (a, b) -> x ; Task 2 coalesces (c, d) -> y.
            let groups = vec![
                PeerGroup {
                    id: GroupId(0),
                    task: TaskId(0),
                    members: vec![block(0), block(1)],
                    output: block(10),
                },
                PeerGroup {
                    id: GroupId(1),
                    task: TaskId(1),
                    members: vec![block(2), block(3)],
                    output: block(11),
                },
                // Block e is referenced by a third task.
                PeerGroup {
                    id: GroupId(2),
                    task: TaskId(2),
                    members: vec![block(4)],
                    output: block(12),
                },
            ];
            tracker.register(&groups, &[]);

            let payload: crate::cache::store::BlockData = Arc::from(vec![0.5f32; 1024]);
            // Initial state: a, b, c cached; every block has one reference.
            for i in 0..3 {
                bm.policy_event(PolicyEvent::RefCount {
                    block: block(i),
                    count: 1,
                });
                bm.policy_event(PolicyEvent::EffectiveCount {
                    block: block(i),
                    count: tracker.effective_count(block(i)),
                });
                bm.insert(block(i), payload.clone());
            }
            bm.policy_event(PolicyEvent::RefCount {
                block: block(3),
                count: 1,
            });
            bm.policy_event(PolicyEvent::RefCount {
                block: block(4),
                count: 1,
            });
            // Block d is materialized but NOT cached: the protocol treats
            // that as an eviction of d -> group 1 becomes incomplete.
            let (deltas, broken) = tracker.apply_eviction_broadcast(block(3));
            for (b, count) in deltas {
                bm.policy_event(PolicyEvent::EffectiveCount { block: b, count });
            }
            if !broken.is_empty() {
                bm.policy_event(PolicyEvent::GroupBroken { members: &broken });
            }
            bm.policy_event(PolicyEvent::EffectiveCount {
                block: block(4),
                count: tracker.effective_count(block(4)),
            });

            // Block e arrives.
            let outcome = bm.insert(block(4), payload.clone());
            let evicted = outcome
                .evicted
                .first()
                .map(|b| names[b.index as usize].to_string())
                .unwrap_or_else(|| "-".into());

            // Run tasks 1 and 2: 4 accesses (a, b, c, d).
            let mut hits = 0u32;
            let mut effective = 0u32;
            for pair in [[block(0), block(1)], [block(2), block(3)]] {
                let in_mem = [bm.contains(pair[0]), bm.contains(pair[1])];
                hits += in_mem.iter().filter(|&&h| h).count() as u32;
                if in_mem.iter().all(|&h| h) {
                    effective += 2;
                }
            }
            ToyRow {
                policy: kind.name().to_string(),
                evicted,
                effective_hit_ratio: effective as f64 / 4.0,
                hit_ratio: hits as f64 / 4.0,
            }
        })
        .collect();
    rows
}

pub fn print_toy_table(rows: &[ToyRow]) {
    crate::out!("| policy | evicts | cache hit ratio | effective cache hit ratio |");
    crate::out!("|---|---|---|---|");
    for r in rows {
        crate::out!(
            "| {} | {} | {:.1}% | {:.1}% |",
            r.policy,
            r.evicted,
            100.0 * r.hit_ratio,
            100.0 * r.effective_hit_ratio
        );
    }
}

// ====================================================================
// Fig 3: the all-or-nothing measurement
// ====================================================================

#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub cached_blocks: u32,
    pub hit_ratio: f64,
    /// Total compute-phase runtime (single worker => sum of task times).
    pub total_runtime: Duration,
}

/// Reproduce Fig 3: a zip job with 10-block RDDs A and B; cache exactly
/// the first `k` blocks in the order A1, B1, A2, B2, … and measure the
/// total task runtime and hit ratio at each k.
pub fn fig3_all_or_nothing(blocks: u32, block_len: usize) -> Result<Vec<Fig3Row>> {
    let base = workload::zip_single(blocks, block_len);
    // Pin order: A_i then B_i, pair by pair (the paper's caching order).
    let a = base.dags[0].datasets[0].id;
    let b = base.dags[0].datasets[1].id;
    let order: Vec<BlockId> = (0..blocks)
        .flat_map(|i| [BlockId::new(a, i), BlockId::new(b, i)])
        .collect();

    let mut rows = Vec::new();
    for k in 0..=order.len() {
        let mut w = base.clone();
        w.pinned_cache = Some(order[..k].to_vec());
        // One worker: makespan of the compute phase == total task runtime.
        let cfg = EngineConfig::builder()
            .num_workers(1)
            .cache_capacity_per_worker(u64::MAX / 4)
            .block_len(block_len)
            .policy(PolicyKind::Lru)
            .build()?;
        let report = Simulator::from_engine_config(cfg).run_workload(&w)?;
        let runtime = report
            .job_times
            .get(&0)
            .copied()
            .unwrap_or(report.makespan);
        rows.push(Fig3Row {
            cached_blocks: k as u32,
            hit_ratio: report.hit_ratio(),
            total_runtime: runtime,
        });
    }
    Ok(rows)
}

pub fn print_fig3(rows: &[Fig3Row]) {
    crate::out!("| cached blocks | cache hit ratio | total task runtime (s) |");
    crate::out!("|---|---|---|");
    for r in rows {
        crate::out!(
            "| {} | {:.2} | {:.3} |",
            r.cached_blocks,
            r.hit_ratio,
            r.total_runtime.as_secs_f64()
        );
    }
}

// ====================================================================
// Fig 5 / 6 / 7: the main evaluation sweep
// ====================================================================

/// Run the paper's §IV experiment across cache sizes × policies on the
/// simulator. One run yields all three figures (runtime, hit ratio,
/// effective hit ratio).
pub fn fig5_6_7_sweep(opts: &ExpOptions) -> Result<Vec<SweepRow>> {
    let w = workload::multi_tenant_zip(opts.tenants, opts.blocks_per_file, opts.block_len);
    let input_bytes = w.input_bytes();
    let mut rows = Vec::new();
    for &fraction in &opts.fractions {
        for &policy in &opts.policies {
            crate::vlog!("sweep: {} at cache fraction {:.2} (sim)", policy.name(), fraction);
            let cfg = opts.engine_config(policy, input_bytes, fraction);
            let report = Simulator::from_engine_config(cfg).run_workload(&w)?;
            rows.push(SweepRow::from_report(&report, input_bytes));
        }
    }
    Ok(rows)
}

/// Same sweep on the threaded engine (slower; validates the simulator).
pub fn fig5_6_7_sweep_real(
    opts: &ExpOptions,
    compute: crate::common::config::ComputeMode,
    time_scale: f64,
) -> Result<Vec<SweepRow>> {
    let w = workload::multi_tenant_zip(opts.tenants, opts.blocks_per_file, opts.block_len);
    let input_bytes = w.input_bytes();
    let mut rows = Vec::new();
    for &fraction in &opts.fractions {
        for &policy in &opts.policies {
            crate::vlog!("sweep: {} at cache fraction {:.2} (threaded)", policy.name(), fraction);
            let mut cfg = opts.engine_config(policy, input_bytes, fraction);
            cfg.compute = compute.clone();
            cfg.time_scale = time_scale;
            let report = crate::driver::ClusterEngine::new(cfg).run_workload(&w)?;
            rows.push(SweepRow::from_report(&report, input_bytes));
        }
    }
    Ok(rows)
}

// ====================================================================
// §III-C: communication overhead
// ====================================================================

#[derive(Debug, Clone)]
pub struct CommRow {
    pub cache_fraction: f64,
    pub peer_groups: u64,
    pub eviction_reports: u64,
    pub broadcasts: u64,
    pub broadcast_deliveries: u64,
}

/// Measure LERC's protocol traffic across cache pressures and check the
/// "at most one broadcast per peer-group" bound.
pub fn comm_overhead(opts: &ExpOptions) -> Result<Vec<CommRow>> {
    let w = workload::multi_tenant_zip(opts.tenants, opts.blocks_per_file, opts.block_len);
    let input_bytes = w.input_bytes();
    let groups = w.task_count() as u64;
    let mut rows = Vec::new();
    for &fraction in &opts.fractions {
        crate::vlog!("comm overhead: LERC at cache fraction {fraction:.2}");
        let cfg = opts.engine_config(PolicyKind::Lerc, input_bytes, fraction);
        let report = Simulator::from_engine_config(cfg).run_workload(&w)?;
        rows.push(CommRow {
            cache_fraction: fraction,
            peer_groups: groups,
            eviction_reports: report.messages.eviction_reports,
            broadcasts: report.messages.invalidation_broadcasts,
            broadcast_deliveries: report.messages.broadcast_deliveries,
        });
    }
    Ok(rows)
}

pub fn print_comm(rows: &[CommRow]) {
    crate::out!("| cache fraction | peer groups | eviction reports | broadcasts | deliveries |");
    crate::out!("|---|---|---|---|---|");
    for r in rows {
        crate::out!(
            "| {:.2} | {} | {} | {} | {} |",
            r.cache_fraction,
            r.peer_groups,
            r.eviction_reports,
            r.broadcasts,
            r.broadcast_deliveries
        );
    }
}

// ====================================================================
// §III-A: sticky-eviction ablation
// ====================================================================

/// Sticky vs LERC vs LRC on the shared-input workload where sticky's
/// whole-group surrender hurts.
pub fn ablation_sticky(
    consumers: u32,
    blocks: u32,
    block_len: usize,
    fraction: f64,
) -> Result<Vec<RunReport>> {
    let w = workload::shared_input(consumers, blocks, block_len);
    let input_bytes = w.input_bytes();
    let mut out = Vec::new();
    for policy in [PolicyKind::Lerc, PolicyKind::Sticky, PolicyKind::Lrc] {
        let cfg = EngineConfig::builder()
            .num_workers(4)
            .cache_capacity_per_worker(((input_bytes as f64 * fraction) / 4.0) as u64)
            .block_len(block_len)
            .policy(policy)
            .build()?;
        out.push(Simulator::from_engine_config(cfg).run_workload(&w)?);
    }
    Ok(out)
}

/// The §III-A single-decision scenario, verbatim: block `s` is shared by
/// three tasks; one of its peer-groups is already broken, two are still
/// complete. A new block arrives and someone must go. Sticky surrenders
/// `s` outright (it sticks to the broken group's fate) and no task is
/// sped up; LERC sees `s` still has two effective references and keeps
/// it. Returns (policy name, effective hits out of 6 task accesses).
pub fn sticky_single_decision() -> Vec<(String, u32)> {
    let block = |i: u32| BlockId::new(DatasetId(0), i);
    // s=0, p1=1 (never cached -> g1 broken), p2=2, p3=3, e=4.
    let groups = vec![
        PeerGroup {
            id: GroupId(0),
            task: TaskId(0),
            members: vec![block(0), block(1)],
            output: block(10),
        },
        PeerGroup {
            id: GroupId(1),
            task: TaskId(1),
            members: vec![block(0), block(2)],
            output: block(11),
        },
        PeerGroup {
            id: GroupId(2),
            task: TaskId(2),
            members: vec![block(0), block(3)],
            output: block(12),
        },
        PeerGroup {
            id: GroupId(3),
            task: TaskId(3),
            members: vec![block(4)],
            output: block(13),
        },
    ];
    [PolicyKind::Lerc, PolicyKind::Sticky]
        .into_iter()
        .map(|kind| {
            let mut bm = BlockManager::new(3 * 4 * 1024, kind);
            let mut tracker = WorkerPeerTracker::default();
            tracker.register(&groups, &[]);
            let payload: crate::cache::store::BlockData = Arc::from(vec![0.5f32; 1024]);
            let sync = |bm: &mut BlockManager, tracker: &WorkerPeerTracker, blocks: &[u32]| {
                for &i in blocks {
                    bm.policy_event(PolicyEvent::EffectiveCount {
                        block: block(i),
                        count: tracker.effective_count(block(i)),
                    });
                }
            };
            // Cache s, p2, p3 (cap 3); p1 is materialized-but-uncached.
            for i in [0u32, 2, 3] {
                bm.policy_event(PolicyEvent::RefCount {
                    block: block(i),
                    count: 1,
                });
                bm.insert(block(i), payload.clone());
            }
            bm.policy_event(PolicyEvent::RefCount {
                block: block(0),
                count: 3, // s is referenced by three tasks
            });
            let (deltas, broken) = tracker.apply_eviction_broadcast(block(1));
            for (bk, count) in deltas {
                bm.policy_event(PolicyEvent::EffectiveCount { block: bk, count });
            }
            if !broken.is_empty() {
                bm.policy_event(PolicyEvent::GroupBroken { members: &broken });
            }
            sync(&mut bm, &tracker, &[0, 2, 3, 4]);
            bm.policy_event(PolicyEvent::RefCount {
                block: block(4),
                count: 1,
            });
            // Block e arrives: the decision point.
            bm.insert(block(4), payload.clone());

            // Score the three binary tasks (6 accesses).
            let mut eff = 0u32;
            for pair in [[0u32, 1], [0, 2], [0, 3]] {
                if bm.contains(block(pair[0])) && bm.contains(block(pair[1])) {
                    eff += 2;
                }
            }
            (kind.name().to_string(), eff)
        })
        .collect()
}

/// Arrival-order ablation (extension): the paper's LRU pathology depends
/// on the parallel-tenant ingest order. Rerun the §IV experiment under
/// four arrival orders and report LRU vs LERC effective ratios.
pub fn ablation_arrival_order(
    opts: &ExpOptions,
    fraction: f64,
) -> Result<Vec<(String, RunReport, RunReport)>> {
    use crate::workload::generators::{multi_tenant_zip_ordered, ArrivalOrder};
    let orders = [
        ArrivalOrder::ParallelTenants,
        ArrivalOrder::SequentialJobs,
        ArrivalOrder::Interleaved,
        ArrivalOrder::Shuffled(opts.seed),
    ];
    let mut out = Vec::new();
    for order in orders {
        let w = multi_tenant_zip_ordered(opts.tenants, opts.blocks_per_file, opts.block_len, order);
        let input = w.input_bytes();
        let lru =
            Simulator::from_engine_config(opts.engine_config(PolicyKind::Lru, input, fraction))
                .run_workload(&w)?;
        let lerc =
            Simulator::from_engine_config(opts.engine_config(PolicyKind::Lerc, input, fraction))
                .run_workload(&w)?;
        out.push((format!("{order:?}"), lru, lerc));
    }
    Ok(out)
}

/// Extended sweep over every implemented policy (beyond the paper's 3).
pub fn extended_policy_sweep(opts: &ExpOptions) -> Result<Vec<SweepRow>> {
    let mut o = opts.clone();
    o.policies = PolicyKind::ALL.to_vec();
    fig5_6_7_sweep(&o)
}

/// Build the standard workload used by the sweep (exposed for the CLI).
pub fn paper_workload(opts: &ExpOptions) -> Workload {
    workload::multi_tenant_zip(opts.tenants, opts.blocks_per_file, opts.block_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toy_table_lerc_evicts_c() {
        let rows = toy_fig1_table(&[PolicyKind::Lru, PolicyKind::Lrc, PolicyKind::Lerc]);
        let lerc = rows.iter().find(|r| r.policy == "LERC").unwrap();
        assert_eq!(lerc.evicted, "c");
        assert!((lerc.effective_hit_ratio - 0.5).abs() < 1e-9);
        // LRU/LRC evict a (recency tiebreak) -> zero effective hits.
        let lru = rows.iter().find(|r| r.policy == "LRU").unwrap();
        assert_eq!(lru.evicted, "a");
        assert_eq!(lru.effective_hit_ratio, 0.0);
    }

    #[test]
    fn fig3_staircase() {
        let rows = fig3_all_or_nothing(4, 4096).unwrap();
        assert_eq!(rows.len(), 9);
        // Hit ratio grows monotonically with k.
        for w in rows.windows(2) {
            assert!(w[1].hit_ratio >= w[0].hit_ratio - 1e-9);
        }
        // Runtime drops only when a PAIR completes: after odd k (1 block
        // of a new pair cached) runtime equals the previous even k.
        for k in (1..rows.len()).step_by(2) {
            let stay = rows[k].total_runtime;
            let before = rows[k - 1].total_runtime;
            let slack = 0.02 * before.as_secs_f64().max(1e-9);
            assert!(
                (stay.as_secs_f64() - before.as_secs_f64()).abs() < slack,
                "runtime moved on half-pair k={k}: {before:?} -> {stay:?}"
            );
        }
        // Full cache strictly faster than empty.
        assert!(rows[8].total_runtime < rows[0].total_runtime);
    }

    #[test]
    fn sweep_produces_paper_shape_small() {
        let opts = ExpOptions {
            workers: 4,
            tenants: 4,
            blocks_per_file: 10,
            block_len: 4096,
            fractions: vec![0.5],
            policies: PolicyKind::PAPER.to_vec(),
            seed: 17,
        };
        let rows = fig5_6_7_sweep(&opts).unwrap();
        assert_eq!(rows.len(), 3);
        let get = |p: &str| rows.iter().find(|r| r.policy == p).unwrap();
        let (lru, lrc, lerc) = (get("LRU"), get("LRC"), get("LERC"));
        assert!(lerc.makespan_s <= lrc.makespan_s + 1e-9);
        assert!(lrc.makespan_s <= lru.makespan_s + 1e-9);
        assert!(lerc.effective_hit_ratio >= lrc.effective_hit_ratio - 1e-9);
        assert!(lrc.effective_hit_ratio >= lru.effective_hit_ratio - 1e-9);
    }

    #[test]
    fn comm_overhead_bounded_by_groups() {
        let opts = ExpOptions {
            workers: 4,
            tenants: 3,
            blocks_per_file: 8,
            block_len: 4096,
            fractions: vec![0.3, 0.6],
            ..Default::default()
        };
        for row in comm_overhead(&opts).unwrap() {
            assert!(
                row.broadcasts <= row.peer_groups,
                "broadcasts {} > groups {}",
                row.broadcasts,
                row.peer_groups
            );
        }
    }

    #[test]
    fn sticky_ablation_runs() {
        let reports = ablation_sticky(3, 8, 4096, 0.4).unwrap();
        assert_eq!(reports.len(), 3);
        let lerc = &reports[0];
        let sticky = &reports[1];
        // LERC never does worse than the sticky strawman.
        assert!(lerc.effective_hit_ratio() >= sticky.effective_hit_ratio() - 1e-9);
    }
}
