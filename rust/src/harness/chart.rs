//! ASCII chart renderer: the harness's figure output (the paper's plots
//! as terminal line/bar charts).

use std::fmt::Write as _;

/// Render one or more named series over a shared x axis as an ASCII line
/// chart (y scaled to `height` rows). Series are plotted with distinct
/// glyphs; collisions show the later series' glyph.
pub fn line_chart(
    title: &str,
    x_label: &str,
    xs: &[f64],
    series: &[(&str, Vec<f64>)],
    height: usize,
) -> String {
    assert!(!xs.is_empty() && !series.is_empty());
    let glyphs = ['o', 'x', '*', '+', '#', '@'];
    let width = xs.len();
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(f64::MAX, f64::min)
        .min(ymax);
    let span = (ymax - ymin).max(1e-12);

    let mut grid = vec![vec![' '; width * 3]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let glyph = glyphs[si % glyphs.len()];
        for (xi, &y) in ys.iter().enumerate() {
            let row = ((ymax - y) / span * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][xi * 3 + 1] = glyph;
        }
    }

    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    for (ri, row) in grid.iter().enumerate() {
        let yval = ymax - span * ri as f64 / (height - 1) as f64;
        let _ = writeln!(out, "{yval:>9.3} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "{:>9} +{}", "", "-".repeat(width * 3));
    let ticks: String = xs.iter().map(|x| format!("{x:>3.2}")).collect();
    let _ = writeln!(out, "{:>10} {}", "", ticks);
    let _ = writeln!(out, "{:>10} {x_label}", "");
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (name, _))| format!("{} {}", glyphs[i % glyphs.len()], name))
        .collect();
    let _ = writeln!(out, "{:>10} legend: {}", "", legend.join("   "));
    out
}

/// Horizontal bar chart for categorical comparisons.
pub fn bar_chart(title: &str, rows: &[(&str, f64)], width: usize) -> String {
    let max = rows.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max).max(1e-12);
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    for (name, v) in rows {
        let n = ((v / max) * width as f64).round() as usize;
        let _ = writeln!(out, "{name:>10} | {:<width$} {v:.3}", "#".repeat(n));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders_all_series() {
        let xs = [0.33, 0.5, 0.66];
        let s = line_chart(
            "runtime",
            "cache fraction",
            &xs,
            &[
                ("LRU", vec![3.0, 3.0, 3.0]),
                ("LERC", vec![2.5, 2.0, 1.5]),
            ],
            8,
        );
        assert!(s.contains("runtime"));
        assert!(s.contains('o') && s.contains('x'));
        assert!(s.contains("legend: o LRU   x LERC"));
        assert_eq!(s.lines().count(), 8 + 4 + 1);
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart("t", &[("a", 1.0), ("b", 2.0)], 10);
        let a_bar = s.lines().nth(1).unwrap().matches('#').count();
        let b_bar = s.lines().nth(2).unwrap().matches('#').count();
        assert_eq!(b_bar, 10);
        assert_eq!(a_bar, 5);
    }

    #[test]
    fn constant_series_does_not_panic() {
        let s = line_chart("c", "x", &[1.0, 2.0], &[("k", vec![5.0, 5.0])], 4);
        assert!(s.contains('o'));
    }
}
