//! Minimal micro-benchmark timer (criterion substitute for the offline
//! build): warmup, repeated timed batches, mean / p50 / p95 reporting in
//! a criterion-like output format so `cargo bench` stays familiar.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_nanos(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

/// Micro-bench runner.
pub struct Bencher {
    /// Target wall time per benchmark (split across samples).
    pub target: Duration,
    /// Number of timed samples.
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self {
            target: Duration::from_millis(500),
            samples: 20,
            results: Vec::new(),
        }
    }

    pub fn with_target(mut self, target: Duration) -> Self {
        self.target = target;
        self
    }

    /// Time `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: how many iters fit one sample budget?
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.target / (self.samples as u32 * 4).max(1) || calib_iters < 3 {
            f();
            calib_iters += 1;
            if calib_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = t0.elapsed().as_nanos().max(1) / calib_iters.max(1) as u128;
        let sample_budget = (self.target.as_nanos() / self.samples as u128).max(1);
        let iters_per_sample = (sample_budget / per_iter.max(1)).clamp(1, 1_000_000) as u64;

        let mut sample_means: Vec<Duration> = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let s0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            let el = s0.elapsed();
            sample_means.push(el / iters_per_sample as u32);
            total_iters += iters_per_sample;
        }
        sample_means.sort();
        let mean = sample_means.iter().sum::<Duration>() / self.samples as u32;
        let p50 = sample_means[self.samples / 2];
        let p95 = sample_means[(self.samples * 95 / 100).min(self.samples - 1)];
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean,
            p50,
            p95,
        };
        crate::out!(
            "{:<48} time: [{:>12} {:>12} {:>12}]  ({} iters)",
            result.name,
            fmt_dur(p50),
            fmt_dur(mean),
            fmt_dur(p95),
            total_iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Run `f` once and report its wall time (for end-to-end "benches"
    /// where one run is the measurement — the paper-figure harnesses).
    pub fn bench_once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        let t0 = Instant::now();
        let out = f();
        let el = t0.elapsed();
        crate::out!("{:<48} time: [{:>12}]  (1 run)", name, fmt_dur(el));
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: 1,
            mean: el,
            p50: el,
            p95: el,
        });
        out
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let n = d.as_nanos();
    if n < 1_000 {
        format!("{n} ns")
    } else if n < 1_000_000 {
        format!("{:.2} µs", n as f64 / 1e3)
    } else if n < 1_000_000_000 {
        format!("{:.2} ms", n as f64 / 1e6)
    } else {
        format!("{:.3} s", n as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::new().with_target(Duration::from_millis(20));
        let mut acc = 0u64;
        let r = b
            .bench("noop-ish", || {
                acc = acc.wrapping_add(std::hint::black_box(1));
            })
            .clone();
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.p95 >= r.p50 || r.p95.as_nanos() + 50 >= r.p50.as_nanos());
    }

    #[test]
    fn bench_once_records() {
        let mut b = Bencher::new();
        let v = b.bench_once("one", || 42);
        assert_eq!(v, 42);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].iters, 1);
    }

    #[test]
    fn fmt_dur_scales() {
        assert_eq!(fmt_dur(Duration::from_nanos(5)), "5 ns");
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
