//! Experiment harness: one runner per paper table/figure, plus an in-tree
//! micro-benchmark timer (the build is offline, so no criterion).

pub mod bench;
pub mod chart;
pub mod experiments;
pub mod logger;

pub use bench::Bencher;
pub use experiments::*;
