//! Leveled logger for the harness and CLI (DESIGN.md §8).
//!
//! Three macros replace the ad-hoc `println!`/`eprintln!` scattering:
//!
//! * [`out!`] — deliverables (tables, charts, report lines) on stdout;
//!   suppressed only by `--quiet`.
//! * [`vlog!`] — progress and diagnostics on stderr with a `· ` prefix;
//!   shown only with `--verbose`.
//! * [`warn!`] — recoverable problems on stderr with a `warning: `
//!   prefix; always shown (even under `--quiet` — silence should never
//!   hide data loss).
//!
//! The level lives in a process-wide atomic so library code (the
//! experiment runners) and the binary share one switch without plumbing
//! a logger handle through every call. The CLI maps `--quiet` /
//! `--verbose` onto [`set_level`]; everything defaults to [`Level::Normal`].

use std::sync::atomic::{AtomicU8, Ordering};

/// Output verbosity, ordered: anything at or below the current level
/// prints.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Warnings only.
    Quiet = 0,
    /// Deliverables + warnings (the default).
    Normal = 1,
    /// Everything, including per-step progress notes.
    Verbose = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Normal as u8);

/// Set the process-wide verbosity (the CLI calls this once at startup).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current process-wide verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        2 => Level::Verbose,
        _ => Level::Normal,
    }
}

/// Would a message at `at` print right now? (Macro guard: formatting is
/// skipped entirely when it returns false.)
pub fn enabled(at: Level) -> bool {
    at as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Deliverable output (stdout). Suppressed only by `--quiet`.
#[macro_export]
macro_rules! out {
    () => {
        if $crate::harness::logger::enabled($crate::harness::logger::Level::Normal) {
            ::std::println!();
        }
    };
    ($($arg:tt)*) => {
        if $crate::harness::logger::enabled($crate::harness::logger::Level::Normal) {
            ::std::println!($($arg)*);
        }
    };
}

/// Progress / diagnostic note (stderr). Shown only with `--verbose`.
#[macro_export]
macro_rules! vlog {
    ($($arg:tt)*) => {
        if $crate::harness::logger::enabled($crate::harness::logger::Level::Verbose) {
            ::std::eprintln!("· {}", ::std::format!($($arg)*));
        }
    };
}

/// Recoverable problem (stderr). Always shown, even under `--quiet`.
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        ::std::eprintln!("warning: {}", ::std::format!($($arg)*));
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_gate() {
        assert!(Level::Quiet < Level::Normal);
        assert!(Level::Normal < Level::Verbose);
        // NOTE: the level is process-global; restore the default so
        // parallel test binaries in this crate see Normal afterwards.
        let prev = level();
        set_level(Level::Quiet);
        assert!(!enabled(Level::Normal));
        assert!(enabled(Level::Quiet));
        set_level(Level::Verbose);
        assert!(enabled(Level::Verbose));
        assert!(enabled(Level::Normal));
        set_level(prev);
    }

    #[test]
    fn macros_expand_without_printing_surprises() {
        let prev = level();
        set_level(Level::Quiet);
        // Must compile and be no-ops at Quiet (visual check only).
        out!("hidden {}", 1);
        vlog!("hidden {}", 2);
        set_level(prev);
    }
}
