//! BlockManager: couples the byte-accounted [`MemoryStore`] with a
//! [`CachePolicy`] and a pin set, and runs the eviction loop.
//!
//! Admission control falls out of the design: `insert` first admits the
//! block, then evicts policy victims until back under capacity. Since the
//! newly inserted block participates in victim selection (unless pinned),
//! a policy may *refuse* the block by evicting it immediately — LERC does
//! exactly this for blocks whose peer-groups are already broken, which is
//! how it "gives up on ineffective cache hits" (paper §IV-B).

use crate::cache::policy::{CachePolicy, PolicyEvent, Tick};
use crate::cache::store::{BlockData, MemoryStore};
use crate::common::config::PolicyKind;
use crate::common::ids::BlockId;

use std::collections::HashSet;

/// Per-worker cache counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub inserts: u64,
    pub evictions: u64,
    /// Inserts evicted within the same insert call (admission refusals).
    pub rejected: u64,
    pub mem_hits: u64,
    pub misses: u64,
}

/// Result of an insert: which blocks were evicted to make room, and
/// whether the inserted block itself survived.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InsertOutcome {
    pub evicted: Vec<BlockId>,
    pub admitted: bool,
}

pub struct BlockManager {
    store: MemoryStore,
    policy: Box<dyn CachePolicy>,
    pinned: HashSet<BlockId>,
    tick: Tick,
    pub stats: CacheStats,
}

impl BlockManager {
    pub fn new(capacity: u64, kind: PolicyKind) -> Self {
        Self {
            store: MemoryStore::new(capacity),
            policy: crate::cache::policy::new_policy(kind),
            pinned: HashSet::new(),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    fn next_tick(&mut self) -> Tick {
        self.tick += 1;
        self.tick
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Read a block, recording the access (hit or miss) in the policy and
    /// the stats.
    pub fn get(&mut self, b: BlockId) -> Option<BlockData> {
        match self.store.get(b) {
            Some(data) => {
                let tick = self.next_tick();
                self.policy.on_event(PolicyEvent::Access { block: b, tick });
                self.stats.mem_hits += 1;
                Some(data)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Non-mutating presence check (no access recorded).
    pub fn contains(&self, b: BlockId) -> bool {
        self.store.contains(b)
    }

    /// Insert a block, evicting victims until under capacity. A block
    /// larger than the whole cache is rejected outright.
    pub fn insert(&mut self, b: BlockId, data: BlockData) -> InsertOutcome {
        let bytes = MemoryStore::bytes_of(&data);
        if bytes > self.store.capacity() {
            self.stats.rejected += 1;
            return InsertOutcome {
                evicted: vec![],
                admitted: false,
            };
        }
        let tick = self.next_tick();
        self.store.put(b, data);
        self.policy.on_event(PolicyEvent::Insert { block: b, tick });
        self.stats.inserts += 1;

        let mut evicted = Vec::new();
        while self.store.over_capacity() {
            let Some(victim) = self.policy.victim(&self.pinned) else {
                // Everything remaining is pinned; caller sized pins wrong.
                break;
            };
            self.store.remove(victim);
            self.policy.on_event(PolicyEvent::Remove { block: victim });
            self.stats.evictions += 1;
            if victim == b {
                self.stats.rejected += 1;
            }
            evicted.push(victim);
        }
        let admitted = !evicted.contains(&b);
        InsertOutcome { evicted, admitted }
    }

    /// Drop a block without policy consultation (e.g. external uncache).
    pub fn remove(&mut self, b: BlockId) -> Option<BlockData> {
        let data = self.store.remove(b)?;
        self.policy.on_event(PolicyEvent::Remove { block: b });
        Some(data)
    }

    /// Pin a block (in-flight task input): exempt from eviction.
    pub fn pin(&mut self, b: BlockId) {
        self.pinned.insert(b);
    }

    pub fn unpin(&mut self, b: BlockId) {
        self.pinned.remove(&b);
    }

    pub fn pinned_count(&self) -> usize {
        self.pinned.len()
    }

    /// Forward a DAG/peer hint to the policy.
    pub fn policy_event(&mut self, ev: PolicyEvent<'_>) {
        self.policy.on_event(ev);
    }

    pub fn used(&self) -> u64 {
        self.store.used()
    }

    pub fn capacity(&self) -> u64 {
        self.store.capacity()
    }

    pub fn cached_blocks(&self) -> Vec<BlockId> {
        self.store.blocks().collect()
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Invariant: store and policy agree on membership; never over
    /// capacity after an insert completes. Used by tests.
    pub fn check_invariants(&self) -> crate::common::error::Result<()> {
        use crate::common::error::EngineError;
        if self.store.len() != self.policy.len() {
            return Err(EngineError::Invariant(format!(
                "store has {} blocks, policy tracks {}",
                self.store.len(),
                self.policy.len()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;
    use std::sync::Arc;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn payload(words: usize) -> BlockData {
        Arc::new(vec![1.0; words])
    }

    fn mgr(capacity_words: usize, kind: PolicyKind) -> BlockManager {
        BlockManager::new((capacity_words * 4) as u64, kind)
    }

    #[test]
    fn insert_within_capacity_evicts_nothing() {
        let mut m = mgr(100, PolicyKind::Lru);
        let out = m.insert(b(1), payload(50));
        assert!(out.admitted && out.evicted.is_empty());
        assert_eq!(m.len(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn lru_evicts_oldest_on_pressure() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(50));
        m.insert(b(2), payload(50));
        let out = m.insert(b(3), payload(50));
        assert_eq!(out.evicted, vec![b(1)]);
        assert!(out.admitted);
        assert!(m.contains(b(2)) && m.contains(b(3)));
        assert!(m.used() <= m.capacity());
        m.check_invariants().unwrap();
    }

    #[test]
    fn pinned_blocks_survive_pressure() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(50));
        m.pin(b(1));
        m.insert(b(2), payload(50));
        let out = m.insert(b(3), payload(50));
        assert!(!out.evicted.contains(&b(1)));
        assert!(m.contains(b(1)));
        m.unpin(b(1));
        let out = m.insert(b(4), payload(50));
        assert!(out.evicted.contains(&b(1)) || out.evicted.contains(&b(3)));
    }

    #[test]
    fn lerc_refuses_ineffective_block() {
        let mut m = mgr(100, PolicyKind::Lerc);
        // Two effective blocks fill the cache.
        for i in 1..=2 {
            m.policy_event(PolicyEvent::EffectiveCount { block: b(i), count: 1 });
            m.policy_event(PolicyEvent::RefCount { block: b(i), count: 1 });
            m.insert(b(i), payload(50));
        }
        // An ineffective block arrives: LERC evicts it immediately.
        m.policy_event(PolicyEvent::EffectiveCount { block: b(3), count: 0 });
        m.policy_event(PolicyEvent::RefCount { block: b(3), count: 1 });
        let out = m.insert(b(3), payload(50));
        assert!(!out.admitted);
        assert_eq!(out.evicted, vec![b(3)]);
        assert!(m.contains(b(1)) && m.contains(b(2)));
        assert_eq!(m.stats.rejected, 1);
    }

    #[test]
    fn oversized_block_rejected_outright() {
        let mut m = mgr(10, PolicyKind::Lru);
        let out = m.insert(b(1), payload(100));
        assert!(!out.admitted);
        assert_eq!(m.len(), 0);
        assert_eq!(m.stats.rejected, 1);
    }

    #[test]
    fn multi_victim_eviction() {
        let mut m = mgr(100, PolicyKind::Lru);
        for i in 1..=4 {
            m.insert(b(i), payload(25));
        }
        // A 75-word block forces three evictions.
        let out = m.insert(b(9), payload(75));
        assert_eq!(out.evicted, vec![b(1), b(2), b(3)]);
        assert!(m.used() <= m.capacity());
        m.check_invariants().unwrap();
    }

    #[test]
    fn get_records_hits_and_misses() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(10));
        assert!(m.get(b(1)).is_some());
        assert!(m.get(b(2)).is_none());
        assert_eq!(m.stats.mem_hits, 1);
        assert_eq!(m.stats.misses, 1);
    }

    #[test]
    fn all_pinned_breaks_loop_gracefully() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(60));
        m.pin(b(1));
        m.pin(b(2));
        let out = m.insert(b(2), payload(60));
        // Over capacity but nothing evictable: both stay (caller's bug).
        assert!(out.admitted);
        assert!(m.used() > m.capacity());
    }
}
