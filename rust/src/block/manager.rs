//! BlockManager: the single-owner facade over the sharded block store.
//!
//! Historically this type owned a monolithic `MemoryStore` + policy + pin
//! set behind `&mut self`; that implementation now lives in
//! [`crate::cache::sharded::ShardedStore`] (lock-striped, `&self`, shared
//! by the threaded engine's workers). `BlockManager` wraps a single-shard
//! store and keeps the original exclusive-access API for the experiment
//! harness, benches and tests, where one owner drives the cache and the
//! exact global eviction order matters.
//!
//! Admission control falls out of the design: `insert` first admits the
//! block, then evicts policy victims until back under capacity. Since the
//! newly inserted block participates in victim selection (unless pinned),
//! a policy may *refuse* the block by evicting it immediately — LERC does
//! exactly this for blocks whose peer-groups are already broken, which is
//! how it "gives up on ineffective cache hits" (paper §IV-B).

use crate::cache::policy::PolicyEvent;
use crate::cache::sharded::ShardedStore;
use crate::cache::store::BlockData;
use crate::common::config::PolicyKind;
use crate::common::ids::BlockId;

pub use crate::cache::sharded::{CacheStats, InsertOutcome};

pub struct BlockManager {
    inner: ShardedStore,
}

impl BlockManager {
    /// A single-shard manager: one policy instance, one global eviction
    /// order (the paper-experiment configuration).
    pub fn new(capacity: u64, kind: PolicyKind) -> Self {
        Self::with_shards(capacity, kind, 1)
    }

    /// A manager striped over `shards` shards (see [`ShardedStore::new`]).
    pub fn with_shards(capacity: u64, kind: PolicyKind, shards: usize) -> Self {
        Self {
            inner: ShardedStore::new(capacity, kind, shards),
        }
    }

    pub fn policy_name(&self) -> &'static str {
        self.inner.policy_name()
    }

    /// The shared store underneath (for callers graduating to `&self`
    /// concurrent access).
    pub fn store(&self) -> &ShardedStore {
        &self.inner
    }

    /// Read a block, recording the access (hit or miss) in the policy and
    /// the stats.
    pub fn get(&mut self, b: BlockId) -> Option<BlockData> {
        self.inner.get(b)
    }

    /// Non-mutating presence check (no access recorded).
    pub fn contains(&self, b: BlockId) -> bool {
        self.inner.contains(b)
    }

    /// Insert a block, evicting victims until under capacity. A block
    /// larger than the whole cache is rejected outright.
    pub fn insert(&mut self, b: BlockId, data: BlockData) -> InsertOutcome {
        self.inner.insert(b, data)
    }

    /// Drop a block without policy consultation (e.g. external uncache).
    /// Pinned blocks are refused (`None`): an in-use block cannot be
    /// uncached.
    pub fn remove(&mut self, b: BlockId) -> Option<BlockData> {
        self.inner.remove(b)
    }

    /// Pin a block (in-flight task input): exempt from eviction.
    pub fn pin(&mut self, b: BlockId) {
        self.inner.pin(b);
    }

    pub fn unpin(&mut self, b: BlockId) {
        self.inner.unpin(b);
    }

    pub fn pinned_count(&self) -> usize {
        self.inner.pinned_count()
    }

    /// Forward a DAG/peer hint to the policy.
    pub fn policy_event(&mut self, ev: PolicyEvent<'_>) {
        self.inner.policy_event(ev);
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.stats()
    }

    pub fn used(&self) -> u64 {
        self.inner.used()
    }

    pub fn capacity(&self) -> u64 {
        self.inner.capacity()
    }

    pub fn cached_blocks(&self) -> Vec<BlockId> {
        self.inner.cached_blocks()
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Invariant: store and policy agree on membership; byte accounting
    /// re-sums exactly. Used by tests.
    pub fn check_invariants(&self) -> crate::common::error::Result<()> {
        self.inner.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::ids::DatasetId;
    use std::sync::Arc;

    fn b(i: u32) -> BlockId {
        BlockId::new(DatasetId(0), i)
    }

    fn payload(words: usize) -> BlockData {
        Arc::from(vec![1.0; words])
    }

    fn mgr(capacity_words: usize, kind: PolicyKind) -> BlockManager {
        BlockManager::new((capacity_words * 4) as u64, kind)
    }

    #[test]
    fn insert_within_capacity_evicts_nothing() {
        let mut m = mgr(100, PolicyKind::Lru);
        let out = m.insert(b(1), payload(50));
        assert!(out.admitted && out.evicted.is_empty());
        assert_eq!(m.len(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn lru_evicts_oldest_on_pressure() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(50));
        m.insert(b(2), payload(50));
        let out = m.insert(b(3), payload(50));
        assert_eq!(out.evicted, vec![b(1)]);
        assert!(out.admitted);
        assert!(m.contains(b(2)) && m.contains(b(3)));
        assert!(m.used() <= m.capacity());
        m.check_invariants().unwrap();
    }

    #[test]
    fn pinned_blocks_survive_pressure() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(50));
        m.pin(b(1));
        m.insert(b(2), payload(50));
        let out = m.insert(b(3), payload(50));
        assert!(!out.evicted.contains(&b(1)));
        assert!(m.contains(b(1)));
        m.unpin(b(1));
        let out = m.insert(b(4), payload(50));
        assert!(out.evicted.contains(&b(1)) || out.evicted.contains(&b(3)));
    }

    #[test]
    fn lerc_refuses_ineffective_block() {
        let mut m = mgr(100, PolicyKind::Lerc);
        // Two effective blocks fill the cache.
        for i in 1..=2 {
            m.policy_event(PolicyEvent::EffectiveCount { block: b(i), count: 1 });
            m.policy_event(PolicyEvent::RefCount { block: b(i), count: 1 });
            m.insert(b(i), payload(50));
        }
        // An ineffective block arrives: LERC evicts it immediately.
        m.policy_event(PolicyEvent::EffectiveCount { block: b(3), count: 0 });
        m.policy_event(PolicyEvent::RefCount { block: b(3), count: 1 });
        let out = m.insert(b(3), payload(50));
        assert!(!out.admitted);
        assert_eq!(out.evicted, vec![b(3)]);
        assert!(m.contains(b(1)) && m.contains(b(2)));
        assert_eq!(m.stats().rejected, 1);
    }

    #[test]
    fn oversized_block_rejected_outright() {
        let mut m = mgr(10, PolicyKind::Lru);
        let out = m.insert(b(1), payload(100));
        assert!(!out.admitted);
        assert_eq!(m.len(), 0);
        assert_eq!(m.stats().rejected, 1);
    }

    #[test]
    fn multi_victim_eviction() {
        let mut m = mgr(100, PolicyKind::Lru);
        for i in 1..=4 {
            m.insert(b(i), payload(25));
        }
        // A 75-word block forces three evictions.
        let out = m.insert(b(9), payload(75));
        assert_eq!(out.evicted, vec![b(1), b(2), b(3)]);
        assert!(m.used() <= m.capacity());
        m.check_invariants().unwrap();
    }

    #[test]
    fn get_records_hits_and_misses() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(10));
        assert!(m.get(b(1)).is_some());
        assert!(m.get(b(2)).is_none());
        assert_eq!(m.stats().mem_hits, 1);
        assert_eq!(m.stats().misses, 1);
    }

    #[test]
    fn all_pinned_breaks_loop_gracefully() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(60));
        m.pin(b(1));
        m.pin(b(2));
        let out = m.insert(b(2), payload(60));
        // Over capacity but nothing evictable: both stay (caller's bug).
        assert!(out.admitted);
        assert!(m.used() > m.capacity());
    }

    #[test]
    fn repeated_pins_require_matching_unpins() {
        let mut m = mgr(100, PolicyKind::Lru);
        m.insert(b(1), payload(50));
        m.pin(b(1));
        m.pin(b(1));
        m.unpin(b(1));
        // Still pinned after one unpin: survives pressure.
        m.insert(b(2), payload(50));
        let out = m.insert(b(3), payload(50));
        assert!(!out.evicted.contains(&b(1)));
        m.unpin(b(1));
        let out = m.insert(b(4), payload(50));
        assert!(out.evicted.contains(&b(1)));
    }
}
