//! The per-worker block manager: memory store + eviction policy + pins.

pub mod manager;

pub use manager::{BlockManager, CacheStats, InsertOutcome};
