//! # lerc-engine
//!
//! A from-scratch data-parallel execution engine (Spark-like: lineage DAGs,
//! stages, per-worker block managers) built to reproduce
//! **"LERC: Coordinated Cache Management for Data-Parallel Systems"**
//! (Yu, Wang, Zhang, Letaief, 2017).
//!
//! The paper's contributions — the *effective cache hit ratio* metric, the
//! *Least Effective Reference Count* eviction policy, and the peer-tracking
//! coordination protocol — are first-class features of this engine
//! ([`cache::lerc`], [`peer`], [`metrics`]).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: DAG scheduler, per-worker
//!   sharded block stores ([`cache::sharded`]) with pluggable eviction
//!   policies, the peer-tracker protocol, a threaded multi-worker engine
//!   and a deterministic discrete-event simulator.
//! * **L2 (python/compile/model.py)** — jax task pipelines (zip, coalesce,
//!   aggregate, partition), AOT-lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels behind each pipeline.
//!
//! At runtime the engine executes task compute through the PJRT CPU client
//! ([`runtime`]); Python is never on the request path.

#![allow(clippy::too_many_arguments, clippy::type_complexity)]

pub mod block;
pub mod cache;
pub mod common;
pub mod dag;
pub mod driver;
pub mod engine;
pub mod harness;
pub mod metrics;
pub mod peer;
pub mod recovery;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod spill;
pub mod storage;
pub mod trace;
pub mod workload;

pub use common::config::{
    ComputeMode, CtrlPlane, DiskConfig, EngineConfig, EngineConfigBuilder, LinkConfig, NetConfig,
    NetModel, PolicyKind, RestorePolicy, SpillConfig, SpillMode,
};
pub use common::error::{EngineError, Result};
pub use engine::Engine;
pub use common::ids::{BlockId, DatasetId, GroupId, JobId, TaskId, WorkerId};
pub use metrics::{AttributionStats, FleetReport, JobStats, LatencyHistogram, RunReport, ScaleStats};
pub use recovery::{AutoscaleConfig, FailureEvent, FailurePlan, TopologyEvent, TopologyPlan};
pub use trace::{TraceConfig, TraceEvent};
pub use workload::{JobQueue, JobSpec};
